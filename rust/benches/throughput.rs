//! End-to-end artifact latency/throughput bench (backs Table 1).
//!
//! Measures the serving hot path per artifact batch variant: compression
//! step, memory inference, full-context parallel forward, and decode.
//! Run with `cargo bench --bench throughput` (uses the test config; pass
//! CCM_BENCH_CONFIG=main for the headline config).

use std::time::Duration;

use ccm::compress::{CompressItem, Engine, InferItem};
use ccm::datagen::{by_name, Split};
use ccm::masks::Method;
use ccm::memory::MemoryStore;
use ccm::model::Checkpoint;
use ccm::runtime::{Runtime, Value};
use ccm::training::pack::{pack_batch, PackPolicy};
use ccm::util::bench::{bench, print_table};

fn main() -> anyhow::Result<()> {
    let config = std::env::var("CCM_BENCH_CONFIG").unwrap_or_else(|_| "test".into());
    let rt = Runtime::from_config(&config)?;
    let m = rt.manifest.model.clone();
    let sc = rt.manifest.scenario.clone();
    let ck = Checkpoint::init(&rt.manifest, 7);
    let comp_len = sc.comp_len_max;
    let engine = Engine::new(&rt, &ck, comp_len)?;
    let budget = Duration::from_millis(800);
    let ds = by_name("metaicl", 7, &sc, m.vocab)?;
    let t = sc.t_max.min(4);
    let samples: Vec<_> = (0..8).map(|i| ds.sample(Split::Test, i % 8, t)).collect();
    let mem = MemoryStore::concat(m.n_layers, sc.mem_slots, m.d_model, comp_len);

    let mut rows = Vec::new();

    // Compression step at batch 1 and 8.
    for b in [1usize, 8] {
        let items: Vec<CompressItem> = samples
            .iter()
            .take(b)
            .map(|s| CompressItem { mem: &mem, chunk: &s.chunks[0], pos_start: 0 })
            .collect();
        let s = bench(&format!("compress_b{b}"), budget, 200, || {
            engine.compress(&items).unwrap();
        });
        rows.push(vec![
            s.name.clone(),
            format!("{:.2}", s.mean_ms()),
            format!("{:.1}", s.throughput(b as f64)),
        ]);
    }

    // Memory inference at batch 1 and 8.
    for b in [1usize, 8] {
        let inputs: Vec<Vec<i32>> = samples.iter().take(b).map(|s| s.input_with_target()).collect();
        let items: Vec<InferItem> = inputs
            .iter()
            .map(|tk| InferItem { mem: &mem, tokens: tk, pos_start: 0 })
            .collect();
        let s = bench(&format!("infer_with_mem_b{b}"), budget, 200, || {
            engine.infer(&items).unwrap();
        });
        rows.push(vec![
            s.name.clone(),
            format!("{:.2}", s.mean_ms()),
            format!("{:.1}", s.throughput(b as f64)),
        ]);
    }

    // Full-context parallel forward (what "no compression" costs).
    let nb = rt.manifest.base_layout.total;
    let nl = rt.manifest.lora_layout.total;
    for b in sc.infer_batches.clone() {
        let policy = PackPolicy::new(Method::Full, comp_len);
        let refs: Vec<_> = samples.iter().take(b).map(|s| (s, None)).collect();
        let batch = pack_batch(&policy, &rt.manifest, &refs, b)?;
        let inputs = vec![
            Value::vec_f32(&[nb], ck.base.data.clone())?,
            Value::vec_f32(&[nl], ck.lora.data.clone())?,
            Value::I32(batch.tokens.clone()),
            Value::I32(batch.comp_slot.clone()),
            Value::F32(batch.gate.clone()),
            Value::I32(batch.pos.clone()),
            Value::F32(batch.mask.clone()),
            Value::F32(batch.merge_p.clone()),
        ];
        let name = format!("ccm_forward_b{b}");
        rt.executable(&name)?;
        let s = bench(&format!("full_forward_b{b}"), budget, 100, || {
            rt.execute_f32(&name, &inputs).unwrap();
        });
        rows.push(vec![
            s.name.clone(),
            format!("{:.2}", s.mean_ms()),
            format!("{:.1}", s.throughput(b as f64)),
        ]);
    }

    print_table(
        &format!("serving hot-path latency (config {config})"),
        &["op", "mean ms", "items/s"],
        &rows,
    );

    // The Table-1 shape check: memory inference beats full-context
    // scoring per sample once contexts are long.
    Ok(())
}
