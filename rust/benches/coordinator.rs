//! Coordinator-overhead micro-benchmarks (host-side only, no XLA).
//!
//! The L3 perf target (DESIGN.md §8): coordinator bookkeeping must be
//! negligible next to artifact execution. These benches quantify mask
//! building, batch packing, memory updates and batcher scheduling.

use std::time::Duration;

use ccm::coordinator::batcher::{Batcher, WorkKind};
use ccm::datagen::{by_name, Split};
use ccm::masks::{build_layout, build_masks, MergeScheme, Method};
use ccm::memory::{CompressedChunk, MemoryStore};
use ccm::model::manifest::ScenarioConfig;
use ccm::training::pack::{pack_batch, PackPolicy};
use ccm::util::bench::{bench, print_table};

fn scenario() -> ScenarioConfig {
    ScenarioConfig {
        t_max: 8,
        chunk_max: 20,
        comp_len_max: 4,
        input_max: 32,
        seq_train: 224,
        mem_slots: 32,
        batch_train: 8,
        infer_batches: vec![1, 8],
        decode_cache: 96,
        rmt_unroll: 4,
        rmt_mem: 4,
    }
}

/// Worker mode for the IPC bench scenario: the bench re-execs itself
/// (env-gated, since bench binaries own `main`) as each shard's worker
/// process over the same sub-ms SimCompute backend.
fn bench_worker_main() -> anyhow::Result<()> {
    use ccm::compress::{Compute, SimCompute};
    use ccm::coordinator::session::SessionPolicy;
    use ccm::server::{BackendFactory, ServerConfig};

    // Absent means "use the default"; present-but-unparseable must
    // fail loudly. Silently defaulting here once turned a typoed shard
    // count into a single-shard bench that looked plausible.
    let env_usize = |key: &str, default: usize| -> anyhow::Result<usize> {
        match std::env::var(key) {
            Ok(v) => v.parse().map_err(|_| anyhow::anyhow!("{key}={v:?} is not a valid usize")),
            Err(_) => Ok(default),
        }
    };
    let sc = scenario();
    let manifest = fake_manifest(sc.clone());
    let mut sim = SimCompute::from_manifest(&manifest);
    sim.compress_delay = Duration::from_micros(200);
    sim.infer_delay = Duration::from_micros(200);
    let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(sc.comp_len_max));
    cfg.shards = env_usize("CCM_BENCH_WORKER_SHARDS", 1)?;
    cfg.max_batch = 8;
    cfg.max_wait = Duration::from_millis(1);
    cfg.max_pending = 4096;
    let shard = env_usize("CCM_BENCH_WORKER_SHARD", 0)?;
    let factory: BackendFactory<'static> = Box::new(move || Ok(Box::new(sim) as Box<dyn Compute>));
    ccm::server::run_worker(&manifest, factory, cfg, shard, None)
}

fn main() -> anyhow::Result<()> {
    if std::env::var("CCM_BENCH_WORKER").as_deref() == Ok("1") {
        return bench_worker_main();
    }
    let budget = Duration::from_millis(500);
    let sc = scenario();
    let mut rows = Vec::new();

    // Mask building (per packed row) for each method.
    let chunk_lens = vec![18usize; 8];
    for method in [Method::Full, Method::CcmConcat, Method::CcmMerge, Method::Compressive] {
        let cl = if method.uses_comp_tokens() { 2 } else { 0 };
        let lay = build_layout(&chunk_lens, cl, 24, sc.seq_train)?;
        let s = bench(&format!("mask/{}", method.name()), budget, 10_000, || {
            build_masks(method, &lay, sc.mem_slots, MergeScheme::Avg, 2).unwrap();
        });
        rows.push(vec![s.name.clone(), format!("{:.3}", s.mean_ms()), String::new()]);
    }

    // Full batch packing (8 samples) — what the trainer/evaluator stages.
    {
        let manifest = fake_manifest(sc.clone());
        let ds = by_name("metaicl", 7, &sc, 512)?;
        let samples: Vec<_> = (0..8).map(|i| ds.sample(Split::Train, i, 8)).collect();
        let refs: Vec<_> = samples.iter().map(|s| (s, None)).collect();
        let policy = PackPolicy::new(Method::CcmConcat, 2);
        let s = bench("pack_batch/b8", budget, 5_000, || {
            pack_batch(&policy, &manifest, &refs, 8).unwrap();
        });
        rows.push(vec![s.name.clone(), format!("{:.3}", s.mean_ms()), "8 rows".into()]);
    }

    // Memory update throughput (concat + merge).
    {
        let h = CompressedChunk {
            k: vec![0.5; 4 * 2 * 128],
            v: vec![0.5; 4 * 2 * 128],
            comp_len: 2,
        };
        let s = bench("mem/concat-update", budget, 100_000, || {
            let mut m = MemoryStore::concat(4, 32, 128, 2);
            for _ in 0..8 {
                m.update(&h).unwrap();
            }
        });
        rows.push(vec![s.name.clone(), format!("{:.4}", s.mean_ms()), "8 updates".into()]);
        let s = bench("mem/merge-update", budget, 100_000, || {
            let mut m = MemoryStore::merge(4, 32, 128, 2, MergeScheme::Avg);
            for _ in 0..8 {
                m.update(&h).unwrap();
            }
        });
        rows.push(vec![s.name.clone(), format!("{:.4}", s.mean_ms()), "8 updates".into()]);
    }

    // Batcher scheduling under load (both policies).
    for infer_priority in [false, true] {
        let name =
            if infer_priority { "batcher/1k-items-prio" } else { "batcher/1k-items" };
        let s = bench(name, budget, 2_000, || {
            let mut b = Batcher::new(8, Duration::ZERO);
            b.infer_priority = infer_priority;
            for i in 0..1000 {
                let kind = if i % 3 == 0 { WorkKind::Infer } else { WorkKind::Compress };
                b.push(&format!("s{}", i % 32), kind, vec![1, 2, 3]);
            }
            while b.next_batch(std::time::Instant::now(), true).is_some() {}
        });
        rows.push(vec![s.name.clone(), format!("{:.3}", s.mean_ms()), "1000 items".into()]);
    }

    // Multi-session serve throughput over the full TCP path: acceptor,
    // connection threads, admission control, pipelined executor, KV
    // governance. SimCompute backend with sub-ms artificial latency —
    // this measures the serving engine, not the model.
    {
        use ccm::compress::SimCompute;
        use ccm::coordinator::session::SessionPolicy;
        use ccm::server::{serve_with_backend, Client, ServerConfig};
        use std::sync::mpsc::channel;

        let manifest = fake_manifest(sc.clone());
        let mut sim = SimCompute::from_manifest(&manifest);
        sim.compress_delay = Duration::from_micros(200);
        sim.infer_delay = Duration::from_micros(200);
        let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(sc.comp_len_max));
        cfg.max_batch = 8;
        cfg.max_wait = Duration::from_millis(1);
        cfg.max_pending = 4096;
        cfg.kv_budget_bytes = Some(64 << 20);
        let (ready_tx, ready_rx) = channel();
        let server = std::thread::spawn(move || {
            serve_with_backend(&manifest, Box::new(sim), cfg, Some(ready_tx))
        });
        let addr = ready_rx.recv()?;
        let n_clients = 8usize;
        let rounds = 50usize;
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                let session = format!("bench{c}");
                for r in 0..rounds {
                    client.add_context(&session, &[1, 2, 3, 4]).unwrap();
                    let next = client.query(&session, &[(r % 30 + 1) as i32], 3).unwrap();
                    assert_eq!(next.len(), 3);
                }
            }));
        }
        for h in handles {
            h.join().expect("bench client");
        }
        let secs = t0.elapsed().as_secs_f64();
        let total = (n_clients * rounds) as f64;
        let mut admin = Client::connect(&addr)?;
        let stats = admin.stats()?;
        let sessions = stats.get("sessions")?.usize()?;
        admin.shutdown()?;
        server.join().expect("server thread")?;
        rows.push(vec![
            "serve/tcp-ctx+query".into(),
            format!("{:.3}", secs * 1e3 / total),
            format!("{:.0} rounds/s across {sessions} sessions", total / secs),
        ]);
    }

    // The same protocol load over a 4-shard server: one executor
    // (SimCompute backend) per shard, sessions hash-routed. Quantifies
    // what executor replication buys when the backend is the bottleneck.
    {
        use ccm::compress::{Compute, SimCompute};
        use ccm::coordinator::session::SessionPolicy;
        use ccm::server::{serve_sharded, BackendFactory, Client, ServerConfig};
        use std::sync::mpsc::channel;

        let manifest = fake_manifest(sc.clone());
        let shards = 4usize;
        let sims: Vec<SimCompute> = (0..shards)
            .map(|_| {
                let mut sim = SimCompute::from_manifest(&manifest);
                sim.compress_delay = Duration::from_micros(200);
                sim.infer_delay = Duration::from_micros(200);
                sim
            })
            .collect();
        let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(sc.comp_len_max));
        cfg.max_batch = 8;
        cfg.max_wait = Duration::from_millis(1);
        cfg.max_pending = 4096;
        cfg.kv_budget_bytes = Some(64 << 20);
        let (ready_tx, ready_rx) = channel();
        let server = std::thread::spawn(move || {
            let factories: Vec<BackendFactory<'static>> = sims
                .into_iter()
                .map(|sim| {
                    Box::new(move || Ok(Box::new(sim) as Box<dyn Compute>))
                        as BackendFactory<'static>
                })
                .collect();
            serve_sharded(&manifest, factories, cfg, Some(ready_tx))
        });
        let addr = ready_rx.recv()?;
        let n_clients = 8usize;
        let rounds = 50usize;
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                let session = format!("bench{c}");
                for r in 0..rounds {
                    client.add_context(&session, &[1, 2, 3, 4]).unwrap();
                    let next = client.query(&session, &[(r % 30 + 1) as i32], 3).unwrap();
                    assert_eq!(next.len(), 3);
                }
            }));
        }
        for h in handles {
            h.join().expect("bench client");
        }
        let secs = t0.elapsed().as_secs_f64();
        let total = (n_clients * rounds) as f64;
        let mut admin = Client::connect(&addr)?;
        let stats = admin.stats()?;
        let sessions = stats.get("sessions")?.usize()?;
        admin.shutdown()?;
        server.join().expect("server thread")?;
        rows.push(vec![
            format!("serve/tcp-{shards}shard"),
            format!("{:.3}", secs * 1e3 / total),
            format!("{:.0} rounds/s across {sessions} sessions", total / secs),
        ]);
    }

    // Many-connection fan-in over the polling reactor: 256 concurrent
    // connections multiplexed on one reactor thread (the thread-per-
    // connection scaling wall this front-end removes), 8 driver
    // threads owning 32 sockets each. Quantifies per-connection
    // reactor overhead, not backend speed.
    {
        use ccm::compress::{Compute, SimCompute};
        use ccm::coordinator::session::SessionPolicy;
        use ccm::server::{serve_sharded, BackendFactory, Client, ReactorMode, ServerConfig};
        use std::sync::mpsc::channel;

        let manifest = fake_manifest(sc.clone());
        let shards = 2usize;
        let sims: Vec<SimCompute> = (0..shards)
            .map(|_| {
                let mut sim = SimCompute::from_manifest(&manifest);
                sim.compress_delay = Duration::from_micros(50);
                sim.infer_delay = Duration::from_micros(50);
                sim
            })
            .collect();
        let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(sc.comp_len_max));
        cfg.max_batch = 8;
        cfg.max_wait = Duration::from_millis(1);
        cfg.max_pending = 8192;
        cfg.reactor = ReactorMode::Epoll;
        // Multi-reactor accept sharding (SO_REUSEPORT where available):
        // the 256-connection fan-in spread over two event loops.
        cfg.reactors = 2;
        cfg.max_conns = 2048;
        let (ready_tx, ready_rx) = channel();
        let server = std::thread::spawn(move || {
            let factories: Vec<BackendFactory<'static>> = sims
                .into_iter()
                .map(|sim| {
                    Box::new(move || Ok(Box::new(sim) as Box<dyn Compute>))
                        as BackendFactory<'static>
                })
                .collect();
            serve_sharded(&manifest, factories, cfg, Some(ready_tx))
        });
        let addr = ready_rx.recv()?;
        let n_threads = 8usize;
        let conns_per_thread = 32usize;
        let rounds = 4usize;
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for t in 0..n_threads {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                // Open (and hold) this thread's slice of the 256 conns.
                let mut clients: Vec<Client> =
                    (0..conns_per_thread).map(|_| Client::connect(&addr).unwrap()).collect();
                for r in 0..rounds {
                    for (i, client) in clients.iter_mut().enumerate() {
                        let session = format!("fan{t}-{i}");
                        client.add_context(&session, &[1, 2, 3, 4]).unwrap();
                        let next = client.query(&session, &[(r % 30 + 1) as i32], 3).unwrap();
                        assert_eq!(next.len(), 3);
                    }
                }
            }));
        }
        for h in handles {
            h.join().expect("fan-in client thread");
        }
        let secs = t0.elapsed().as_secs_f64();
        let conns = n_threads * conns_per_thread;
        let total = (conns * rounds) as f64;
        let mut admin = Client::connect(&addr)?;
        let stats = admin.stats()?;
        let sessions = stats.get("sessions")?.usize()?;
        admin.shutdown()?;
        server.join().expect("server thread")?;
        rows.push(vec![
            format!("serve/tcp-{conns}conn-epoll"),
            format!("{:.3}", secs * 1e3 / total),
            format!("{:.0} rounds/s across {sessions} sessions", total / secs),
        ]);
    }

    // The sharded protocol load again, but with each shard executor in
    // its own WORKER PROCESS behind the pipelined IPC proxy (the bench
    // re-execs itself in worker mode). Read against serve/tcp-Nshard:
    // the delta is what the process boundary costs per round trip.
    {
        use ccm::coordinator::session::SessionPolicy;
        use ccm::server::{serve_workers, Client, ServerConfig, WorkerMode};
        use std::sync::mpsc::channel;

        let workers = 2usize;
        let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(sc.comp_len_max));
        cfg.max_batch = 8;
        cfg.max_wait = Duration::from_millis(1);
        cfg.max_pending = 4096;
        let exe = std::env::current_exe()?;
        let mode = WorkerMode::Spawn {
            count: workers,
            launcher: Box::new(move |shard| {
                let mut cmd = std::process::Command::new(&exe);
                cmd.env("CCM_BENCH_WORKER", "1")
                    .env("CCM_BENCH_WORKER_SHARD", shard.to_string())
                    .env("CCM_BENCH_WORKER_SHARDS", workers.to_string());
                cmd
            }),
        };
        let (ready_tx, ready_rx) = channel();
        let server = std::thread::spawn(move || serve_workers(cfg, mode, Some(ready_tx)));
        let addr = ready_rx.recv()?;
        let n_clients = 8usize;
        let rounds = 50usize;
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                let session = format!("bench{c}");
                for r in 0..rounds {
                    client.add_context(&session, &[1, 2, 3, 4]).unwrap();
                    let next = client.query(&session, &[(r % 30 + 1) as i32], 3).unwrap();
                    assert_eq!(next.len(), 3);
                }
            }));
        }
        for h in handles {
            h.join().expect("worker-bench client");
        }
        let secs = t0.elapsed().as_secs_f64();
        let total = (n_clients * rounds) as f64;
        let mut admin = Client::connect(&addr)?;
        let stats = admin.stats()?;
        let sessions = stats.get("sessions")?.usize()?;
        assert_eq!(stats.get("shard_restarts")?.usize()?, 0, "no worker may crash mid-bench");
        admin.shutdown()?;
        server.join().expect("server thread")?;
        rows.push(vec![
            format!("serve/tcp-{workers}worker-ipc"),
            format!("{:.3}", secs * 1e3 / total),
            format!("{:.0} rounds/s across {sessions} sessions", total / secs),
        ]);
    }

    print_table("coordinator overhead (host-side)", &["op", "mean ms", "note"], &rows);
    Ok(())
}

fn fake_manifest(sc: ScenarioConfig) -> ccm::model::Manifest {
    use ccm::model::manifest::*;
    Manifest {
        config_name: "bench".into(),
        dir: std::path::PathBuf::from("."),
        model: ModelConfig {
            name: "bench".into(),
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 512,
            max_pos: 512,
            lora_rank: 8,
            lora_alpha: 16.0,
            pad_id: 0,
            bos_id: 1,
            sep_id: 2,
            comp_id: 3,
            d_head: 32,
        },
        scenario: sc,
        base_layout: ParamLayout { total: 1, entries: vec![] },
        lora_layout: ParamLayout { total: 1, entries: vec![] },
        artifacts: vec![],
        mask_goldens: vec![],
    }
}
