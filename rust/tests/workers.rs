//! Fault-injection integration tests for the cross-process worker
//! topology: SIGKILL a worker mid-burst and prove the documented
//! failure semantics over real sockets and real processes — in-flight
//! requests to the dead shard fail over to `shard_unavailable` (no
//! hang, no dropped connection), other shards keep answering
//! throughout, and the supervisor respawns the worker with fresh
//! sessions and an incremented `shard_restarts`, all without
//! restarting the front-end. Until this suite, nothing exercised
//! partial failure: every prior topology died as one process.
//!
//! Worker processes are this same test binary re-exec'd through
//! `sim_worker_process_entry` (see `common::sim_worker_entry_if_requested`).

mod common;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use ccm::coordinator::session::SessionPolicy;
use ccm::model::Manifest;
use ccm::server::{serve_workers, shard_for, Client, ServerConfig, WorkerMode};
use ccm::util::json::Json;

use common::{
    assert_error, assert_ok, ids_on_shard, kill9, poll_until, process_alive, top1, wait_drained,
    ServerHandle,
};

/// Re-exec entry: processes spawned by these tests run THIS test with
/// the worker env set and become SimCompute worker processes; in a
/// normal test run it is an empty pass.
#[test]
fn sim_worker_process_entry() {
    common::sim_worker_entry_if_requested();
}

const ENTRY: &str = "sim_worker_process_entry";

#[test]
fn worker_topology_routes_stably_and_shuts_down_every_process() {
    let workers = 2usize;
    let server = common::start_worker_server(ENTRY, workers, Vec::new(), |_| {});
    let mut admin = server.client();
    common::wait_workers_up(&mut admin, workers, Duration::from_secs(30));
    // Routing stability across processes AND connections: a session's
    // chunks land on one worker whatever connection carries them, so
    // its time step keeps advancing.
    let n_sessions = 8usize;
    for round in 1..=2i64 {
        let mut client = server.client();
        for s in 0..n_sessions {
            let ack = client.add_context(&format!("user{s}"), &[1, 2]).unwrap();
            assert_ok(&ack);
            assert_eq!(ack.get("t").unwrap().i64().unwrap(), round, "user{s}");
        }
        let next = client.query(&format!("user{round}"), &[6], 1).unwrap();
        assert_eq!(top1(&next), 6);
    }
    let stats = wait_drained(&mut admin, Duration::from_secs(10));
    assert_eq!(stats.get("shards").unwrap().usize().unwrap(), workers);
    assert_eq!(stats.get("sessions").unwrap().usize().unwrap(), n_sessions);
    assert_eq!(stats.get("compressions").unwrap().usize().unwrap(), n_sessions * 2);
    assert_eq!(stats.get("shard_restarts").unwrap().usize().unwrap(), 0);
    // Per-shard split matches the routing hash exactly — across the
    // process boundary, same invariant as in-process shards.
    for (i, p) in stats.get("per_shard").unwrap().arr().unwrap().iter().enumerate() {
        let expected =
            (0..n_sessions).filter(|s| shard_for(&format!("user{s}"), workers) == i).count();
        assert_eq!(p.get("shard").unwrap().usize().unwrap(), i);
        assert_eq!(p.get("sessions").unwrap().usize().unwrap(), expected, "shard {i}");
    }
    // Supervision rows: both workers up, live pids, a live RTT sample.
    let pids = server.note_pids(&stats);
    let rows = stats.get("per_worker").unwrap().arr().unwrap();
    assert_eq!(rows.len(), workers);
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.get("worker").unwrap().usize().unwrap(), i);
        assert_eq!(row.get("up").unwrap(), &Json::Bool(true), "worker {i}");
        assert!(pids[i].is_some(), "worker {i} must report its pid");
        assert!(process_alive(pids[i].unwrap()) || !cfg!(unix), "worker {i} pid must be live");
        assert!(row.get("rtt_ms").unwrap().f64().unwrap() > 0.0, "worker {i} rtt sample");
    }
    // Shutdown drains ACROSS the IPC boundary: the ack arrives only
    // after both workers drained; the processes then exit and the
    // front-end port is released.
    let addr = server.addr().to_string();
    server.shutdown_join();
    if cfg!(unix) {
        for pid in pids.into_iter().flatten() {
            poll_until(Duration::from_secs(10), "worker process to exit after shutdown", || {
                (!process_alive(pid)).then_some(())
            });
        }
    }
    assert!(std::net::TcpListener::bind(&addr).is_ok(), "port still bound after shutdown");
}

#[cfg(unix)]
#[test]
fn worker_kill_mid_burst_fails_fast_while_other_shards_serve_and_respawn_recovers() {
    let workers = 2usize;
    // The victim shard gets a 2 s inference delay so the burst below is
    // guaranteed to still be in flight when the SIGKILL lands; the
    // survivor shard stays fast.
    let per_shard_env =
        vec![vec![("CCM_TEST_WORKER_INFER_MS".to_string(), "2000".to_string())], Vec::new()];
    let server = common::start_worker_server(ENTRY, workers, per_shard_env, |_| {});
    let addr = server.addr().to_string();
    let mut admin = server.client();
    common::wait_workers_up(&mut admin, workers, Duration::from_secs(30));

    // Establish state on both shards: the victim session reaches t=2,
    // the survivor t=1.
    let victim_sessions = ids_on_shard(0, workers, 4);
    let survivor_session = ids_on_shard(1, workers, 1).pop().unwrap();
    let mut client = server.client();
    let victim_session = victim_sessions[0].clone();
    for tokens in [[1, 2], [3, 4]] {
        let ack = client.add_context(&victim_session, &tokens).unwrap();
        assert_ok(&ack);
    }
    let ack = client.add_context(&survivor_session, &[5, 6]).unwrap();
    assert_ok(&ack);
    let stats = wait_drained(&mut admin, Duration::from_secs(10));
    let pids = server.note_pids(&stats);
    let victim_pid = pids[0].expect("worker 0 up");

    // Survivor load brackets the whole failure: continuous queries on
    // shard 1, every single one asserted OK.
    let stop = Arc::new(AtomicBool::new(false));
    let survivor_ok = Arc::new(AtomicUsize::new(0));
    let survivor = {
        let addr = addr.clone();
        let session = survivor_session.clone();
        let stop = stop.clone();
        let survivor_ok = survivor_ok.clone();
        std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("survivor connect");
            while !stop.load(Ordering::SeqCst) {
                let next = client.query(&session, &[9], 1).expect("survivor reply");
                assert_eq!(next[0].0, 9, "survivor reply corrupted");
                survivor_ok.fetch_add(1, Ordering::SeqCst);
            }
        })
    };

    // In-flight burst against the victim shard: one query per session,
    // each stuck behind the 2 s inference when the kill lands. Every
    // one must come back as a prompt `shard_unavailable` — not a hang,
    // not a dropped connection.
    let written = Arc::new(AtomicUsize::new(0));
    let mut burst = Vec::new();
    for session in victim_sessions.iter().cloned() {
        let addr = addr.clone();
        let written = written.clone();
        burst.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("burst connect");
            let line =
                format!("{{\"op\":\"query\",\"session\":\"{session}\",\"tokens\":[4],\"topk\":1}}");
            // call() writes the line, then blocks on the reply; the
            // written counter lets the killer thread sequence itself.
            written.fetch_add(1, Ordering::SeqCst);
            let t0 = Instant::now();
            let resp = client.call(&line).expect("a reply line, not a dropped connection");
            (resp, t0.elapsed())
        }));
    }
    poll_until(Duration::from_secs(10), "burst queries to be written", || {
        (written.load(Ordering::SeqCst) == burst.len()).then_some(())
    });
    // Let the frames reach the worker's executor, then kill it cold.
    std::thread::sleep(Duration::from_millis(150));
    kill9(victim_pid);
    for b in burst {
        let (resp, elapsed) = b.join().expect("burst thread");
        assert_error(&resp, "shard_unavailable");
        assert!(
            elapsed < Duration::from_secs(8),
            "failover must be prompt (got {elapsed:?}), never a hang on the 2 s backend"
        );
    }

    // Respawn: restarts increments and the worker returns under a new
    // pid — while the survivor thread keeps asserting on shard 1.
    let new_pid = poll_until(Duration::from_secs(30), "worker 0 to respawn", || {
        let stats = admin.stats().expect("stats during outage");
        let pids = server.note_pids(&stats);
        let row = &stats.get("per_worker").unwrap().arr().unwrap()[0];
        let up = row.get("up").unwrap() == &Json::Bool(true);
        let restarts = row.get("restarts").unwrap().usize().unwrap();
        match pids[0] {
            Some(pid) if up && restarts == 1 && pid != victim_pid => Some(pid),
            _ => None,
        }
    });
    assert_ne!(new_pid, victim_pid);

    // Fresh sessions: the victim session had reached t=2; after the
    // respawn its next chunk acks t=1 — Mem(t) died with the process.
    let t = poll_until(Duration::from_secs(15), "victim shard to serve again", || {
        let mut c = Client::connect(&addr).expect("connect");
        let ack = c.add_context(&victim_session, &[7]).expect("reply");
        if ack.get("ok").unwrap() == &Json::Bool(true) {
            Some(ack.get("t").unwrap().i64().unwrap())
        } else {
            assert_error(&ack, "shard_unavailable"); // the only refusal allowed here
            None
        }
    });
    assert_eq!(t, 1, "{victim_session}: respawned worker must start fresh");

    // The survivor never missed a beat, before, during, or after.
    let before_stop = survivor_ok.load(Ordering::SeqCst);
    assert!(before_stop > 0, "survivor load must have been flowing");
    stop.store(true, Ordering::SeqCst);
    survivor.join().expect("survivor thread — a non-victim reply was lost");
    // And its session state was untouched by the neighbour's death.
    let ack = client.add_context(&survivor_session, &[8]).unwrap();
    assert_ok(&ack);
    assert_eq!(ack.get("t").unwrap().i64().unwrap(), 2, "survivor state must persist");

    let stats = wait_drained(&mut admin, Duration::from_secs(30));
    assert_eq!(stats.get("shard_restarts").unwrap().usize().unwrap(), 1);
    server.shutdown_join();
}

#[test]
fn shutdown_storm_resolves_every_requester_promptly() {
    let workers = 2usize;
    // Tightened per-request reply deadline: pre-fix, a shutdown
    // dispatched after the fleet had drained was stashed in a ledger
    // nobody read anymore, and its client parked here until the
    // timeout reply (`ok:false`) — which this test turns into a
    // failure. Post-fix the late shutdown is refused: the connection
    // closes and `Client::shutdown` treats the EOF as the ack.
    let server = common::start_worker_server(ENTRY, workers, Vec::new(), |cfg| {
        cfg.reply_timeout = Duration::from_secs(10);
    });
    let addr = server.addr().to_string();
    let mut admin = server.client();
    common::wait_workers_up(&mut admin, workers, Duration::from_secs(30));

    // Concurrent staggered shutdown requesters, kept flowing through
    // the whole drain so some land while the workers are draining and
    // some after the drain ledger was collected. Every one must
    // resolve as an ack or a clean close — never a timeout reply.
    let mut stormers = Vec::new();
    for i in 0..8usize {
        let addr = addr.clone();
        stormers.push(std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150 * i as u64));
            let mut resolved = 0usize;
            loop {
                let Ok(mut client) = Client::connect(&addr) else {
                    return resolved; // port released: the fleet is down
                };
                client.shutdown().expect("shutdown must ack or close, never time out");
                resolved += 1;
            }
        }));
    }
    let mut total = 0usize;
    for s in stormers {
        total += s.join().expect("stormer thread");
    }
    assert!(total > 0, "at least the first stormer must see the full drain ack");
    server.join();
}

#[test]
fn external_workers_connect_mode_serves_and_drains() {
    // `--worker-addr` topology: the workers are started by the test
    // (stand-ins for an operator), the front-end only connects.
    let workers = 2usize;
    let (mut child0, addr0) = common::spawn_raw_sim_worker(ENTRY, 0, workers);
    let (mut child1, addr1) = common::spawn_raw_sim_worker(ENTRY, 1, workers);
    let m = Manifest::toy();
    let cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(m.scenario.comp_len_max));
    let (ready_tx, ready_rx) = channel();
    let mode = WorkerMode::Connect { addrs: vec![addr0, addr1] };
    let handle = std::thread::spawn(move || serve_workers(cfg, mode, Some(ready_tx)));
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).expect("server ready");
    let server = ServerHandle::new(addr, handle);
    let mut admin = server.client();
    common::wait_workers_up(&mut admin, workers, Duration::from_secs(30));

    let mut client = server.client();
    for shard in 0..workers {
        for id in ids_on_shard(shard, workers, 2) {
            let ack = client.add_context(&id, &[1, 2]).unwrap();
            assert_ok(&ack);
            assert_eq!(ack.get("t").unwrap().i64().unwrap(), 1, "{id}");
            let next = client.query(&id, &[3], 1).unwrap();
            assert_eq!(top1(&next), 3, "{id}");
        }
    }
    let stats = wait_drained(&mut admin, Duration::from_secs(10));
    assert_eq!(stats.get("sessions").unwrap().usize().unwrap(), 2 * workers);
    let rows = stats.get("per_worker").unwrap().arr().unwrap();
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.get("up").unwrap(), &Json::Bool(true), "worker {i}");
        assert_eq!(
            row.get("pid").unwrap(),
            &Json::Null,
            "connect mode supervises connections, not processes"
        );
        assert_eq!(row.get("restarts").unwrap().usize().unwrap(), 0);
    }
    // Shutdown drains both EXTERNAL workers too: they ack and exit on
    // their own, and only then does the front-end ack its client.
    server.shutdown_join();
    child0.wait_success(Duration::from_secs(10), "external worker 0 to exit after drain");
    child1.wait_success(Duration::from_secs(10), "external worker 1 to exit after drain");
}
