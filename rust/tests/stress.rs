//! CI stress gates for the serving engine: >= 1024 concurrent
//! connections against a sharded SimCompute server, hard-gating
//! against lost replies, broken session accounting, and fd leaks —
//! in-process shards (`CCM_STRESS=1`); for the cross-process
//! topology, worker-process shards with a mid-stress SIGKILL restart
//! (`CCM_STRESS=1` + `CCM_STRESS_WORKERS=1`); and tiered session
//! memory under an aggressive spill threshold, gating exact
//! hibernation counter balance and pre-spill `t` resume
//! (`CCM_STRESS=1` + `CCM_STRESS_HIBERNATE=1`).
//!
//! Gated because they need a raised fd limit (>= 4096; the default
//! soft limit of 1024 cannot hold 2048 sockets). The CI `stress` job
//! matrix runs them in release with `ulimit -n 65536`:
//!
//! ```bash
//! ulimit -n 65536 && CCM_STRESS=1 cargo test --release --test stress
//! ```

mod common;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use ccm::compress::{Compute, SimCompute};
use ccm::coordinator::session::SessionPolicy;
use ccm::model::Manifest;
use ccm::server::{serve_sharded, BackendFactory, Client, ReactorMode, ServerConfig};
use ccm::util::json::Json;

use common::{ids_on_shard, kill9, poll_until, wait_drained};

const N_WORKERS: usize = 32;
const CONNS_PER_WORKER: usize = 32; // 1024 concurrent connections
const ROUNDS: i64 = 2;
const CHURN_PER_WORKER: usize = 8; // extra short-lived connections

/// Both stress tests bracket themselves with PROCESS-WIDE fd counts,
/// so they must never overlap (libtest runs tests concurrently by
/// default): each takes this lock for its whole body. Poisoning is
/// ignored — one failed gate must not turn the other into a second
/// spurious failure.
static STRESS_GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn open_fds() -> Option<usize> {
    std::fs::read_dir("/proc/self/fd").ok().map(|dir| dir.count())
}

fn stress_enabled() -> bool {
    std::env::var("CCM_STRESS").map(|v| v == "1") == Ok(true)
}

/// The CI stress matrix drives the reactor count through
/// CCM_SERVE_REACTORS; unset defaults to 1. Parsed strictly: a typo'd
/// value must fail the gate loudly, not silently run one reactor while
/// the job claims to cover four.
fn reactors_from_env_strict() -> usize {
    match std::env::var("CCM_SERVE_REACTORS") {
        Ok(v) => v.parse::<usize>().expect("CCM_SERVE_REACTORS must be a positive integer"),
        Err(_) => 1,
    }
}

/// Re-exec entry: processes spawned by the worker-topology stress test
/// run THIS test with the worker env set and become SimCompute worker
/// processes; in a normal test run it is an empty pass.
#[test]
fn stress_sim_worker_entry() {
    common::sim_worker_entry_if_requested();
}

#[test]
fn reactor_sustains_1024_connections_without_lost_replies_or_fd_leaks() {
    if !stress_enabled() {
        eprintln!(
            "skipping reactor stress test: set CCM_STRESS=1 (needs `ulimit -n` >= 4096; \
             run by the CI `stress` job)"
        );
        return;
    }
    let _gate = STRESS_GATE.lock().unwrap_or_else(|e| e.into_inner());
    let fd_baseline = open_fds();

    let shards = 4usize;
    let reactors = reactors_from_env_strict();
    let manifest = Manifest::toy();
    let mut cfg =
        ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(manifest.scenario.comp_len_max));
    cfg.shards = shards;
    // The gate targets the epoll reactor explicitly (the acceptance
    // criterion), whatever CCM_SERVE_REACTOR says for the host suite.
    cfg.reactor = ReactorMode::Epoll;
    cfg.reactors = reactors;
    cfg.max_pending = 100_000;
    cfg.max_conns = 20_000;
    let (ready_tx, ready_rx) = channel();
    let server = std::thread::spawn(move || {
        let factories: Vec<BackendFactory<'static>> = (0..shards)
            .map(|_| {
                let m = Manifest::toy();
                Box::new(move || Ok(Box::new(SimCompute::from_manifest(&m)) as Box<dyn Compute>))
                    as BackendFactory<'static>
            })
            .collect();
        serve_sharded(&Manifest::toy(), factories, cfg, Some(ready_tx))
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).expect("server ready");

    // Phase barriers: (1) all 1024 connections are open before any
    // traffic, (2) every worker finishes its rounds before any conn
    // closes — the full population stays concurrent throughout.
    let barrier = Arc::new(Barrier::new(N_WORKERS));
    let mut handles = Vec::new();
    for w in 0..N_WORKERS {
        let addr = addr.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut clients: Vec<(String, Client)> = (0..CONNS_PER_WORKER)
                .map(|i| (format!("stress-{w}-{i}"), Client::connect(&addr).expect("connect")))
                .collect();
            barrier.wait();
            for round in 1..=ROUNDS {
                for (session, client) in clients.iter_mut() {
                    let ack = client.add_context(session, &[1, 2, 3]).expect("context ack");
                    assert_eq!(
                        ack.get("t").unwrap().i64().unwrap(),
                        round,
                        "{session}: session state must survive across rounds"
                    );
                    let tok = 5 + (round as i32 % 3);
                    let next = client.query(session, &[tok], 3).expect("query reply");
                    assert_eq!(next[0].0, tok, "{session} round {round}: echo rank");
                }
            }
            barrier.wait();
            drop(clients);
            // Churn: short-lived connections creating fresh sessions
            // after the bulk population, to exercise accept/close and
            // session accounting past the steady state.
            for i in 0..CHURN_PER_WORKER {
                let session = format!("churn-{w}-{i}");
                let mut client = Client::connect(&addr).expect("churn connect");
                let next = client.query(&session, &[9], 1).expect("churn query");
                assert_eq!(next[0].0, 9, "{session}");
            }
        }));
    }
    for handle in handles {
        handle.join().expect("stress worker");
    }

    // Zero lost replies: every context/query above got its answer (the
    // workers asserted each), and the counters must balance exactly.
    let n_conns = N_WORKERS * CONNS_PER_WORKER;
    let n_churn = N_WORKERS * CHURN_PER_WORKER;
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(60));
    assert_eq!(stats.get("shards").unwrap().usize().unwrap(), shards);
    assert_eq!(stats.get("sessions").unwrap().usize().unwrap(), n_conns + n_churn);
    assert_eq!(
        stats.get("compressions").unwrap().usize().unwrap(),
        n_conns * ROUNDS as usize,
        "every context chunk must be absorbed"
    );
    assert_eq!(
        stats.get("inferences").unwrap().usize().unwrap(),
        n_conns * ROUNDS as usize + n_churn,
        "every query must execute"
    );
    assert_eq!(
        stats.get("requests").unwrap().usize().unwrap(),
        n_conns * 2 * ROUNDS as usize + n_churn,
        "every request must be admitted exactly once"
    );
    assert_eq!(stats.get("rejected_overload").unwrap().usize().unwrap(), 0);

    // Accept-sharding audit: one stats row per reactor thread, every
    // reactor accepted a share of the population (kernel SO_REUSEPORT
    // hashing or round-robin handoff — either must balance 1000+
    // conns), nothing was refused, and every connection was owned by
    // exactly one reactor.
    let rows = stats.get("per_reactor").unwrap().arr().unwrap();
    assert_eq!(rows.len(), reactors, "per_reactor rows must match CCM_SERVE_REACTORS");
    let mut accepted_total = 0usize;
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.get("reactor").unwrap().usize().unwrap(), i);
        let accepted = row.get("accepted").unwrap().usize().unwrap();
        assert!(accepted > 0, "reactor {i} accepted none of the {n_conns} connections");
        assert_eq!(row.get("refusals").unwrap().usize().unwrap(), 0, "reactor {i}");
        accepted_total += accepted;
    }
    assert_eq!(
        accepted_total,
        n_conns + n_churn + 1, // workers + churn + this admin conn
        "every connection must be owned by exactly one reactor"
    );

    // Session accounting after churn, via the per-session detail view.
    let detailed = admin.stats_detailed().unwrap();
    let list = detailed.get("sessions_detail").unwrap().arr().unwrap();
    assert_eq!(list.len(), n_conns + n_churn);
    let mut stress_sessions = 0usize;
    let mut kv_sum = 0usize;
    for s in list {
        let id = s.get("id").unwrap().str().unwrap();
        let t = s.get("t").unwrap().usize().unwrap();
        let kv = s.get("kv_bytes").unwrap().usize().unwrap();
        kv_sum += kv;
        if id.starts_with("stress-") {
            stress_sessions += 1;
            assert_eq!(t, ROUNDS as usize, "{id}: absorbed chunk count");
            assert!(kv > 0, "{id}: compressed memory resident");
        } else {
            assert!(id.starts_with("churn-"), "unexpected session {id}");
            assert_eq!(t, 0, "{id}: query-only session absorbs no chunks");
        }
    }
    assert_eq!(stress_sessions, n_conns);
    assert_eq!(kv_sum, detailed.get("kv_bytes").unwrap().usize().unwrap());

    admin.shutdown().unwrap();
    server.join().unwrap().unwrap();

    assert_fds_recover(fd_baseline);
}

/// The same 1024-connection population, but across the PROCESS
/// boundary: 2 SimCompute worker processes behind the routing hash,
/// gated on zero lost replies and counter balance, then a mid-stress
/// SIGKILL of one worker that must lose no non-victim replies, respawn
/// with fresh sessions, and increment `shard_restarts` — all without
/// restarting the front-end.
#[test]
fn workers_sustain_1024_connections_and_survive_a_mid_stress_restart() {
    if !stress_enabled() || std::env::var("CCM_STRESS_WORKERS").map(|v| v == "1") != Ok(true) {
        eprintln!(
            "skipping worker stress test: set CCM_STRESS=1 and CCM_STRESS_WORKERS=1 (needs \
             `ulimit -n` >= 4096; run by the CI `stress` workers matrix leg)"
        );
        return;
    }
    if !cfg!(unix) {
        eprintln!("skipping worker stress test: SIGKILL fault injection needs unix");
        return;
    }
    let _gate = STRESS_GATE.lock().unwrap_or_else(|e| e.into_inner());
    let fd_baseline = open_fds();

    let workers = 2usize;
    let reactors = reactors_from_env_strict();
    let server = common::start_worker_server("stress_sim_worker_entry", workers, Vec::new(), |cfg| {
        cfg.reactor = ReactorMode::Epoll;
        cfg.reactors = reactors;
        cfg.max_pending = 100_000;
        cfg.max_conns = 20_000;
    });
    let addr = server.addr().to_string();
    let mut admin = server.client();
    common::wait_workers_up(&mut admin, workers, Duration::from_secs(30));

    // Phase A: the full 1024-connection population, every reply
    // asserted, exactly as for in-process shards.
    let barrier = Arc::new(Barrier::new(N_WORKERS));
    let mut handles = Vec::new();
    for w in 0..N_WORKERS {
        let addr = addr.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut clients: Vec<(String, Client)> = (0..CONNS_PER_WORKER)
                .map(|i| (format!("stress-{w}-{i}"), Client::connect(&addr).expect("connect")))
                .collect();
            barrier.wait();
            for round in 1..=ROUNDS {
                for (session, client) in clients.iter_mut() {
                    let ack = client.add_context(session, &[1, 2, 3]).expect("context ack");
                    assert_eq!(ack.get("t").unwrap().i64().unwrap(), round, "{session}");
                    let tok = 5 + (round as i32 % 3);
                    let next = client.query(session, &[tok], 3).expect("query reply");
                    assert_eq!(next[0].0, tok, "{session} round {round}: echo rank");
                }
            }
            barrier.wait();
        }));
    }
    for handle in handles {
        handle.join().expect("stress client thread");
    }

    let n_conns = N_WORKERS * CONNS_PER_WORKER;
    let stats = wait_drained(&mut admin, Duration::from_secs(60));
    assert_eq!(stats.get("shards").unwrap().usize().unwrap(), workers);
    assert_eq!(stats.get("sessions").unwrap().usize().unwrap(), n_conns);
    assert_eq!(stats.get("compressions").unwrap().usize().unwrap(), n_conns * ROUNDS as usize);
    assert_eq!(
        stats.get("inferences").unwrap().usize().unwrap(),
        n_conns * ROUNDS as usize,
        "every query crossed the IPC boundary and back"
    );
    assert_eq!(
        stats.get("requests").unwrap().usize().unwrap(),
        n_conns * 2 * ROUNDS as usize,
        "every request admitted exactly once across both worker processes"
    );
    assert_eq!(stats.get("rejected_overload").unwrap().usize().unwrap(), 0);
    assert_eq!(stats.get("shard_restarts").unwrap().usize().unwrap(), 0);
    let rows = stats.get("per_reactor").unwrap().arr().unwrap();
    assert_eq!(rows.len(), reactors, "front-end transport rows survive the worker topology");
    let pids = server.note_pids(&stats);
    assert_eq!(pids.len(), workers);
    let victim_pid = pids[0].expect("worker 0 up with a pid");
    for (i, row) in stats.get("per_worker").unwrap().arr().unwrap().iter().enumerate() {
        assert_eq!(row.get("worker").unwrap().usize().unwrap(), i);
        assert_eq!(row.get("up").unwrap(), &Json::Bool(true), "worker {i} must be up");
    }

    // Phase B: continuous non-victim load while worker 0 is SIGKILLed.
    // Every reply on the surviving shard must stay a success — the
    // victim's failure is not allowed to cost anyone else anything.
    let survivor_sessions = ids_on_shard(1, workers, 64);
    let stop = Arc::new(AtomicBool::new(false));
    let survivor_queries = Arc::new(AtomicUsize::new(0));
    let mut burst = Vec::new();
    for chunk in survivor_sessions.chunks(8) {
        let addr = addr.clone();
        let sessions: Vec<String> = chunk.to_vec();
        let stop = stop.clone();
        let survivor_queries = survivor_queries.clone();
        burst.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("survivor connect");
            while !stop.load(Ordering::SeqCst) {
                for session in &sessions {
                    let next = client.query(session, &[7], 1).expect("survivor reply");
                    assert_eq!(next[0].0, 7, "{session}: non-victim reply corrupted");
                    survivor_queries.fetch_add(1, Ordering::SeqCst);
                }
            }
        }));
    }
    // Let the burst actually flow before the kill, so in-flight
    // non-victim traffic brackets the failure.
    poll_until(Duration::from_secs(10), "survivor burst to start", || {
        (survivor_queries.load(Ordering::SeqCst) > 64).then_some(())
    });
    kill9(victim_pid);
    // Respawn: restarts increments, the worker comes back up under a
    // new pid — all while the survivor burst keeps asserting.
    let new_pid = poll_until(Duration::from_secs(30), "worker 0 to respawn", || {
        let stats = admin.stats().expect("stats during restart");
        let pids = server.note_pids(&stats);
        let row = &stats.get("per_worker").unwrap().arr().unwrap()[0];
        let up = row.get("up").unwrap() == &Json::Bool(true);
        let restarts = row.get("restarts").unwrap().usize().unwrap();
        match pids[0] {
            Some(pid) if up && restarts == 1 && pid != victim_pid => Some(pid),
            _ => None,
        }
    });
    assert_ne!(new_pid, victim_pid);
    let mid_burst = survivor_queries.load(Ordering::SeqCst);
    // Keep the burst running a beat past the respawn, then stop it.
    poll_until(Duration::from_secs(10), "survivor burst to continue past the respawn", || {
        (survivor_queries.load(Ordering::SeqCst) > mid_burst + 64).then_some(())
    });
    stop.store(true, Ordering::SeqCst);
    for b in burst {
        b.join().expect("survivor burst thread — a non-victim reply was lost");
    }

    // The respawned worker serves FRESH sessions: a phase-A session on
    // shard 0 restarts at t=1 (its Mem(t) died with the old process).
    let victim_session = (0..N_WORKERS)
        .flat_map(|w| (0..CONNS_PER_WORKER).map(move |i| format!("stress-{w}-{i}")))
        .find(|id| ccm::server::shard_for(id, workers) == 0)
        .expect("some stress session routes to shard 0");
    let t = poll_until(Duration::from_secs(15), "victim shard to serve again", || {
        let mut c = Client::connect(&addr).expect("connect");
        let ack = c.add_context(&victim_session, &[1]).expect("reply");
        if ack.get("ok").unwrap() == &Json::Bool(true) {
            Some(ack.get("t").unwrap().i64().unwrap())
        } else {
            None // shard_unavailable while the respawn completes
        }
    });
    assert_eq!(t, 1, "{victim_session}: respawned worker must start with fresh sessions");

    let stats = wait_drained(&mut admin, Duration::from_secs(60));
    assert_eq!(stats.get("shard_restarts").unwrap().usize().unwrap(), 1);
    drop(admin);
    server.shutdown_join();

    // Port actually released and fds recovered in the front-end
    // process (worker fds died with the workers).
    assert!(std::net::TcpListener::bind(&addr).is_ok(), "port still bound after shutdown");
    assert_fds_recover(fd_baseline);
}

/// The 1024-connection population with hibernation turned all the way
/// up: a 1 ms idle threshold means sessions spill their `Mem(t)` to
/// disk BETWEEN a client's own rounds and rehydrate on the next touch,
/// thousands of times across the run. Gates: every reply asserted (a
/// session that restarted at t=1 instead of resuming fails the round
/// assertion), exact hibernation counter balance on every stats
/// snapshot (`sessions + hibernated_sessions == population`,
/// `spills - rehydrations == hibernated_sessions`), hibernated bytes
/// excluded from the hot KV accounting, zero corrupt snapshots, and
/// the fd gate brackets all spill-file IO (spill/rehydrate must not
/// leak file descriptors any more than sockets).
#[test]
fn hibernation_sustains_1024_connections_with_exact_counter_balance() {
    if !stress_enabled() || std::env::var("CCM_STRESS_HIBERNATE").map(|v| v == "1") != Ok(true) {
        eprintln!(
            "skipping hibernation stress test: set CCM_STRESS=1 and CCM_STRESS_HIBERNATE=1 \
             (needs `ulimit -n` >= 4096; run by the CI `stress` hibernate matrix leg)"
        );
        return;
    }
    let _gate = STRESS_GATE.lock().unwrap_or_else(|e| e.into_inner());
    let fd_baseline = open_fds();

    let root = std::env::temp_dir().join(format!("ccm-stress-hib-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let shards = 4usize;
    let reactors = reactors_from_env_strict();
    let manifest = Manifest::toy();
    let mut cfg =
        ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(manifest.scenario.comp_len_max));
    cfg.shards = shards;
    cfg.reactor = ReactorMode::Epoll;
    cfg.reactors = reactors;
    cfg.max_pending = 100_000;
    cfg.max_conns = 20_000;
    cfg.hibernate_dir = Some(root.clone());
    // Aggressive on purpose: any gap in a session's traffic spills it.
    cfg.hibernate_after = Some(Duration::from_millis(1));
    let (ready_tx, ready_rx) = channel();
    let server = std::thread::spawn(move || {
        let factories: Vec<BackendFactory<'static>> = (0..shards)
            .map(|_| {
                let m = Manifest::toy();
                Box::new(move || Ok(Box::new(SimCompute::from_manifest(&m)) as Box<dyn Compute>))
                    as BackendFactory<'static>
            })
            .collect();
        serve_sharded(&Manifest::toy(), factories, cfg, Some(ready_tx))
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).expect("server ready");

    // Phase A: the full population. Each `t == round` assertion is the
    // resume gate — a session served fresh after a spill would ack t=1.
    let barrier = Arc::new(Barrier::new(N_WORKERS));
    let mut handles = Vec::new();
    for w in 0..N_WORKERS {
        let addr = addr.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut clients: Vec<(String, Client)> = (0..CONNS_PER_WORKER)
                .map(|i| (format!("stress-{w}-{i}"), Client::connect(&addr).expect("connect")))
                .collect();
            barrier.wait();
            for round in 1..=ROUNDS {
                for (session, client) in clients.iter_mut() {
                    let ack = client.add_context(session, &[1, 2, 3]).expect("context ack");
                    assert_eq!(
                        ack.get("t").unwrap().i64().unwrap(),
                        round,
                        "{session}: Mem(t) must resume at its pre-spill time step"
                    );
                    let tok = 5 + (round as i32 % 3);
                    let next = client.query(session, &[tok], 3).expect("query reply");
                    assert_eq!(next[0].0, tok, "{session} round {round}: echo rank");
                }
            }
            barrier.wait();
        }));
    }
    for handle in handles {
        handle.join().expect("hibernation stress worker");
    }

    // Quiesce, then let the idle reaper hibernate the whole population.
    let n_conns = N_WORKERS * CONNS_PER_WORKER;
    let mut admin = Client::connect(&addr).unwrap();
    wait_drained(&mut admin, Duration::from_secs(60));
    let balance = |stats: &Json, what: &str| -> (usize, usize) {
        let sessions = stats.get("sessions").unwrap().usize().unwrap();
        let hibernated = stats.get("hibernated_sessions").unwrap().usize().unwrap();
        let spills = stats.get("spills").unwrap().usize().unwrap();
        let rehydrations = stats.get("rehydrations").unwrap().usize().unwrap();
        assert_eq!(sessions + hibernated, n_conns, "{what}: population must be conserved");
        assert_eq!(
            spills - rehydrations,
            hibernated,
            "{what}: every spill not yet rehydrated must be exactly one hibernated session"
        );
        assert_eq!(
            stats.get("snapshot_corrupt").unwrap().usize().unwrap(),
            0,
            "{what}: healthy traffic must never produce a corrupt snapshot"
        );
        (sessions, hibernated)
    };
    let stats = poll_until(Duration::from_secs(60), "every session to hibernate", || {
        let stats = admin.stats().expect("stats");
        let (_, hibernated) = balance(&stats, "while hibernating");
        (hibernated == n_conns).then_some(stats)
    });
    assert_eq!(stats.get("sessions").unwrap().usize().unwrap(), 0);
    assert_eq!(
        stats.get("kv_bytes").unwrap().usize().unwrap(),
        0,
        "hibernated bytes must leave the hot KV accounting"
    );
    assert!(stats.get("hibernated_bytes").unwrap().usize().unwrap() > 0);
    assert!(
        stats.get("spills").unwrap().usize().unwrap() >= n_conns,
        "each session spilled at least once"
    );
    assert_eq!(stats.get("requests").unwrap().usize().unwrap(), n_conns * 2 * ROUNDS as usize);
    assert_eq!(stats.get("compressions").unwrap().usize().unwrap(), n_conns * ROUNDS as usize);
    assert_eq!(stats.get("inferences").unwrap().usize().unwrap(), n_conns * ROUNDS as usize);
    assert_eq!(stats.get("rejected_overload").unwrap().usize().unwrap(), 0);

    // Phase B: touch every fully-hibernated session once; each must
    // rehydrate from disk and resume exactly where it left off.
    let mut handles = Vec::new();
    for w in 0..N_WORKERS {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("reconnect");
            for i in 0..CONNS_PER_WORKER {
                let session = format!("stress-{w}-{i}");
                let ack = client.add_context(&session, &[4]).expect("post-hibernation ack");
                assert_eq!(
                    ack.get("t").unwrap().i64().unwrap(),
                    ROUNDS + 1,
                    "{session}: rehydrated session must resume at its pre-spill time step"
                );
            }
        }));
    }
    for handle in handles {
        handle.join().expect("rehydration worker");
    }
    poll_until(Duration::from_secs(60), "population to hibernate again", || {
        let stats = admin.stats().expect("stats");
        let (_, hibernated) = balance(&stats, "after rehydration");
        (hibernated == n_conns).then_some(())
    });

    admin.shutdown().unwrap();
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&root);
    assert_fds_recover(fd_baseline);
}

/// fd-leak gate: once every connection is closed and the server has
/// shut down, the process must be back at (about) its baseline fd
/// count. Small slack for test-harness internals; a reactor leaking
/// per-connection fds overshoots by hundreds.
fn assert_fds_recover(baseline: Option<usize>) {
    let Some(baseline) = baseline else { return };
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let now_fds = open_fds().expect("/proc/self/fd");
        if now_fds <= baseline + 16 {
            break;
        }
        assert!(Instant::now() < deadline, "fd leak: {now_fds} open fds vs baseline {baseline}");
        std::thread::sleep(Duration::from_millis(100));
    }
}
