//! CI stress gate for the polling reactor: >= 1024 concurrent
//! connections against a sharded SimCompute server, hard-gating
//! against lost replies, broken session accounting, and fd leaks.
//!
//! Gated behind `CCM_STRESS=1` because it needs a raised fd limit
//! (>= 4096; the default soft limit of 1024 cannot hold 2048 sockets).
//! The CI `stress` job runs it in release with `ulimit -n 65536`:
//!
//! ```bash
//! ulimit -n 65536 && CCM_STRESS=1 cargo test --release --test stress
//! ```

use std::sync::mpsc::channel;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use ccm::compress::{Compute, SimCompute};
use ccm::coordinator::session::SessionPolicy;
use ccm::model::Manifest;
use ccm::server::{serve_sharded, BackendFactory, Client, ReactorMode, ServerConfig};
use ccm::util::json::Json;

const N_WORKERS: usize = 32;
const CONNS_PER_WORKER: usize = 32; // 1024 concurrent connections
const ROUNDS: i64 = 2;
const CHURN_PER_WORKER: usize = 8; // extra short-lived connections

fn open_fds() -> Option<usize> {
    std::fs::read_dir("/proc/self/fd").ok().map(|dir| dir.count())
}

/// Poll stats until no work is queued or in flight.
fn wait_drained(admin: &mut Client, timeout: Duration) -> Json {
    let t0 = Instant::now();
    loop {
        let stats = admin.stats().expect("stats");
        let pending = stats.get("pending").unwrap().usize().unwrap();
        let waiting = stats.get("waiting").unwrap().usize().unwrap();
        if pending == 0 && waiting == 0 {
            return stats;
        }
        assert!(t0.elapsed() < timeout, "server did not drain in {timeout:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn reactor_sustains_1024_connections_without_lost_replies_or_fd_leaks() {
    if std::env::var("CCM_STRESS").map(|v| v == "1") != Ok(true) {
        eprintln!(
            "skipping reactor stress test: set CCM_STRESS=1 (needs `ulimit -n` >= 4096; \
             run by the CI `stress` job)"
        );
        return;
    }
    let fd_baseline = open_fds();

    let shards = 4usize;
    // The CI stress matrix drives the reactor count through 1 and 4
    // via CCM_SERVE_REACTORS; unset defaults to 1. Parsed strictly: a
    // typo'd value must fail the gate loudly, not silently run one
    // reactor while the job claims to cover four.
    let reactors = match std::env::var("CCM_SERVE_REACTORS") {
        Ok(v) => v.parse::<usize>().expect("CCM_SERVE_REACTORS must be a positive integer"),
        Err(_) => 1,
    };
    let manifest = Manifest::toy();
    let mut cfg =
        ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(manifest.scenario.comp_len_max));
    cfg.shards = shards;
    // The gate targets the epoll reactor explicitly (the acceptance
    // criterion), whatever CCM_SERVE_REACTOR says for the host suite.
    cfg.reactor = ReactorMode::Epoll;
    cfg.reactors = reactors;
    cfg.max_pending = 100_000;
    cfg.max_conns = 20_000;
    let (ready_tx, ready_rx) = channel();
    let server = std::thread::spawn(move || {
        let factories: Vec<BackendFactory<'static>> = (0..shards)
            .map(|_| {
                let m = Manifest::toy();
                Box::new(move || Ok(Box::new(SimCompute::from_manifest(&m)) as Box<dyn Compute>))
                    as BackendFactory<'static>
            })
            .collect();
        serve_sharded(&Manifest::toy(), factories, cfg, Some(ready_tx))
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).expect("server ready");

    // Phase barriers: (1) all 1024 connections are open before any
    // traffic, (2) every worker finishes its rounds before any conn
    // closes — the full population stays concurrent throughout.
    let barrier = Arc::new(Barrier::new(N_WORKERS));
    let mut handles = Vec::new();
    for w in 0..N_WORKERS {
        let addr = addr.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut clients: Vec<(String, Client)> = (0..CONNS_PER_WORKER)
                .map(|i| (format!("stress-{w}-{i}"), Client::connect(&addr).expect("connect")))
                .collect();
            barrier.wait();
            for round in 1..=ROUNDS {
                for (session, client) in clients.iter_mut() {
                    let ack = client.add_context(session, &[1, 2, 3]).expect("context ack");
                    assert_eq!(
                        ack.get("t").unwrap().i64().unwrap(),
                        round,
                        "{session}: session state must survive across rounds"
                    );
                    let tok = 5 + (round as i32 % 3);
                    let next = client.query(session, &[tok], 3).expect("query reply");
                    assert_eq!(next[0].0, tok, "{session} round {round}: echo rank");
                }
            }
            barrier.wait();
            drop(clients);
            // Churn: short-lived connections creating fresh sessions
            // after the bulk population, to exercise accept/close and
            // session accounting past the steady state.
            for i in 0..CHURN_PER_WORKER {
                let session = format!("churn-{w}-{i}");
                let mut client = Client::connect(&addr).expect("churn connect");
                let next = client.query(&session, &[9], 1).expect("churn query");
                assert_eq!(next[0].0, 9, "{session}");
            }
        }));
    }
    for handle in handles {
        handle.join().expect("stress worker");
    }

    // Zero lost replies: every context/query above got its answer (the
    // workers asserted each), and the counters must balance exactly.
    let n_conns = N_WORKERS * CONNS_PER_WORKER;
    let n_churn = N_WORKERS * CHURN_PER_WORKER;
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(60));
    assert_eq!(stats.get("shards").unwrap().usize().unwrap(), shards);
    assert_eq!(stats.get("sessions").unwrap().usize().unwrap(), n_conns + n_churn);
    assert_eq!(
        stats.get("compressions").unwrap().usize().unwrap(),
        n_conns * ROUNDS as usize,
        "every context chunk must be absorbed"
    );
    assert_eq!(
        stats.get("inferences").unwrap().usize().unwrap(),
        n_conns * ROUNDS as usize + n_churn,
        "every query must execute"
    );
    assert_eq!(
        stats.get("requests").unwrap().usize().unwrap(),
        n_conns * 2 * ROUNDS as usize + n_churn,
        "every request must be admitted exactly once"
    );
    assert_eq!(stats.get("rejected_overload").unwrap().usize().unwrap(), 0);

    // Accept-sharding audit: one stats row per reactor thread, every
    // reactor accepted a share of the population (kernel SO_REUSEPORT
    // hashing or round-robin handoff — either must balance 1000+
    // conns), nothing was refused, and every connection was owned by
    // exactly one reactor.
    let rows = stats.get("per_reactor").unwrap().arr().unwrap();
    assert_eq!(rows.len(), reactors, "per_reactor rows must match CCM_SERVE_REACTORS");
    let mut accepted_total = 0usize;
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.get("reactor").unwrap().usize().unwrap(), i);
        let accepted = row.get("accepted").unwrap().usize().unwrap();
        assert!(accepted > 0, "reactor {i} accepted none of the {n_conns} connections");
        assert_eq!(row.get("refusals").unwrap().usize().unwrap(), 0, "reactor {i}");
        accepted_total += accepted;
    }
    assert_eq!(
        accepted_total,
        n_conns + n_churn + 1, // workers + churn + this admin conn
        "every connection must be owned by exactly one reactor"
    );

    // Session accounting after churn, via the per-session detail view.
    let detailed = admin.stats_detailed().unwrap();
    let list = detailed.get("sessions_detail").unwrap().arr().unwrap();
    assert_eq!(list.len(), n_conns + n_churn);
    let mut stress_sessions = 0usize;
    let mut kv_sum = 0usize;
    for s in list {
        let id = s.get("id").unwrap().str().unwrap();
        let t = s.get("t").unwrap().usize().unwrap();
        let kv = s.get("kv_bytes").unwrap().usize().unwrap();
        kv_sum += kv;
        if id.starts_with("stress-") {
            stress_sessions += 1;
            assert_eq!(t, ROUNDS as usize, "{id}: absorbed chunk count");
            assert!(kv > 0, "{id}: compressed memory resident");
        } else {
            assert!(id.starts_with("churn-"), "unexpected session {id}");
            assert_eq!(t, 0, "{id}: query-only session absorbs no chunks");
        }
    }
    assert_eq!(stress_sessions, n_conns);
    assert_eq!(kv_sum, detailed.get("kv_bytes").unwrap().usize().unwrap());

    admin.shutdown().unwrap();
    server.join().unwrap().unwrap();

    // fd-leak gate: once every connection is closed and the server has
    // shut down, the process must be back at (about) its baseline fd
    // count. Small slack for test-harness internals; a reactor leaking
    // per-connection fds overshoots by hundreds.
    if let Some(baseline) = fd_baseline {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let now_fds = open_fds().expect("/proc/self/fd");
            if now_fds <= baseline + 16 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "fd leak: {now_fds} open fds vs baseline {baseline}"
            );
            std::thread::sleep(Duration::from_millis(100));
        }
    }
}
