//! Integration: `ccm loadgen` replays a mixed multi-tenant population
//! against a live 2-shard SimCompute server over the real JSON-lines
//! protocol, and the run accounting holds: no lost replies, refusals
//! stay out of the latency pool, per-scenario percentiles are sane,
//! and the sampled quality scorer yields finite ROUGE / memacct
//! numbers. The scenario-by-scenario operator guide for these knobs is
//! docs/SCENARIOS.md.

mod common;

use std::time::Duration;

use ccm::bench::loadgen::{build_plans, drive, LoadSpec, Mix, Workload};
use ccm::compress::StrategyKind;
use ccm::model::Manifest;

fn test_spec() -> LoadSpec {
    LoadSpec {
        users: 24,
        mix: Mix::parse("dialog=1,metaicl=1").expect("mix"),
        rate: 400.0,
        seed: 11,
        churn: 0.2,
        quality_every: 4,
        ramp_secs: 0.1,
        stream_len_max: 8,
        topk: 3,
    }
}

#[test]
fn mixed_population_replay_loses_nothing_and_scores_quality() {
    let server = common::start_sharded(vec![common::sim(), common::sim()], |cfg| {
        cfg.max_batch = 8;
        cfg.max_wait = Duration::from_millis(1);
        cfg.max_pending = 4096;
    });

    let spec = test_spec();
    let summary = drive(&server.addr, &Manifest::toy(), &spec).expect("drive");

    // Open-loop accounting: every scheduled request resolves to exactly
    // one of served / refused / lost, and a healthy server loses none.
    assert_eq!(summary.users, spec.users);
    assert_eq!(summary.total.lost, 0, "lost replies: {:?}", summary.total);
    assert_eq!(summary.total.sent, summary.total.ok + summary.total.refused);
    assert!(summary.total.ok > 0, "nothing served: {:?}", summary.total);

    // The refusal-separation invariant end-to-end: the latency pool
    // holds exactly one sample per SERVED request, never more.
    assert_eq!(summary.total.lat_us.len() as u64, summary.total.ok);

    // Both scenario populations ran, split evenly by the 1:1 mix, with
    // ordered, positive percentile fields wherever requests landed.
    assert_eq!(summary.scenarios.len(), 2);
    let workloads: Vec<Workload> = summary.scenarios.iter().map(|s| s.tenant.workload).collect();
    assert!(workloads.contains(&Workload::Dialog) && workloads.contains(&Workload::MetaIcl));
    for sc in &summary.scenarios {
        assert_eq!(sc.users, spec.users / 2, "{:?} population", sc.tenant);
        assert!(sc.bucket.ok > 0, "{:?} served nothing", sc.tenant);
        let (p50, p99, p999) = (sc.bucket.p_ms(500), sc.bucket.p_ms(990), sc.bucket.p_ms(999));
        assert!(
            p50 > 0.0 && p50 <= p99 && p99 <= p999,
            "{:?} percentiles out of order: p50={p50} p99={p99} p99.9={p999}",
            sc.tenant
        );
    }

    // Sampled sessions were scored live: finite ROUGE in [0,1] and
    // positive memacct byte counts (full-context vs CCM vs live ack).
    let q = &summary.quality;
    assert!(q.samples >= 1, "no quality samples: {q:?}");
    assert!(
        q.rouge_mean.is_finite() && (0.0..=1.0).contains(&q.rouge_mean),
        "rouge_mean {} out of range",
        q.rouge_mean
    );
    assert!(q.kv_full_mean.is_finite() && q.kv_full_mean > 0.0, "kv_full_mean {}", q.kv_full_mean);
    assert!(q.kv_ccm_mean.is_finite() && q.kv_ccm_mean > 0.0, "kv_ccm_mean {}", q.kv_ccm_mean);
    assert!(
        q.kv_ratio_mean.is_finite() && q.kv_ratio_mean > 0.0,
        "kv_ratio_mean {}",
        q.kv_ratio_mean
    );

    server.shutdown_join();
}

#[test]
fn flooding_tier_absorbs_refusals_while_premium_p99_stays_ordered() {
    // The tiered-QoS shape under deliberate overload: a `none`-tier
    // flood (7/8 of the population, offered far over capacity) against
    // a slow single-shard server with a tiny admission queue. The
    // premium `ccm` slice must keep being served with ordered, finite
    // percentiles, while the refusals land overwhelmingly on the
    // flooding tier — overload degrades the flooder, not the tenant
    // next to it.
    let mut sim = common::sim();
    sim.compress_delay = Duration::from_millis(5);
    sim.infer_delay = Duration::from_millis(5);
    let server = common::start_sharded(vec![sim], |cfg| {
        cfg.max_batch = 4;
        cfg.max_wait = Duration::from_millis(1);
        cfg.max_pending = 4;
    });

    let spec = LoadSpec {
        users: 64,
        mix: Mix::parse("dialog@none=7,dialog@ccm=1").expect("mix"),
        rate: 4000.0,
        seed: 23,
        churn: 0.0,
        quality_every: 0,
        ramp_secs: 0.05,
        stream_len_max: 8,
        topk: 3,
    };
    let summary = drive(&server.addr, &Manifest::toy(), &spec).expect("drive");
    assert_eq!(summary.total.lost, 0, "lost replies: {:?}", summary.total);
    assert!(summary.total.refused > 0, "the flood never overloaded the server");

    let tier = |strategy: StrategyKind| {
        summary
            .scenarios
            .iter()
            .find(|s| s.tenant.strategy == Some(strategy))
            .unwrap_or_else(|| panic!("no {} slice in the summary", strategy.name()))
    };
    let premium = tier(StrategyKind::Ccm);
    let flood = tier(StrategyKind::NoCompress);
    assert!(premium.bucket.ok > 0, "premium tier starved: {:?}", premium.bucket);
    let (p50, p99, p999) =
        (premium.bucket.p_ms(500), premium.bucket.p_ms(990), premium.bucket.p_ms(999));
    assert!(
        p50 > 0.0 && p50 <= p99 && p99 <= p999,
        "premium percentiles out of order: p50={p50} p99={p99} p99.9={p999}"
    );
    assert!(flood.bucket.refused > 0, "the flooding tier absorbed no refusals");
    assert!(
        flood.bucket.refused >= premium.bucket.refused,
        "refusals landed on the premium tier: flood={} premium={}",
        flood.bucket.refused,
        premium.bucket.refused
    );

    // Both tiers are live and visible in merged per-strategy stats:
    // the replay's strategy field reached admission, not just the wire.
    let mut admin = server.client();
    let stats = admin.stats().expect("stats");
    let strat = stats.get("strategies").expect("strategies object");
    for name in ["ccm", "none"] {
        let sessions =
            strat.get(name).expect("tier row").get("sessions").expect("sessions").usize().unwrap();
        assert!(sessions > 0, "{name} tier admitted no sessions");
    }
    server.shutdown_join();
}

#[test]
fn replay_plans_are_reproducible_for_a_fixed_spec() {
    // The wire-driving half of the generator is exercised above; the
    // planning half must be a pure function of the spec so runs are
    // comparable across invocations and machines.
    let m = Manifest::toy();
    let spec = test_spec();
    let a = build_plans(&m, &spec).expect("plans");
    let b = build_plans(&m, &spec).expect("plans");
    assert_eq!(a, b);
    assert_eq!(a.len(), spec.users);
    // Quality probes land on every `quality_every`-th user only (and
    // at least one sampled user carries a non-empty probe).
    for plan in &a {
        if plan.quality.is_some() {
            assert_eq!(plan.user % spec.quality_every, 0, "probe off-cadence on u{}", plan.user);
        }
    }
    assert!(a.iter().any(|p| p.quality.is_some()), "no user carries a quality probe");
}
