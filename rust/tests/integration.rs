//! Integration tests over the real AOT artifacts (test config).
//!
//! Requires `make artifacts` (python -m compile.aot --config test).
//! These tests are the cross-layer contract: the Rust coordinator's
//! recurrent online path must match the parallel forward the adapters
//! are trained with, and the training artifacts must optimize.

use ccm::compress::{target_avg_loglik, CompressItem, Engine, InferItem};
use ccm::coordinator::session::SessionPolicy;
use ccm::coordinator::Coordinator;
use ccm::datagen::{by_name, Split};
use ccm::masks::{MergeScheme, Method};
use ccm::memory::MemoryStore;
use ccm::model::Checkpoint;
use ccm::runtime::{Runtime, Value};
use ccm::tensor::{IntTensor, Tensor};
use ccm::training::pack::{pack_batch, PackPolicy};
use ccm::training::Trainer;

/// These tests exercise the real artifact path; without `make artifacts`
/// (or with the offline xla stub) they skip instead of failing, so the
/// tier-1 suite stays green on machines without the XLA runtime. Set
/// CCM_REQUIRE_ARTIFACTS=1 (e.g. in a CI job that built artifacts) to
/// turn a silent skip into a hard failure; `0`, `false`, or empty means
/// "not required" (so CI can pass it explicitly to document intent).
fn artifacts_required() -> bool {
    match std::env::var("CCM_REQUIRE_ARTIFACTS") {
        Ok(v) => !matches!(v.as_str(), "" | "0" | "false"),
        Err(_) => false,
    }
}

fn runtime() -> Option<Runtime> {
    match Runtime::from_config("test") {
        Ok(rt) => Some(rt),
        Err(e) => {
            if artifacts_required() {
                panic!("CCM_REQUIRE_ARTIFACTS set but artifacts unavailable: {e:#}");
            }
            eprintln!("skipping artifact test: {e:#} (run `make artifacts` + real xla crate)");
            None
        }
    }
}

/// A briefly-pretrained base checkpoint shared across tests (compression
/// training needs a non-random base to have signal, as in the paper's
/// recipe: dataset fine-tune first, then adapter training).
fn pretrained_ck() -> Option<&'static Checkpoint> {
    static CK: std::sync::OnceLock<Option<Checkpoint>> = std::sync::OnceLock::new();
    CK.get_or_init(|| {
        let rt = runtime()?;
        let mut ck = Checkpoint::init(&rt.manifest, 1);
        let trainer = Trainer::new(&rt);
        let mixture = ccm::datagen::corpus::Mixture::parse("metaicl+dialog");
        trainer.pretrain_lm(&mut ck, &mixture, 80, 3e-3, 5).expect("pretrain");
        Some(ck)
    })
    .as_ref()
}

#[test]
fn mask_goldens_match_python() {
    let Some(rt) = runtime() else { return };
    let n = ccm::masks::verify_goldens(&rt.manifest.mask_goldens).unwrap();
    assert!(n >= 12, "expected a full golden suite, got {n}");
}

#[test]
fn every_artifact_compiles_and_shapes_check() {
    let Some(rt) = runtime() else { return };
    let names: Vec<String> = rt.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
    for n in &names {
        rt.executable(n).unwrap_or_else(|e| panic!("compile {n}: {e:#}"));
    }
}

/// The core cross-layer test: online recursion (compress_chunk +
/// infer_with_mem staged by the Rust engine) must reproduce the parallel
/// forward's logits at the input positions — Rust-side mirror of
/// python/tests/test_model.py::test_parallel_equals_recurrent.
#[test]
fn recurrent_engine_matches_parallel_forward() {
    let Some(rt) = runtime() else { return };
    let ck = Checkpoint::init(&rt.manifest, 42);
    let sc = &rt.manifest.scenario;
    let ds = by_name("metaicl", 7, sc, rt.manifest.model.vocab).unwrap();
    let sample = ds.sample(Split::Test, 1, 3);
    let comp_len = sc.comp_len_max;

    for (method, scheme) in [
        (Method::CcmConcat, MergeScheme::Avg),
        (Method::CcmMerge, MergeScheme::Avg),
        (Method::CcmMerge, MergeScheme::Ema(0.5)),
    ] {
        // Parallel path.
        let mut policy = PackPolicy::new(method, comp_len);
        policy.scheme = scheme;
        let row = ccm::training::pack::pack_row(&policy, sc, &sample, None).unwrap();
        let batch = pack_batch(&policy, &rt.manifest, &[(&sample, None)], 1).unwrap();
        let nb = rt.manifest.base_layout.total;
        let nl = rt.manifest.lora_layout.total;
        let outs = rt
            .execute_f32(
                "ccm_forward_b1",
                &[
                    Value::vec_f32(&[nb], ck.base.data.clone()).unwrap(),
                    Value::vec_f32(&[nl], ck.lora.data.clone()).unwrap(),
                    Value::I32(batch.tokens),
                    Value::I32(batch.comp_slot),
                    Value::F32(batch.gate),
                    Value::I32(batch.pos),
                    Value::F32(batch.mask),
                    Value::F32(batch.merge_p),
                ],
            )
            .unwrap();
        let par = &outs[0]; // [1, S, V]

        // Recurrent path via the engine.
        let engine = Engine::new(&rt, &ck, comp_len).unwrap();
        let m = &rt.manifest.model;
        let mut mem = match method {
            Method::CcmMerge => {
                MemoryStore::merge(m.n_layers, sc.mem_slots, m.d_model, comp_len, scheme)
            }
            _ => MemoryStore::concat(m.n_layers, sc.mem_slots, m.d_model, comp_len),
        };
        let mut pos = 0usize;
        for c in &sample.chunks {
            let item = CompressItem { mem: &mem, chunk: c, pos_start: pos };
            let h = engine.compress(std::slice::from_ref(&item)).unwrap().remove(0);
            mem.update(&h).unwrap();
            pos += c.len() + comp_len;
        }
        let it = sample.input_with_target();
        let item = InferItem { mem: &mem, tokens: &it, pos_start: pos };
        let rec = &engine.infer(std::slice::from_ref(&item)).unwrap()[0]; // [Si, V]

        // Compare logits at the input positions.
        let v = rt.manifest.model.vocab;
        let input_start = row.layout.input_start();
        let mut max_diff = 0f32;
        for i in 0..it.len() {
            for t in 0..v {
                let a = par.get(&[0, input_start + i, t]);
                let b = rec.get(&[i, t]);
                max_diff = max_diff.max((a - b).abs());
            }
        }
        assert!(
            max_diff < 2e-3,
            "{method:?}/{scheme:?}: parallel vs recurrent logits diverge by {max_diff}"
        );
    }
}

#[test]
fn lm_training_reduces_loss() {
    // Uses the shared pretrained checkpoint's training trajectory.
    let Some(rt) = runtime() else { return };
    let mut ck = Checkpoint::init(&rt.manifest, 1);
    let trainer = Trainer::new(&rt);
    let mixture = ccm::datagen::corpus::Mixture::parse("metaicl+dialog");
    let report = trainer.pretrain_lm(&mut ck, &mixture, 60, 3e-3, 5).unwrap();
    let first: f32 = report.losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = report.losses[report.losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(
        last < first - 0.4,
        "LM loss should drop by >0.4 nats in 60 steps: {first} -> {last}"
    );
}

#[test]
fn ccm_training_reduces_loss_and_is_faster_than_rmt() {
    let Some(rt) = runtime() else { return };
    let Some(ck0) = pretrained_ck() else { return };
    let mut ck = ck0.clone();
    let trainer = Trainer::new(&rt);
    let mixture = ccm::datagen::corpus::Mixture::parse("metaicl");
    let policy = PackPolicy::new(Method::CcmConcat, rt.manifest.scenario.comp_len_max);
    // Loss-decrease on held-out batches is noisy at test scale (the
    // rigorous fixed-batch decrease test lives in python tests); here we
    // train longer and compare first/last deciles.
    let ccm_rep = trainer.train_ccm(&mut ck, &policy, &mixture, 60, 2e-2, 3).unwrap();
    let first: f32 = ccm_rep.losses[..10].iter().sum::<f32>() / 10.0;
    let last: f32 = ccm_rep.losses[ccm_rep.losses.len() - 10..].iter().sum::<f32>() / 10.0;
    assert!(
        last < first,
        "ccm loss should decrease on a pretrained base: {first} -> {last} ({:?})",
        ccm_rep.losses
    );
    let mut ck2 = ck0.clone();
    let rmt_rep = trainer.train_rmt(&mut ck2, &mixture, 12, 3e-3, 3).unwrap();
    assert!(
        rmt_rep.losses.iter().all(|l| l.is_finite()),
        "rmt losses finite: {:?}",
        rmt_rep.losses
    );
    // Table 8's structural claim: recurrent training costs more per
    // sample than the parallelized forward (even at tiny scale the
    // sequential unroll pays R+1 forwards).
    assert!(
        rmt_rep.ms_per_sample > ccm_rep.ms_per_sample,
        "rmt {:.2} ms/sample should exceed ccm {:.2} ms/sample",
        rmt_rep.ms_per_sample,
        ccm_rep.ms_per_sample
    );
}

#[test]
fn coordinator_end_to_end_batched_sessions() {
    let Some(rt) = runtime() else { return };
    let ck = Checkpoint::init(&rt.manifest, 4);
    let mut coord = Coordinator::new(
        &rt,
        &ck,
        SessionPolicy::concat(rt.manifest.scenario.comp_len_max),
        4,
        std::time::Duration::ZERO,
    )
    .unwrap();
    let sc = &rt.manifest.scenario;
    let ds = by_name("lamp", 9, sc, rt.manifest.model.vocab).unwrap();
    let mut seqs = Vec::new();
    for id in 0..3 {
        let s = ds.sample(Split::Test, id, 2);
        let sess = format!("user{id}");
        for c in &s.chunks {
            coord.add_context(&sess, c.clone());
        }
        let seq = coord.query(&sess, s.input_with_target());
        seqs.push((seq, s));
    }
    coord.run_until_idle().unwrap();
    for (seq, s) in seqs {
        let logits = coord.take_result(seq).expect("query result");
        let ll = target_avg_loglik(&logits, s.input.len(), &s.target);
        assert!(ll.is_finite() && ll < 0.0, "loglik {ll}");
    }
    assert_eq!(coord.metrics.compressions, 6);
    assert_eq!(coord.metrics.inferences, 3);
    assert!(coord.metrics.mean_batch_size() > 1.0, "batching must group sessions");
    assert!(coord.sessions.total_kv_bytes() > 0);
}

#[test]
fn decode_step_streams_tokens() {
    let Some(rt) = runtime() else { return };
    let ck = Checkpoint::init(&rt.manifest, 5);
    let m = &rt.manifest.model;
    let sc = &rt.manifest.scenario;
    let (l, d, mm, cc) = (m.n_layers, m.d_model, sc.mem_slots, sc.decode_cache);
    let nb = rt.manifest.base_layout.total;
    let nl = rt.manifest.lora_layout.total;
    let mut cache_k = Tensor::zeros(&[1, l, cc, d]);
    let mut cache_v = Tensor::zeros(&[1, l, cc, d]);
    let toks = [5i32, 6, 7, 8];
    let mut last = Vec::new();
    for (i, &t) in toks.iter().enumerate() {
        let outs = rt
            .execute_f32(
                "decode_step",
                &[
                    Value::vec_f32(&[nb], ck.base.data.clone()).unwrap(),
                    Value::vec_f32(&[nl], ck.lora.data.clone()).unwrap(),
                    Value::F32(Tensor::zeros(&[1, l, mm, d])),
                    Value::F32(Tensor::zeros(&[1, l, mm, d])),
                    Value::I32(IntTensor::from_vec(&[1], vec![0]).unwrap()),
                    Value::F32(cache_k.clone()),
                    Value::F32(cache_v.clone()),
                    Value::scalar_i32(i as i32),
                    Value::I32(IntTensor::from_vec(&[1], vec![t]).unwrap()),
                    Value::I32(IntTensor::from_vec(&[1], vec![i as i32]).unwrap()),
                ],
            )
            .unwrap();
        assert_eq!(outs[0].shape, vec![1, m.vocab]);
        cache_k = outs[1].clone();
        cache_v = outs[2].clone();
        last = outs[0].data.clone();
    }
    assert!(last.iter().all(|x| x.is_finite()));
    // The cache must contain non-zero KV at the written positions.
    assert!(cache_k.data.iter().any(|&x| x != 0.0));
}

#[test]
fn pallas_forward_artifact_matches_jnp_forward() {
    let Some(rt) = runtime() else { return };
    let ck = Checkpoint::init(&rt.manifest, 6);
    let sc = &rt.manifest.scenario;
    let ds = by_name("metaicl", 11, sc, rt.manifest.model.vocab).unwrap();
    let sample = ds.sample(Split::Test, 0, 2);
    let policy = PackPolicy::new(Method::CcmConcat, sc.comp_len_max);
    let batch = pack_batch(&policy, &rt.manifest, &[(&sample, None)], 1).unwrap();
    let nb = rt.manifest.base_layout.total;
    let nl = rt.manifest.lora_layout.total;
    let inputs = |b: &ccm::training::pack::PackedBatch| {
        vec![
            Value::vec_f32(&[nb], ck.base.data.clone()).unwrap(),
            Value::vec_f32(&[nl], ck.lora.data.clone()).unwrap(),
            Value::I32(b.tokens.clone()),
            Value::I32(b.comp_slot.clone()),
            Value::F32(b.gate.clone()),
            Value::I32(b.pos.clone()),
            Value::F32(b.mask.clone()),
            Value::F32(b.merge_p.clone()),
        ]
    };
    let jnp = rt.execute_f32("ccm_forward_b1", &inputs(&batch)).unwrap();
    let pal = rt.execute_f32("ccm_forward_pallas_b1", &inputs(&batch)).unwrap();
    let max_diff = jnp[0]
        .data
        .iter()
        .zip(&pal[0].data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 5e-3, "pallas vs jnp forward diverge: {max_diff}");
}
