//! Shared test support for the serving integration suites (serve.rs,
//! workers.rs, stress.rs): server guards with drop-kill, deadline-
//! polling waits (never bare sleeps for readiness), reply assertion
//! helpers, and the re-exec machinery that turns the host test binary
//! into a SimCompute worker process for the cross-process topology.
//!
//! Compiled separately into each test binary, so not every helper is
//! used everywhere — hence the file-level `dead_code` allowance.

#![allow(dead_code)]

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use ccm::compress::{Compute, SimCompute};
use ccm::coordinator::session::SessionPolicy;
use ccm::model::Manifest;
use ccm::server::{
    serve_sharded, serve_with_backend, serve_workers, shard_for, BackendFactory, Client,
    ServerConfig, WorkerMode,
};
use ccm::util::json::Json;

// ---------------------------------------------------------------------
// Deadline polling (flake-proof waits).

/// Poll `f` every few milliseconds until it yields a value; panic with
/// `what` once `timeout` elapses. The replacement for ad-hoc sleeps:
/// waits exactly as long as needed and fails loudly instead of flaking.
pub fn poll_until<T>(timeout: Duration, what: &str, mut f: impl FnMut() -> Option<T>) -> T {
    let deadline = Instant::now() + timeout;
    loop {
        if let Some(v) = f() {
            return v;
        }
        assert!(Instant::now() < deadline, "timed out after {timeout:?} waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Poll merged stats until every worker's `per_worker` row is `up`.
/// The serve `ready` signal fires when the FRONT-END port is bound —
/// workers may still be spawning, and requests racing their startup
/// get `shard_unavailable` by design — so worker-topology tests gate
/// on this before asserting replies.
pub fn wait_workers_up(admin: &mut Client, workers: usize, timeout: Duration) -> Json {
    poll_until(timeout, "all workers to come up", || {
        let stats = admin.stats().expect("stats");
        let up = match stats.opt("per_worker").and_then(|v| v.arr().ok()) {
            Some(rows) => {
                rows.len() == workers && rows.iter().all(|r| r.opt("up") == Some(&Json::Bool(true)))
            }
            None => false,
        };
        up.then_some(stats)
    })
}

/// Poll stats until no work is queued or in flight; returns the final
/// stats object.
pub fn wait_drained(admin: &mut Client, timeout: Duration) -> Json {
    poll_until(timeout, "server to drain", || {
        let stats = admin.stats().expect("stats");
        let pending = stats.get("pending").unwrap().usize().unwrap();
        let waiting = stats.get("waiting").unwrap().usize().unwrap();
        (pending == 0 && waiting == 0).then_some(stats)
    })
}

// ---------------------------------------------------------------------
// Reply assertion helpers.

pub fn assert_ok(resp: &Json) {
    assert_eq!(resp.get("ok").unwrap(), &Json::Bool(true), "expected ok reply: {resp}");
}

pub fn assert_error(resp: &Json, code: &str) {
    assert_eq!(resp.get("ok").unwrap(), &Json::Bool(false), "expected {code} refusal: {resp}");
    assert_eq!(resp.get("error").unwrap().str().unwrap(), code, "wrong refusal: {resp}");
}

pub fn top1(next: &[(i32, f32)]) -> i32 {
    next[0].0
}

// ---------------------------------------------------------------------
// Backends and routing fixtures.

pub fn sim() -> SimCompute {
    SimCompute::from_manifest(&Manifest::toy())
}

/// Compressed-KV bytes one absorbed chunk costs a session (derived
/// from the shared toy manifest: 2 buffers x layers x comp_len x
/// d_model x 4 bytes).
pub fn kv_per_chunk() -> usize {
    let m = Manifest::toy();
    2 * m.model.n_layers * m.scenario.comp_len_max * m.model.d_model * 4
}

/// The first `n` ids of the form `s<i>` that route to `shard`.
pub fn ids_on_shard(shard: usize, shards: usize, n: usize) -> Vec<String> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while out.len() < n {
        let id = format!("s{i}");
        if shard_for(&id, shards) == shard {
            out.push(id);
        }
        i += 1;
    }
    out
}

// ---------------------------------------------------------------------
// Server guards.

/// A serve thread under test. On clean paths call [`shutdown_join`] /
/// [`join`]; if the test panics first, `Drop` best-effort shuts the
/// server down over a raw socket (with timeouts, without joining) so a
/// failed test cannot leave the server — or its worker processes —
/// running behind it.
///
/// [`shutdown_join`]: ServerHandle::shutdown_join
/// [`join`]: ServerHandle::join
pub struct ServerHandle {
    pub addr: String,
    handle: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
    finished: bool,
}

impl ServerHandle {
    pub fn new(addr: String, handle: std::thread::JoinHandle<anyhow::Result<()>>) -> ServerHandle {
        ServerHandle { addr, handle: Some(handle), finished: false }
    }

    pub fn client(&self) -> Client {
        Client::connect(&self.addr).expect("connect")
    }

    /// Issue a shutdown on a fresh connection, then join the serve
    /// thread and unwrap its result.
    pub fn shutdown_join(mut self) {
        let mut admin = self.client();
        admin.shutdown().expect("shutdown ack");
        self.finish();
    }

    /// Join after a shutdown was already acknowledged through some
    /// client the test drove itself.
    pub fn join(mut self) {
        self.finish();
    }

    fn finish(&mut self) {
        self.finished = true;
        self.handle
            .take()
            .expect("server already joined")
            .join()
            .expect("server thread")
            .expect("server result");
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        if !self.finished {
            best_effort_shutdown(&self.addr);
        }
    }
}

/// Best-effort shutdown over a raw socket: bounded by read/write
/// timeouts, never joins anything, safe from `Drop` during a panic.
pub fn best_effort_shutdown(addr: &str) {
    if let Ok(mut stream) = TcpStream::connect(addr) {
        let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
        let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
        let _ = stream.write_all(b"{\"op\":\"shutdown\"}\n");
        let mut ack = [0u8; 256];
        let _ = stream.read(&mut ack);
    }
}

/// Start a single-executor server over SimCompute.
pub fn start_server(sim: SimCompute, tune: impl FnOnce(&mut ServerConfig)) -> ServerHandle {
    let m = Manifest::toy();
    let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(m.scenario.comp_len_max));
    tune(&mut cfg);
    let (ready_tx, ready_rx) = channel();
    let handle =
        std::thread::spawn(move || serve_with_backend(&m, Box::new(sim), cfg, Some(ready_tx)));
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).expect("server ready");
    ServerHandle::new(addr, handle)
}

/// Start an N-shard in-process server, one SimCompute per shard
/// (sims[i] becomes shard i's backend).
pub fn start_sharded(sims: Vec<SimCompute>, tune: impl FnOnce(&mut ServerConfig)) -> ServerHandle {
    let m = Manifest::toy();
    let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(m.scenario.comp_len_max));
    cfg.shards = sims.len();
    tune(&mut cfg);
    let (ready_tx, ready_rx) = channel();
    let handle = std::thread::spawn(move || {
        let factories: Vec<BackendFactory<'static>> = sims
            .into_iter()
            .map(|sim| {
                Box::new(move || Ok(Box::new(sim) as Box<dyn Compute>)) as BackendFactory<'static>
            })
            .collect();
        serve_sharded(&m, factories, cfg, Some(ready_tx))
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).expect("server ready");
    ServerHandle::new(addr, handle)
}

// ---------------------------------------------------------------------
// Worker-process topology support (re-exec of the test binary).

/// Env var that flips the re-exec'd test binary into worker mode.
pub const SIM_WORKER_ENV: &str = "CCM_TEST_SIM_WORKER";

/// Body of each test binary's worker entry `#[test]`: when the worker
/// env is set (only in processes spawned by [`sim_worker_mode`]), run a
/// SimCompute worker and exit the process; otherwise return and let the
/// entry pass as an empty test.
pub fn sim_worker_entry_if_requested() {
    if std::env::var(SIM_WORKER_ENV).as_deref() != Ok("1") {
        return;
    }
    let env_u64 = |key: &str, default: u64| -> u64 {
        std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
    };
    let m = Manifest::toy();
    let shard = env_u64("CCM_TEST_WORKER_SHARD", 0) as usize;
    let shards = (env_u64("CCM_TEST_WORKER_SHARDS", 1) as usize).max(1);
    let mut sim = SimCompute::from_manifest(&m);
    sim.compress_delay = Duration::from_millis(env_u64("CCM_TEST_WORKER_COMPRESS_MS", 0));
    sim.infer_delay = Duration::from_millis(env_u64("CCM_TEST_WORKER_INFER_MS", 0));
    let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(m.scenario.comp_len_max));
    cfg.shards = shards;
    cfg.max_pending = env_u64("CCM_TEST_WORKER_MAX_PENDING", 100_000) as usize;
    let kv_budget = env_u64("CCM_TEST_WORKER_KV_BUDGET", 0) as usize;
    if kv_budget > 0 {
        cfg.kv_budget_bytes = Some(kv_budget);
    }
    // Tiered-memory knobs: a hibernate root turns on spill-to-disk in
    // the worker's executor; the threshold and the orphan grace are in
    // milliseconds so tests can use aggressive values.
    if let Ok(dir) = std::env::var("CCM_TEST_WORKER_HIBERNATE_DIR") {
        if !dir.is_empty() {
            cfg.hibernate_dir = Some(std::path::PathBuf::from(dir));
            cfg.hibernate_after =
                Some(Duration::from_millis(env_u64("CCM_TEST_WORKER_HIBERNATE_AFTER_MS", 50)));
        }
    }
    if let Ok(ms) = std::env::var("CCM_TEST_WORKER_ORPHAN_GRACE_MS") {
        if let Ok(ms) = ms.parse::<u64>() {
            cfg.orphan_grace = Duration::from_millis(ms);
        }
    }
    let factory: BackendFactory<'static> = Box::new(move || Ok(Box::new(sim) as Box<dyn Compute>));
    let code = match ccm::server::run_worker(&m, factory, cfg, shard, None) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("sim worker failed: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

/// Spawn-mode [`WorkerMode`] whose launcher re-execs THIS test binary,
/// filtered down to `entry` (the worker entry `#[test]` of the calling
/// binary) with `--nocapture` so the ready handshake reaches stdout.
/// `per_shard_env` lets a test give individual workers different knobs
/// (e.g. a slow backend on the victim shard only).
pub fn sim_worker_mode(
    entry: &'static str,
    shards: usize,
    per_shard_env: Vec<Vec<(String, String)>>,
) -> WorkerMode {
    WorkerMode::Spawn {
        count: shards,
        launcher: Box::new(move |shard| {
            let exe = std::env::current_exe().expect("current_exe");
            let mut cmd = std::process::Command::new(exe);
            cmd.args([entry, "--exact", "--nocapture"]);
            cmd.env(SIM_WORKER_ENV, "1")
                .env("CCM_TEST_WORKER_SHARD", shard.to_string())
                .env("CCM_TEST_WORKER_SHARDS", shards.to_string());
            if let Some(envs) = per_shard_env.get(shard) {
                for (k, v) in envs {
                    cmd.env(k, v);
                }
            }
            cmd
        }),
    }
}

/// A worker-topology server under test: the [`ServerHandle`] guard plus
/// a record of every worker pid observed through stats, SIGKILLed as a
/// backstop if the test dies before a clean shutdown (worker processes
/// outlive the test process otherwise — the one leak a thread guard
/// cannot catch).
pub struct WorkerServer {
    server: Option<ServerHandle>,
    pids: Mutex<Vec<u32>>,
}

impl WorkerServer {
    pub fn addr(&self) -> &str {
        &self.server.as_ref().expect("server live").addr
    }

    pub fn client(&self) -> Client {
        Client::connect(self.addr()).expect("connect")
    }

    /// Record every pid in a stats object's `per_worker` rows (so the
    /// drop backstop knows who to kill) and return the per-worker pids
    /// in shard order (`None` while a worker is down).
    pub fn note_pids(&self, stats: &Json) -> Vec<Option<u32>> {
        let rows = stats.get("per_worker").expect("per_worker rows").arr().expect("array");
        let mut recorded = self.pids.lock().unwrap();
        rows.iter()
            .map(|row| {
                let pid = row.opt("pid").and_then(|v| v.usize().ok()).map(|p| p as u32);
                if let Some(p) = pid {
                    if !recorded.contains(&p) {
                        recorded.push(p);
                    }
                }
                pid
            })
            .collect()
    }

    pub fn shutdown_join(mut self) {
        self.server.take().expect("server live").shutdown_join();
    }

    /// Join after a shutdown was already acknowledged through some
    /// client the test drove itself.
    pub fn join(mut self) {
        self.server.take().expect("server live").join();
    }
}

impl Drop for WorkerServer {
    fn drop(&mut self) {
        let Some(server) = self.server.as_mut() else { return };
        if server.finished {
            return;
        }
        best_effort_shutdown(&server.addr);
        server.finished = true; // suppress the inner guard's second attempt
        // Give cleanly-shut workers a moment to exit, then SIGKILL
        // whatever is left of the ones we saw.
        std::thread::sleep(Duration::from_millis(300));
        for pid in self.pids.lock().unwrap().drain(..) {
            if process_alive(pid) {
                kill9(pid);
            }
        }
    }
}

/// Start a worker-topology server: `shards` SimCompute workers spawned
/// by re-exec'ing this test binary through its `entry` test.
pub fn start_worker_server(
    entry: &'static str,
    shards: usize,
    per_shard_env: Vec<Vec<(String, String)>>,
    tune: impl FnOnce(&mut ServerConfig),
) -> WorkerServer {
    let m = Manifest::toy();
    let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(m.scenario.comp_len_max));
    tune(&mut cfg);
    let mode = sim_worker_mode(entry, shards, per_shard_env);
    let (ready_tx, ready_rx) = channel();
    let handle = std::thread::spawn(move || serve_workers(cfg, mode, Some(ready_tx)));
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).expect("server ready");
    WorkerServer { server: Some(ServerHandle::new(addr, handle)), pids: Mutex::new(Vec::new()) }
}

/// Kill-on-drop wrapper for worker processes a test spawns itself
/// (SIGKILL is a no-op once the child has exited cleanly).
pub struct ChildGuard(pub std::process::Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

impl ChildGuard {
    /// Deadline-poll the child's exit and assert it succeeded.
    pub fn wait_success(&mut self, timeout: Duration, what: &str) {
        let status = poll_until(timeout, what, || self.0.try_wait().expect("try_wait"));
        assert!(status.success(), "{what}: worker exited with {status:?}");
    }
}

/// Spawn a raw SimCompute worker process (no supervisor) by re-exec'ing
/// this test binary, and read its ready handshake: the fixture for
/// `--worker-addr` connect-mode tests. Stdout keeps draining on a
/// helper thread so the child never blocks on the pipe.
pub fn spawn_raw_sim_worker(entry: &str, shard: usize, shards: usize) -> (ChildGuard, String) {
    use std::io::BufRead;
    let exe = std::env::current_exe().expect("current_exe");
    let mut cmd = std::process::Command::new(exe);
    cmd.args([entry, "--exact", "--nocapture"])
        .env(SIM_WORKER_ENV, "1")
        .env("CCM_TEST_WORKER_SHARD", shard.to_string())
        .env("CCM_TEST_WORKER_SHARDS", shards.to_string())
        .stdin(std::process::Stdio::null())
        .stdout(std::process::Stdio::piped());
    let mut child = cmd.spawn().expect("spawn raw worker");
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = std::io::BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("worker stdout");
        assert!(n > 0, "worker exited before its ready handshake");
        if let Some(addr) = line.trim().strip_prefix(ccm::server::WORKER_READY_PREFIX) {
            break addr.trim().to_string();
        }
    };
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
            sink.clear();
        }
    });
    (ChildGuard(child), addr)
}

// ---------------------------------------------------------------------
// Unix process helpers (fault injection).

#[cfg(unix)]
pub fn kill9(pid: u32) {
    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    // SAFETY: plain FFI call with scalar arguments; worst case the pid
    // is already gone and the syscall returns ESRCH.
    unsafe {
        kill(pid as i32, 9);
    }
}

/// True while `pid` exists (signal 0 probe).
#[cfg(unix)]
pub fn process_alive(pid: u32) -> bool {
    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    // SAFETY: plain FFI call with scalar arguments; signal 0 performs
    // only the existence/permission check, delivering nothing.
    unsafe { kill(pid as i32, 0) == 0 }
}

#[cfg(not(unix))]
pub fn kill9(_pid: u32) {}

#[cfg(not(unix))]
pub fn process_alive(_pid: u32) -> bool {
    false
}
