//! Protocol-level integration tests for the pipelined serving engine.
//!
//! These run the full TCP serve path (acceptor, connection threads,
//! executor pump, admission control, memory governance) over the
//! deterministic `SimCompute` backend, so they need no AOT artifacts
//! and no XLA — they test the serving system, not the model.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use ccm::compress::SimCompute;
use ccm::coordinator::session::SessionPolicy;
use ccm::model::Manifest;
use ccm::server::{serve_with_backend, Client, ServerConfig};
use ccm::util::json::Json;

/// Compressed-KV bytes one absorbed chunk costs a session (derived
/// from the shared toy manifest: 2 buffers x layers x comp_len x
/// d_model x 4 bytes).
fn kv_per_chunk() -> usize {
    let m = Manifest::toy();
    2 * m.model.n_layers * m.scenario.comp_len_max * m.model.d_model * 4
}

/// Start a server over SimCompute; returns (addr, join handle).
fn start_server(
    sim: SimCompute,
    tune: impl FnOnce(&mut ServerConfig),
) -> (String, std::thread::JoinHandle<anyhow::Result<()>>) {
    let m = Manifest::toy();
    let mut cfg =
        ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(m.scenario.comp_len_max));
    tune(&mut cfg);
    let (ready_tx, ready_rx) = channel();
    let handle = std::thread::spawn(move || {
        serve_with_backend(&m, Box::new(sim), cfg, Some(ready_tx))
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).expect("server ready");
    (addr, handle)
}

fn sim() -> SimCompute {
    SimCompute::from_manifest(&Manifest::toy())
}

/// Poll stats until no work is queued or in flight.
fn wait_drained(admin: &mut Client, timeout: Duration) -> Json {
    let t0 = Instant::now();
    loop {
        let stats = admin.stats().expect("stats");
        let pending = stats.get("pending").unwrap().usize().unwrap();
        let waiting = stats.get("waiting").unwrap().usize().unwrap();
        if pending == 0 && waiting == 0 {
            return stats;
        }
        assert!(t0.elapsed() < timeout, "server did not drain in {timeout:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn top1(next: &[(i32, f32)]) -> i32 {
    next[0].0
}

#[test]
fn concurrent_clients_interleave_context_and_query() {
    let (addr, server) = start_server(sim(), |_| {});
    let n_clients = 4;
    let rounds = 3;
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let session = format!("user{c}");
            for round in 1..=rounds {
                let chunk = [10 + c, 11 + c, 12 + c];
                let ack = client.add_context(&session, &chunk).unwrap();
                // The ack reports the step this chunk lands on.
                assert_eq!(ack.get("t").unwrap().i64().unwrap(), round as i64, "{session}");
                let q = 20 + c;
                let next = client.query(&session, &[q], 3).unwrap();
                assert_eq!(top1(&next), q, "echo backend must rank the token first");
                assert!(next.iter().all(|(_, lp)| *lp <= 0.0), "logprobs <= 0");
            }
        }));
    }
    for h in handles {
        h.join().expect("client thread");
    }
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(5));
    assert_eq!(stats.get("sessions").unwrap().usize().unwrap(), n_clients as usize);
    assert_eq!(
        stats.get("compressions").unwrap().usize().unwrap(),
        n_clients as usize * rounds as usize
    );
    assert_eq!(
        stats.get("inferences").unwrap().usize().unwrap(),
        n_clients as usize * rounds as usize
    );
    admin.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn pipelined_context_acks_report_distinct_steps() {
    // Regression for the seed bug: two context chunks queued together
    // both acked t+1. Write both lines before reading any reply.
    let (addr, server) = start_server(sim(), |_| {});
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writer
        .write_all(
            b"{\"op\":\"context\",\"session\":\"u\",\"tokens\":[4,5]}\n\
              {\"op\":\"context\",\"session\":\"u\",\"tokens\":[6,7]}\n",
        )
        .unwrap();
    let mut ts = Vec::new();
    for _ in 0..2 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").unwrap(), &Json::Bool(true));
        ts.push(j.get("t").unwrap().i64().unwrap());
    }
    assert_eq!(ts, vec![1, 2], "acks must report the actual queued steps");
    let mut admin = Client::connect(&addr).unwrap();
    wait_drained(&mut admin, Duration::from_secs(5));
    admin.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn overload_refuses_then_recovers() {
    // One pending slot, 200 ms per compress batch: of 10 simultaneous
    // contexts, at most a few can ever be admitted before the rest see
    // the bound (each connection carries one in-flight request, so the
    // flood needs parallel connections to pile up).
    let mut slow = sim();
    slow.compress_delay = Duration::from_millis(200);
    let (addr, server) = start_server(slow, |cfg| {
        cfg.max_batch = 1;
        cfg.max_pending = 1;
    });
    let n = 10;
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(n));
    let mut handles = Vec::new();
    for i in 0..n {
        let addr = addr.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            barrier.wait();
            let line =
                format!("{{\"op\":\"context\",\"session\":\"c{i}\",\"tokens\":[{}]}}", i % 8);
            let resp = client.call(&line).unwrap();
            if resp.get("ok").unwrap() == &Json::Bool(true) {
                Ok(())
            } else {
                assert_eq!(resp.get("error").unwrap().str().unwrap(), "overloaded");
                assert!(resp.get("pending").unwrap().usize().unwrap() >= 1);
                Err(())
            }
        }));
    }
    let results: Vec<Result<(), ()>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let ok = results.iter().filter(|r| r.is_ok()).count();
    let overloaded = results.len() - ok;
    assert!(ok >= 1, "at least the first context must be admitted");
    assert!(overloaded >= 1, "a 10-wide burst over a 1-slot queue must refuse some");
    // Recovery: once drained, new work is admitted and answered.
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(10));
    assert!(stats.get("rejected_overload").unwrap().usize().unwrap() >= overloaded);
    let mut client = Client::connect(&addr).unwrap();
    let next = client.query("fresh", &[7], 1).unwrap();
    assert_eq!(top1(&next), 7);
    admin.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn kv_budget_evicts_oldest_sessions_and_keeps_answering() {
    let budget = 3 * kv_per_chunk();
    let (addr, server) = start_server(sim(), move |cfg| {
        cfg.kv_budget_bytes = Some(budget);
    });
    let mut client = Client::connect(&addr).unwrap();
    let n_sessions = 8;
    for s in 0..n_sessions {
        client.add_context(&format!("s{s}"), &[4 + s, 5 + s]).unwrap();
    }
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(5));
    // sessions x per-chunk KV exceeds the budget; eviction must have
    // kept the server under it and reported the count.
    let kv = stats.get("kv_bytes").unwrap().usize().unwrap();
    assert!(kv <= budget, "kv {kv} over budget {budget}");
    let evicted = stats.get("sessions_evicted").unwrap().usize().unwrap();
    assert!(evicted >= (n_sessions as usize).saturating_sub(3), "evicted {evicted}");
    assert!(stats.get("sessions").unwrap().usize().unwrap() <= 3);
    assert_eq!(stats.get("kv_budget_bytes").unwrap().usize().unwrap(), budget);
    // Queries still answered: a surviving recent session, and an
    // evicted one (transparently restarted with empty memory).
    let next = client.query(&format!("s{}", n_sessions - 1), &[9], 1).unwrap();
    assert_eq!(top1(&next), 9);
    let next = client.query("s0", &[11], 1).unwrap();
    assert_eq!(top1(&next), 11);
    admin.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn query_is_not_stuck_behind_unrelated_backlog() {
    // 12 connections feed session "bulk" with 5 chunks each (60 chunks,
    // 15 compress batches, ~600 ms of backend time). A query for an
    // unrelated session issued into the middle of that flood must come
    // back while most of the backlog is still queued: the executor
    // interleaves intake, one-batch pumps, and delivery, and the batcher
    // prioritises ready inference batches.
    let mut slow = sim();
    slow.compress_delay = Duration::from_millis(40);
    slow.infer_delay = Duration::from_millis(1);
    let (addr, server) = start_server(slow, |cfg| {
        cfg.max_batch = 4;
        cfg.max_pending = 1000;
    });
    let total_chunks = 60usize;
    let mut handles = Vec::new();
    for c in 0..12 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            for i in 0..5i32 {
                client.add_context("bulk", &[(c + i) % 8]).unwrap();
            }
        }));
    }
    // Let the backlog build, then race a query against it.
    std::thread::sleep(Duration::from_millis(100));
    let mut fast = Client::connect(&addr).unwrap();
    let next = fast.query("fast", &[9], 1).unwrap();
    assert_eq!(top1(&next), 9);
    let stats = fast.stats().unwrap();
    let done = stats.get("compressions").unwrap().usize().unwrap();
    assert!(
        done < total_chunks,
        "query must be answered before the unrelated backlog drains \
         (all {total_chunks} compressions already done)"
    );
    for h in handles {
        h.join().expect("bulk client");
    }
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(15));
    assert_eq!(stats.get("compressions").unwrap().usize().unwrap(), total_chunks);
    // The bulk session absorbed every chunk in order: its final time
    // step equals the chunk count even though 12 connections raced.
    let t = {
        let mut c = Client::connect(&addr).unwrap();
        let ack = c.add_context("bulk", &[1]).unwrap();
        ack.get("t").unwrap().i64().unwrap()
    };
    assert_eq!(t, total_chunks as i64 + 1);
    wait_drained(&mut admin, Duration::from_secs(5));
    admin.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn graceful_shutdown_drains_work_and_releases_port() {
    let mut slow = sim();
    slow.compress_delay = Duration::from_millis(10);
    let (addr, server) = start_server(slow, |_| {});
    // Queue work, then request shutdown: the reply must arrive only
    // after the in-flight work drained, and the port must be free.
    let mut client = Client::connect(&addr).unwrap();
    for i in 0..6 {
        client.add_context("tail", &[i]).unwrap();
    }
    let seen_before_shutdown = {
        let mut admin = Client::connect(&addr).unwrap();
        let resp = admin.call("{\"op\":\"shutdown\"}").unwrap();
        assert_eq!(resp.get("kind").unwrap().str().unwrap(), "shutdown");
        assert_eq!(resp.get("ok").unwrap(), &Json::Bool(true));
        true
    };
    assert!(seen_before_shutdown);
    server.join().unwrap().unwrap();
    // New work is refused after shutdown (connection fails or errors),
    // and the listener actually released the port: rebinding succeeds.
    let rebound = TcpListener::bind(&addr);
    assert!(rebound.is_ok(), "port still bound after shutdown: {rebound:?}");
}

// (Refusal of new work while a shutdown drains is deterministic at the
// admission layer and is unit-tested in `ccm::server::tests` — driving
// it through TCP would need fragile sleeps against the drain clock.)
