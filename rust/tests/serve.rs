//! Protocol-level integration tests for the pipelined serving engine.
//!
//! These run the full TCP serve path (acceptor, connection threads,
//! executor pump, admission control, memory governance) over the
//! deterministic `SimCompute` backend, so they need no AOT artifacts
//! and no XLA — they test the serving system, not the model.
//!
//! Shared fixtures (server guards with drop-kill, deadline-polling
//! waits, routing helpers) live in `common/mod.rs`; the thin wrappers
//! below only keep the historical `(addr, guard)` call shape.

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use ccm::compress::SimCompute;
use ccm::coordinator::session::EvictionKind;
use ccm::model::Manifest;
use ccm::server::{shard_for, Client, ReactorMode, ServerConfig};
use ccm::util::json::Json;

use common::{ids_on_shard, kv_per_chunk, poll_until, sim, top1, wait_drained, ServerHandle};

/// Start a server over SimCompute; returns (addr, drop-kill guard).
fn start_server(sim: SimCompute, tune: impl FnOnce(&mut ServerConfig)) -> (String, ServerHandle) {
    let server = common::start_server(sim, tune);
    (server.addr.clone(), server)
}

#[test]
fn concurrent_clients_interleave_context_and_query() {
    let (addr, server) = start_server(sim(), |_| {});
    let n_clients = 4;
    let rounds = 3;
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let session = format!("user{c}");
            for round in 1..=rounds {
                let chunk = [10 + c, 11 + c, 12 + c];
                let ack = client.add_context(&session, &chunk).unwrap();
                // The ack reports the step this chunk lands on.
                assert_eq!(ack.get("t").unwrap().i64().unwrap(), round as i64, "{session}");
                let q = 20 + c;
                let next = client.query(&session, &[q], 3).unwrap();
                assert_eq!(top1(&next), q, "echo backend must rank the token first");
                assert!(next.iter().all(|(_, lp)| *lp <= 0.0), "logprobs <= 0");
            }
        }));
    }
    for h in handles {
        h.join().expect("client thread");
    }
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(5));
    assert_eq!(stats.get("sessions").unwrap().usize().unwrap(), n_clients as usize);
    assert_eq!(
        stats.get("compressions").unwrap().usize().unwrap(),
        n_clients as usize * rounds as usize
    );
    assert_eq!(
        stats.get("inferences").unwrap().usize().unwrap(),
        n_clients as usize * rounds as usize
    );
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn pipelined_context_acks_report_distinct_steps() {
    // Regression for the seed bug: two context chunks queued together
    // both acked t+1. Write both lines before reading any reply.
    let (addr, server) = start_server(sim(), |_| {});
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writer
        .write_all(
            b"{\"op\":\"context\",\"session\":\"u\",\"tokens\":[4,5]}\n\
              {\"op\":\"context\",\"session\":\"u\",\"tokens\":[6,7]}\n",
        )
        .unwrap();
    let mut ts = Vec::new();
    for _ in 0..2 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").unwrap(), &Json::Bool(true));
        ts.push(j.get("t").unwrap().i64().unwrap());
    }
    assert_eq!(ts, vec![1, 2], "acks must report the actual queued steps");
    let mut admin = Client::connect(&addr).unwrap();
    wait_drained(&mut admin, Duration::from_secs(5));
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn overload_refuses_then_recovers() {
    // One pending slot, 200 ms per compress batch: of 10 simultaneous
    // contexts, at most a few can ever be admitted before the rest see
    // the bound (each connection carries one in-flight request, so the
    // flood needs parallel connections to pile up).
    let mut slow = sim();
    slow.compress_delay = Duration::from_millis(200);
    let (addr, server) = start_server(slow, |cfg| {
        cfg.max_batch = 1;
        cfg.max_pending = 1;
    });
    let n = 10;
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(n));
    let mut handles = Vec::new();
    for i in 0..n {
        let addr = addr.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            barrier.wait();
            let line =
                format!("{{\"op\":\"context\",\"session\":\"c{i}\",\"tokens\":[{}]}}", i % 8);
            let resp = client.call(&line).unwrap();
            if resp.get("ok").unwrap() == &Json::Bool(true) {
                Ok(())
            } else {
                assert_eq!(resp.get("error").unwrap().str().unwrap(), "overloaded");
                assert!(resp.get("pending").unwrap().usize().unwrap() >= 1);
                Err(())
            }
        }));
    }
    let results: Vec<Result<(), ()>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let ok = results.iter().filter(|r| r.is_ok()).count();
    let overloaded = results.len() - ok;
    assert!(ok >= 1, "at least the first context must be admitted");
    assert!(overloaded >= 1, "a 10-wide burst over a 1-slot queue must refuse some");
    // Recovery: once drained, new work is admitted and answered.
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(10));
    assert!(stats.get("rejected_overload").unwrap().usize().unwrap() >= overloaded);
    let mut client = Client::connect(&addr).unwrap();
    let next = client.query("fresh", &[7], 1).unwrap();
    assert_eq!(top1(&next), 7);
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn kv_budget_evicts_oldest_sessions_and_keeps_answering() {
    let budget = 3 * kv_per_chunk();
    let (addr, server) = start_server(sim(), move |cfg| {
        cfg.kv_budget_bytes = Some(budget);
    });
    let mut client = Client::connect(&addr).unwrap();
    let n_sessions = 8;
    for s in 0..n_sessions {
        client.add_context(&format!("s{s}"), &[4 + s, 5 + s]).unwrap();
    }
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(5));
    // sessions x per-chunk KV exceeds the budget; eviction must have
    // kept the server under it and reported the count.
    let kv = stats.get("kv_bytes").unwrap().usize().unwrap();
    assert!(kv <= budget, "kv {kv} over budget {budget}");
    let evicted = stats.get("sessions_evicted").unwrap().usize().unwrap();
    assert!(evicted >= (n_sessions as usize).saturating_sub(3), "evicted {evicted}");
    assert!(stats.get("sessions").unwrap().usize().unwrap() <= 3);
    assert_eq!(stats.get("kv_budget_bytes").unwrap().usize().unwrap(), budget);
    // Queries still answered: a surviving recent session, and an
    // evicted one (transparently restarted with empty memory).
    let next = client.query(&format!("s{}", n_sessions - 1), &[9], 1).unwrap();
    assert_eq!(top1(&next), 9);
    let next = client.query("s0", &[11], 1).unwrap();
    assert_eq!(top1(&next), 11);
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn query_is_not_stuck_behind_unrelated_backlog() {
    // 12 connections feed session "bulk" with 5 chunks each (60 chunks,
    // 15 compress batches, ~600 ms of backend time). A query for an
    // unrelated session issued into the middle of that flood must come
    // back while most of the backlog is still queued: the executor
    // interleaves intake, one-batch pumps, and delivery, and the batcher
    // prioritises ready inference batches.
    let mut slow = sim();
    slow.compress_delay = Duration::from_millis(40);
    slow.infer_delay = Duration::from_millis(1);
    let (addr, server) = start_server(slow, |cfg| {
        cfg.max_batch = 4;
        cfg.max_pending = 1000;
    });
    let total_chunks = 60usize;
    let mut handles = Vec::new();
    for c in 0..12 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            for i in 0..5i32 {
                client.add_context("bulk", &[(c + i) % 8]).unwrap();
            }
        }));
    }
    // Let the backlog actually build (deadline-polled, not a blind
    // sleep), then race a query against it.
    let mut fast = Client::connect(&addr).unwrap();
    poll_until(Duration::from_secs(10), "compress backlog to build", || {
        let stats = fast.stats().expect("stats");
        (stats.get("pending").unwrap().usize().unwrap() >= 8).then_some(())
    });
    let next = fast.query("fast", &[9], 1).unwrap();
    assert_eq!(top1(&next), 9);
    let stats = fast.stats().unwrap();
    let done = stats.get("compressions").unwrap().usize().unwrap();
    assert!(
        done < total_chunks,
        "query must be answered before the unrelated backlog drains \
         (all {total_chunks} compressions already done)"
    );
    for h in handles {
        h.join().expect("bulk client");
    }
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(15));
    assert_eq!(stats.get("compressions").unwrap().usize().unwrap(), total_chunks);
    // The bulk session absorbed every chunk in order: its final time
    // step equals the chunk count even though 12 connections raced.
    let t = {
        let mut c = Client::connect(&addr).unwrap();
        let ack = c.add_context("bulk", &[1]).unwrap();
        ack.get("t").unwrap().i64().unwrap()
    };
    assert_eq!(t, total_chunks as i64 + 1);
    wait_drained(&mut admin, Duration::from_secs(5));
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn overlong_line_is_refused_and_connection_survives() {
    // Slow-loris hardening: a peer drip-feeding a line that never ends
    // must not pin buffer memory. Past the cap the server answers
    // line_too_long, drops the buffered bytes, and resynchronises at
    // the next newline — the connection stays usable.
    let (addr, server) = start_server(sim(), |cfg| cfg.max_line_bytes = 1024);
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    // 8 KiB of garbage with no newline (8x the cap), then the newline.
    writer.write_all(&vec![b'x'; 8 * 1024]).unwrap();
    writer.flush().unwrap();
    writer.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("ok").unwrap(), &Json::Bool(false));
    assert_eq!(j.get("error").unwrap().str().unwrap(), "line_too_long");
    // Framing recovered: a normal request on the same connection works.
    writer.write_all(b"{\"op\":\"query\",\"session\":\"ok\",\"tokens\":[7],\"topk\":1}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("ok").unwrap(), &Json::Bool(true), "{line}");
    let next = j.get("next").unwrap().arr().unwrap();
    assert_eq!(next[0].arr().unwrap()[0].i64().unwrap(), 7);
    // A line at exactly the cap still parses (the cap is a bound, not
    // an off-by-one): pad a valid request with leading spaces.
    let body = "{\"op\":\"query\",\"session\":\"pad\",\"tokens\":[5],\"topk\":1}";
    let padded = format!("{}{body}\n", " ".repeat(1024 - body.len()));
    writer.write_all(padded.as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(Json::parse(line.trim()).unwrap().get("ok").unwrap(), &Json::Bool(true));
    let mut admin = Client::connect(&addr).unwrap();
    wait_drained(&mut admin, Duration::from_secs(5));
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn max_conns_refuses_excess_connections_and_recovers() {
    let (addr, server) = start_server(sim(), |cfg| cfg.max_conns = 2);
    let mut c1 = Client::connect(&addr).unwrap();
    let mut c2 = Client::connect(&addr).unwrap();
    // A round-trip on both guarantees the server has registered them.
    assert_eq!(top1(&c1.query("a", &[1], 1).unwrap()), 1);
    assert_eq!(top1(&c2.query("b", &[2], 1).unwrap()), 2);
    // Third connection: accepted at the TCP level, then refused with
    // one proactive line and closed — no request needed.
    {
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("error").unwrap().str().unwrap(), "too_many_connections");
        let mut eof = String::new();
        assert_eq!(reader.read_line(&mut eof).unwrap(), 0, "refused conn must be closed");
    }
    // Closing a connection frees its slot; the server notices the EOF
    // asynchronously, so poll until a fresh connection is admitted.
    drop(c1);
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut admitted = loop {
        let stream = TcpStream::connect(&addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(300))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writer
            .write_all(b"{\"op\":\"query\",\"session\":\"c\",\"tokens\":[3],\"topk\":1}\n")
            .unwrap();
        let mut line = String::new();
        if let Ok(len) = reader.read_line(&mut line) {
            if len > 0 {
                let j = Json::parse(line.trim()).unwrap();
                if j.get("ok").unwrap() == &Json::Bool(true) {
                    break (reader, writer);
                }
                // Still too_many_connections: the slot is not free yet.
            }
        }
        assert!(Instant::now() < deadline, "slot never freed after closing a connection");
        std::thread::sleep(Duration::from_millis(20));
    };
    // The admitted connection is a full citizen: shut the server down
    // through it (the ack arrives after drain + port release).
    admitted.1.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
    admitted.0.get_ref().set_read_timeout(None).unwrap();
    let mut ack = String::new();
    admitted.0.read_line(&mut ack).unwrap();
    assert_eq!(Json::parse(ack.trim()).unwrap().get("ok").unwrap(), &Json::Bool(true));
    server.join();
}

#[test]
fn slow_reader_receives_every_reply_in_order() {
    // Partial-write continuation: a client floods queries on one
    // connection while reading slowly. Replies (~full-vocab topk, far
    // more bytes than the socket buffers hold) pile into the server's
    // per-connection write buffer; every reply must still arrive, in
    // request order. A writer thread feeds the flood so the slow read
    // loop and the request stream are concurrent, like a real client.
    let (addr, server) = start_server(sim(), |cfg| {
        cfg.max_pending = 20_000;
    });
    let vocab = Manifest::toy().model.vocab;
    let n = 2000usize;
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let feeder = std::thread::spawn(move || {
        for i in 0..n {
            let tok = (i % (vocab - 1)) + 1; // 1..vocab: distinct from the mem-bump at 0
            let line = format!(
                "{{\"op\":\"query\",\"session\":\"bp\",\"tokens\":[{tok}],\"topk\":{vocab}}}\n"
            );
            writer.write_all(line.as_bytes()).unwrap();
        }
        writer.flush().unwrap();
        writer
    });
    for i in 0..n {
        if i % 50 == 0 {
            // Slow consumer: let the server's write buffer back up.
            std::thread::sleep(Duration::from_millis(1));
        }
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(!line.trim().is_empty(), "reply {i} missing");
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").unwrap(), &Json::Bool(true), "reply {i}: {line}");
        let next = j.get("next").unwrap().arr().unwrap();
        assert_eq!(next.len(), vocab, "reply {i} carries the full distribution");
        let top = next[0].arr().unwrap()[0].i64().unwrap();
        assert_eq!(top, ((i % (vocab - 1)) + 1) as i64, "reply {i} out of order");
    }
    drop(feeder.join().expect("feeder thread"));
    let mut admin = Client::connect(&addr).unwrap();
    wait_drained(&mut admin, Duration::from_secs(10));
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn stats_detail_reports_per_session_accounting() {
    let (addr, server) = start_server(sim(), |_| {});
    let mut client = Client::connect(&addr).unwrap();
    client.add_context("alpha", &[1, 2]).unwrap();
    client.add_context("alpha", &[3, 4]).unwrap();
    client.add_context("beta", &[5, 6]).unwrap();
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(5));
    assert!(stats.opt("sessions_detail").is_none(), "detail must be opt-in");
    let detailed = admin.stats_detailed().unwrap();
    let list = detailed.get("sessions_detail").unwrap().arr().unwrap();
    assert_eq!(list.len(), 2);
    assert_eq!(list[0].get("id").unwrap().str().unwrap(), "alpha");
    assert_eq!(list[0].get("t").unwrap().usize().unwrap(), 2);
    assert_eq!(list[1].get("id").unwrap().str().unwrap(), "beta");
    assert_eq!(list[1].get("t").unwrap().usize().unwrap(), 1);
    // Per-session kv sums to the aggregate in the same response.
    let kv_sum: usize = list.iter().map(|s| s.get("kv_bytes").unwrap().usize().unwrap()).sum();
    assert_eq!(kv_sum, detailed.get("kv_bytes").unwrap().usize().unwrap());
    for s in list {
        let age = s.get("age_ms").unwrap().usize().unwrap();
        let idle = s.get("idle_ms").unwrap().usize().unwrap();
        assert!(idle <= age, "idle {idle} > age {age}");
    }
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn stats_detail_merges_sessions_across_shards() {
    let shards = 2;
    let (addr, server) = start_sharded((0..shards).map(|_| sim()).collect(), |_| {});
    let mut client = Client::connect(&addr).unwrap();
    let on0 = ids_on_shard(0, shards, 2);
    let on1 = ids_on_shard(1, shards, 2);
    for id in on0.iter().chain(on1.iter()) {
        client.add_context(id, &[1, 2]).unwrap();
    }
    let mut admin = Client::connect(&addr).unwrap();
    wait_drained(&mut admin, Duration::from_secs(5));
    let detailed = admin.stats_detailed().unwrap();
    let list = detailed.get("sessions_detail").unwrap().arr().unwrap();
    assert_eq!(list.len(), 4, "merged view must span all shards");
    let mut expected: Vec<String> = on0.iter().chain(on1.iter()).cloned().collect();
    expected.sort();
    let got: Vec<String> =
        list.iter().map(|s| s.get("id").unwrap().str().unwrap().to_string()).collect();
    assert_eq!(got, expected, "merged rows sort by id across shards");
    // Each shard's own embedded stats carry only its residents.
    for p in detailed.get("per_shard").unwrap().arr().unwrap() {
        let shard = p.get("shard").unwrap().usize().unwrap();
        let own = p.get("sessions_detail").unwrap().arr().unwrap();
        assert_eq!(own.len(), 2, "shard {shard}");
        for s in own {
            let id = s.get("id").unwrap().str().unwrap();
            assert_eq!(ccm::server::shard_for(id, shards), shard, "{id}");
        }
    }
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn graceful_shutdown_drains_work_and_releases_port() {
    let mut slow = sim();
    slow.compress_delay = Duration::from_millis(10);
    let (addr, server) = start_server(slow, |_| {});
    // Queue work, then request shutdown: the reply must arrive only
    // after the in-flight work drained, and the port must be free.
    let mut client = Client::connect(&addr).unwrap();
    for i in 0..6 {
        client.add_context("tail", &[i]).unwrap();
    }
    let seen_before_shutdown = {
        let mut admin = Client::connect(&addr).unwrap();
        let resp = admin.call("{\"op\":\"shutdown\"}").unwrap();
        assert_eq!(resp.get("kind").unwrap().str().unwrap(), "shutdown");
        assert_eq!(resp.get("ok").unwrap(), &Json::Bool(true));
        true
    };
    assert!(seen_before_shutdown);
    server.join();
    // New work is refused after shutdown (connection fails or errors),
    // and the listener actually released the port: rebinding succeeds.
    let rebound = TcpListener::bind(&addr);
    assert!(rebound.is_ok(), "port still bound after shutdown: {rebound:?}");
}

// (Refusal of new work while a shutdown drains is deterministic at the
// admission layer and is unit-tested in `ccm::server::tests` — driving
// it through TCP would need fragile sleeps against the drain clock.)

// ---------------------------------------------------------------------
// Sharded serving: one executor (backend + batcher + session manager)
// per shard, deterministic session→shard routing, per-shard budgets.

/// Start an N-shard server, one SimCompute per shard (sims[i] becomes
/// shard i's backend); returns (addr, drop-kill guard).
fn start_sharded(
    sims: Vec<SimCompute>,
    tune: impl FnOnce(&mut ServerConfig),
) -> (String, ServerHandle) {
    let server = common::start_sharded(sims, tune);
    (server.addr.clone(), server)
}

#[test]
fn sharded_routing_is_stable_and_stats_merge() {
    // Routing stability: a session's chunks land on one shard no matter
    // which connection carries them, so its time step keeps advancing;
    // and the merged stats' per-shard split matches the routing hash
    // exactly.
    let shards = 4;
    let (addr, server) = start_sharded((0..shards).map(|_| sim()).collect(), |_| {});
    let n_sessions = 16usize;
    for round in 1..=2i64 {
        // A fresh connection per round: routing must not depend on the
        // connection, only on the session id.
        let mut client = Client::connect(&addr).unwrap();
        for s in 0..n_sessions {
            let ack = client.add_context(&format!("user{s}"), &[1, 2]).unwrap();
            assert_eq!(ack.get("t").unwrap().i64().unwrap(), round, "user{s}");
        }
    }
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(5));
    assert_eq!(stats.get("shards").unwrap().usize().unwrap(), shards);
    assert_eq!(stats.get("sessions").unwrap().usize().unwrap(), n_sessions);
    assert_eq!(stats.get("compressions").unwrap().usize().unwrap(), n_sessions * 2);
    let per = stats.get("per_shard").unwrap().arr().unwrap();
    assert_eq!(per.len(), shards);
    for (i, p) in per.iter().enumerate() {
        let expected = (0..n_sessions)
            .filter(|s| shard_for(&format!("user{s}"), shards) == i)
            .count();
        assert_eq!(p.get("shard").unwrap().usize().unwrap(), i);
        assert_eq!(p.get("sessions").unwrap().usize().unwrap(), expected, "shard {i}");
    }
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn cross_shard_ordering_is_preserved_per_session() {
    // One connection interleaving two sessions pinned to different
    // shards: each session's acks and query results must follow its own
    // submission order, independent of the other shard's progress.
    let shards = 2;
    let (addr, server) = start_sharded((0..shards).map(|_| sim()).collect(), |_| {});
    let a = ids_on_shard(0, shards, 1).pop().unwrap();
    let b = ids_on_shard(1, shards, 1).pop().unwrap();
    let mut client = Client::connect(&addr).unwrap();
    for round in 1..=3i64 {
        let ack = client.add_context(&a, &[1, 2]).unwrap();
        assert_eq!(ack.get("t").unwrap().i64().unwrap(), round, "{a}");
        let ack = client.add_context(&b, &[3, 4]).unwrap();
        assert_eq!(ack.get("t").unwrap().i64().unwrap(), round, "{b}");
        let next = client.query(&a, &[5], 1).unwrap();
        assert_eq!(top1(&next), 5);
        let next = client.query(&b, &[9], 1).unwrap();
        assert_eq!(top1(&next), 9);
    }
    let mut admin = Client::connect(&addr).unwrap();
    wait_drained(&mut admin, Duration::from_secs(5));
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn overload_on_one_shard_does_not_refuse_the_other() {
    // Shard 0 gets a slow backend and a burst that saturates its
    // one-slot pending queue; shard 1 must keep admitting and answering
    // immediately — per-shard admission control isolates the overload.
    let shards = 2;
    let mut sims: Vec<SimCompute> = (0..shards).map(|_| sim()).collect();
    sims[0].compress_delay = Duration::from_millis(4000);
    let (addr, server) = start_sharded(sims, |cfg| {
        cfg.max_batch = 1;
        cfg.max_pending = 1;
    });
    let flood_ids = ids_on_shard(0, shards, 8);
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(flood_ids.len()));
    let mut handles = Vec::new();
    for id in flood_ids {
        let addr = addr.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            barrier.wait();
            let line = format!("{{\"op\":\"context\",\"session\":\"{id}\",\"tokens\":[1]}}");
            let resp = client.call(&line).unwrap();
            if resp.get("ok").unwrap() == &Json::Bool(true) {
                Ok(())
            } else {
                assert_eq!(resp.get("error").unwrap().str().unwrap(), "overloaded");
                Err(())
            }
        }));
    }
    let results: Vec<Result<(), ()>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let overloaded = results.iter().filter(|r| r.is_err()).count();
    assert!(results.len() - overloaded >= 1, "at least one flood context must be admitted");
    assert!(overloaded >= 1, "an 8-wide burst over a 1-slot queue must refuse some");
    // Shard 0 is now busy for ~4 s per admitted batch; shard 1 must
    // answer well inside that window: the 2 s bound leaves 2x margin
    // against CI scheduling jitter, and queuing behind shard 0 would
    // cost >= 4 s (2x the bound), so the two outcomes cannot blur.
    let t0 = Instant::now();
    let quiet = ids_on_shard(1, shards, 1).pop().unwrap();
    let mut client = Client::connect(&addr).unwrap();
    let ack = client.add_context(&quiet, &[3]).unwrap();
    assert_eq!(ack.get("ok").unwrap(), &Json::Bool(true), "shard 1 must admit");
    let next = client.query(&quiet, &[7], 1).unwrap();
    assert_eq!(top1(&next), 7);
    assert!(
        t0.elapsed() < Duration::from_millis(2000),
        "shard 1 work must not queue behind shard 0 ({:?})",
        t0.elapsed()
    );
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(30));
    assert!(stats.get("rejected_overload").unwrap().usize().unwrap() >= overloaded);
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn kv_budget_partitions_across_shards() {
    // The global budget splits into per-shard slices that sum exactly
    // to it; each shard enforces its own slice independently.
    let shards = 2;
    let budget = 2 * 3 * kv_per_chunk(); // three one-chunk sessions per shard
    let (addr, server) = start_sharded((0..shards).map(|_| sim()).collect(), move |cfg| {
        cfg.kv_budget_bytes = Some(budget);
    });
    let mut client = Client::connect(&addr).unwrap();
    for shard in 0..shards {
        for id in ids_on_shard(shard, shards, 6) {
            client.add_context(&id, &[4, 5]).unwrap();
        }
    }
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(5));
    assert_eq!(stats.get("kv_budget_bytes").unwrap().usize().unwrap(), budget);
    assert!(stats.get("kv_bytes").unwrap().usize().unwrap() <= budget);
    for p in stats.get("per_shard").unwrap().arr().unwrap() {
        let slice = p.get("kv_budget_bytes").unwrap().usize().unwrap();
        assert_eq!(slice, budget / 2, "even budget must split evenly");
        let kv = p.get("kv_bytes").unwrap().usize().unwrap();
        assert!(kv <= slice, "shard over its slice: {kv} > {slice}");
        assert!(p.get("sessions").unwrap().usize().unwrap() <= 3);
        assert!(p.get("sessions_evicted").unwrap().usize().unwrap() >= 3);
    }
    // Surviving and evicted sessions both still answer (evicted ones
    // transparently restart with empty memory).
    let next = client.query(&ids_on_shard(0, shards, 1)[0], &[9], 1).unwrap();
    assert_eq!(top1(&next), 9);
    admin.shutdown().unwrap();
    server.join();
}

// ---------------------------------------------------------------------
// Multi-reactor accept sharding (PR 4): N reactor threads, each with
// its own poller/conn-table/completion-queue, SO_REUSEPORT listeners
// where available (single-listener round-robin handoff elsewhere).

#[test]
fn multi_reactor_accept_sharding_balances_and_shuts_down_cleanly() {
    let reactors = 4usize;
    let shards = 2usize;
    let (addr, server) = start_sharded((0..shards).map(|_| sim()).collect(), |cfg| {
        cfg.reactor = ReactorMode::Epoll;
        cfg.reactors = reactors;
    });
    // 64 concurrent connections, each a full context+query round trip:
    // replies must route back through the owning reactor untangled.
    let n_conns = 64usize;
    let mut clients: Vec<Client> = (0..n_conns).map(|_| Client::connect(&addr).unwrap()).collect();
    for (i, client) in clients.iter_mut().enumerate() {
        let session = format!("mr-{i}");
        let ack = client.add_context(&session, &[1, 2]).unwrap();
        assert_eq!(ack.get("t").unwrap().i64().unwrap(), 1, "{session}");
        let next = client.query(&session, &[7], 1).unwrap();
        assert_eq!(top1(&next), 7, "{session}");
    }
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(10));
    let rows = stats.get("per_reactor").unwrap().arr().unwrap();
    assert_eq!(rows.len(), reactors, "one stats row per reactor thread");
    let (mut accepted_total, mut conns_total) = (0usize, 0usize);
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.get("reactor").unwrap().usize().unwrap(), i);
        let accepted = row.get("accepted").unwrap().usize().unwrap();
        assert!(accepted > 0, "reactor {i} must own at least one of the {n_conns} conns");
        assert!(row.get("lines").unwrap().usize().unwrap() > 0, "reactor {i} framed no lines");
        assert_eq!(row.get("refusals").unwrap().usize().unwrap(), 0);
        accepted_total += accepted;
        conns_total += row.get("conns").unwrap().usize().unwrap();
    }
    assert_eq!(accepted_total, n_conns + 1, "every connection accepted exactly once");
    assert_eq!(conns_total, n_conns + 1, "clients plus admin all still open");
    assert_eq!(stats.get("sessions").unwrap().usize().unwrap(), n_conns);
    // Staged multi-reactor shutdown: ack only after EVERY reactor
    // released its listener — the port must be immediately rebindable.
    admin.shutdown().unwrap();
    drop(clients);
    server.join();
    let rebound = TcpListener::bind(&addr);
    assert!(rebound.is_ok(), "port still bound after multi-reactor shutdown: {rebound:?}");
}

#[test]
fn single_listener_handoff_spreads_conns_across_reactors() {
    // Forced fallback for platforms/kernels without SO_REUSEPORT:
    // reactor 0 owns the only listener and round-robins accepted
    // sockets to its peers; the conn population must still spread.
    let (addr, server) = start_server(sim(), |cfg| {
        cfg.reactor = ReactorMode::Epoll;
        cfg.reactors = 2;
        cfg.force_accept_handoff = true;
    });
    let mut clients: Vec<Client> = (0..8).map(|_| Client::connect(&addr).unwrap()).collect();
    for (i, client) in clients.iter_mut().enumerate() {
        let next = client.query(&format!("ho-{i}"), &[5], 1).unwrap();
        assert_eq!(top1(&next), 5);
    }
    let mut admin = Client::connect(&addr).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(5));
    let rows = stats.get("per_reactor").unwrap().arr().unwrap();
    assert_eq!(rows.len(), 2);
    let accepted: Vec<usize> =
        rows.iter().map(|r| r.get("accepted").unwrap().usize().unwrap()).collect();
    assert_eq!(accepted.iter().sum::<usize>(), 9, "8 clients + admin, each owned once");
    assert!(accepted.iter().all(|a| *a > 0), "round-robin must reach every reactor: {accepted:?}");
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn reply_timeout_is_answered_promptly() {
    // Regression (PR 3 latent bug): the reactor polled on a flat 500 ms
    // tick and additionally gated the expiry scan on a 500 ms cadence,
    // so a timed-out request could be answered ~0.5–1 s late. The poll
    // timeout now derives from the earliest pending deadline.
    let mut slow = sim();
    slow.infer_delay = Duration::from_millis(2000);
    let (addr, server) = start_server(slow, |cfg| {
        cfg.reactor = ReactorMode::Epoll;
        cfg.reply_timeout = Duration::from_millis(200);
    });
    let mut client = Client::connect(&addr).unwrap();
    let t0 = Instant::now();
    let resp =
        client.call("{\"op\":\"query\",\"session\":\"t\",\"tokens\":[3],\"topk\":1}").unwrap();
    let elapsed = t0.elapsed();
    assert_eq!(resp.get("ok").unwrap(), &Json::Bool(false), "{resp}");
    assert_eq!(resp.get("error").unwrap().str().unwrap(), "timeout");
    assert!(elapsed >= Duration::from_millis(180), "deadline must actually elapse: {elapsed:?}");
    assert!(
        elapsed < Duration::from_millis(480),
        "timeout reply must track the deadline, not a 500 ms scan tick: {elapsed:?}"
    );
    // Let the stuck batch finish; its late reply must be dropped (the
    // request was already answered) and the connection stay usable.
    std::thread::sleep(Duration::from_millis(2300));
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("ok").unwrap(), &Json::Bool(true), "conn must survive the timeout");
    client.shutdown().unwrap();
    server.join();
}

#[test]
fn refused_connections_always_receive_the_refusal_line() {
    // Regression (PR 3 latent bug): the over-max_conns refusal was a
    // bare write_all on a just-nonblocking socket — WouldBlock or a
    // partial write silently dropped the line. Refusals are now
    // tracked conns that flush through normal write continuation.
    let (addr, server) = start_server(sim(), |cfg| {
        cfg.reactor = ReactorMode::Epoll;
        cfg.max_conns = 2;
    });
    let mut c1 = Client::connect(&addr).unwrap();
    let mut c2 = Client::connect(&addr).unwrap();
    assert_eq!(top1(&c1.query("a", &[1], 1).unwrap()), 1);
    assert_eq!(top1(&c2.query("b", &[2], 1).unwrap()), 2);
    // A simultaneous wave over the full budget: every refused socket
    // must read the refusal line, then see a clean close.
    let mut handles = Vec::new();
    for _ in 0..8 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(&addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            let mut reader = BufReader::new(stream);
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(!line.trim().is_empty(), "refusal line must arrive before close");
            let j = Json::parse(line.trim()).unwrap();
            assert_eq!(j.get("error").unwrap().str().unwrap(), "too_many_connections");
            let mut eof = String::new();
            assert_eq!(reader.read_line(&mut eof).unwrap(), 0, "refused conn must be closed");
        }));
    }
    for h in handles {
        h.join().expect("refused client");
    }
    // The admitted conns kept their slots and keep serving.
    assert_eq!(top1(&c1.query("a", &[3], 1).unwrap()), 3);
    assert_eq!(top1(&c2.query("b", &[4], 1).unwrap()), 4);
    c1.shutdown().unwrap();
    server.join();
}

#[test]
fn stats_detail_prefix_and_limit_bound_the_view() {
    // Pagination knobs for large fleets, across shards: prefix filters
    // everywhere, limit applies globally after the merge (first N by
    // id), and the aggregate counters stay untouched.
    let shards = 2;
    let (addr, server) = start_sharded((0..shards).map(|_| sim()).collect(), |_| {});
    let mut client = Client::connect(&addr).unwrap();
    for i in 0..4 {
        client.add_context(&format!("user-{i}"), &[1, 2]).unwrap();
    }
    client.add_context("admin-0", &[3, 4]).unwrap();
    let mut admin = Client::connect(&addr).unwrap();
    wait_drained(&mut admin, Duration::from_secs(5));
    let page = admin.stats_page("user-", 3).unwrap();
    let list = page.get("sessions_detail").unwrap().arr().unwrap();
    let ids: Vec<&str> = list.iter().map(|s| s.get("id").unwrap().str().unwrap()).collect();
    assert_eq!(ids, vec!["user-0", "user-1", "user-2"], "first 3 user-* rows by id");
    assert_eq!(page.get("sessions").unwrap().usize().unwrap(), 5, "counters stay global");
    // Unbounded detail still reports the whole fleet.
    let all = admin.stats_detailed().unwrap();
    assert_eq!(all.get("sessions_detail").unwrap().arr().unwrap().len(), 5);
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn stats_page_bounds_the_single_shard_view_too() {
    let (addr, server) = start_server(sim(), |_| {});
    let mut client = Client::connect(&addr).unwrap();
    for i in 0..3 {
        client.add_context(&format!("s-{i}"), &[1, 2]).unwrap();
    }
    let mut admin = Client::connect(&addr).unwrap();
    wait_drained(&mut admin, Duration::from_secs(5));
    let page = admin.stats_page("s-", 2).unwrap();
    let list = page.get("sessions_detail").unwrap().arr().unwrap();
    let ids: Vec<&str> = list.iter().map(|s| s.get("id").unwrap().str().unwrap()).collect();
    assert_eq!(ids, vec!["s-0", "s-1"]);
    assert_eq!(page.get("sessions").unwrap().usize().unwrap(), 3);
    admin.shutdown().unwrap();
    server.join();
}

#[test]
fn lru_eviction_policy_is_selectable_and_observable() {
    // --eviction lru: a recently-used old session survives budget
    // pressure; the least-recently-used one is evicted. Observable via
    // the context ack's time step (a surviving session continues at
    // t+1, an evicted one restarts at t=1).
    let budget = 2 * kv_per_chunk();
    let (addr, server) = start_server(sim(), move |cfg| {
        cfg.kv_budget_bytes = Some(budget);
        cfg.eviction = EvictionKind::Lru;
    });
    let mut client = Client::connect(&addr).unwrap();
    client.add_context("a", &[1, 2]).unwrap();
    client.add_context("b", &[3, 4]).unwrap();
    let mut admin = Client::connect(&addr).unwrap();
    wait_drained(&mut admin, Duration::from_secs(5));
    // Touch "a": now "b" is the least recently used.
    client.query("a", &[5], 1).unwrap();
    // "c" overflows the two-session budget → exactly one eviction.
    client.add_context("c", &[5, 6]).unwrap();
    let stats = wait_drained(&mut admin, Duration::from_secs(5));
    assert_eq!(stats.get("eviction").unwrap().str().unwrap(), "lru");
    assert_eq!(stats.get("sessions").unwrap().usize().unwrap(), 2);
    assert_eq!(stats.get("sessions_evicted").unwrap().usize().unwrap(), 1);
    let ack = client.add_context("a", &[7]).unwrap();
    assert_eq!(ack.get("t").unwrap().i64().unwrap(), 2, "recently-used session must survive");
    let ack = client.add_context("b", &[8]).unwrap();
    assert_eq!(ack.get("t").unwrap().i64().unwrap(), 1, "LRU session must have been evicted");
    wait_drained(&mut admin, Duration::from_secs(5));
    admin.shutdown().unwrap();
    server.join();
}
