//! Fault-injection suite for tiered session memory (hibernation):
//! idle sessions spill their `Mem(t)` snapshots to disk and rehydrate
//! transparently on the next touch, asserted end to end over the real
//! JSON-lines protocol in BOTH topologies (in-process executor and
//! worker processes behind the shard IPC hop).
//!
//! The failure contract under test: a corrupt, truncated, or
//! version-skewed snapshot is equivalent to an eviction — the next
//! touch serves a FRESH session at t=1, bumps `snapshot_corrupt`, and
//! never panics or drops the client connection. A SIGKILLed worker
//! leaves old-or-none snapshots (spills are tmp-then-rename), and its
//! successor rehydrates the predecessor's spill directory, so Mem(t)
//! survives worker restarts.

mod common;

use std::time::Duration;

use ccm::model::snapshot::SessionSnapshot;
use ccm::server::hibernate::{shard_dir, snap_path};
use ccm::server::Client;
use ccm::util::json::Json;

use common::{assert_ok, poll_until, sim, start_server, start_worker_server, wait_workers_up};

/// Re-exec entry: processes spawned by the worker-topology tests run
/// THIS test with the worker env set and become SimCompute worker
/// processes; in a normal test run it is an empty pass.
#[test]
fn hibernate_worker_entry() {
    common::sim_worker_entry_if_requested();
}

/// Per-test hibernation root under the system temp dir, pre-cleaned so
/// a crashed previous run cannot leak state into this one.
fn hib_root(case: &str) -> std::path::PathBuf {
    let root = std::env::temp_dir().join(format!("ccm-it-hib-{}-{case}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    root
}

fn stat(stats: &Json, key: &str) -> usize {
    stats.get(key).expect(key).usize().expect(key)
}

fn ack_t(ack: &Json) -> usize {
    assert_ok(ack);
    ack.get("t").expect("t in context ack").usize().expect("t")
}

// ---------------------------------------------------------------------
// In-process topology.

#[test]
fn inprocess_idle_session_spills_then_rehydrates_at_same_t() {
    let root = hib_root("inproc-roundtrip");
    let server = start_server(sim(), |cfg| {
        cfg.hibernate_dir = Some(root.clone());
        cfg.hibernate_after = Some(Duration::from_millis(50));
    });
    let mut client = server.client();
    assert_eq!(ack_t(&client.add_context("s", &[4, 5, 6]).expect("context 1")), 1);
    assert_eq!(ack_t(&client.add_context("s", &[7, 8, 9]).expect("context 2")), 2);
    let mut admin = server.client();
    let stats = poll_until(Duration::from_secs(10), "session to hibernate", || {
        let stats = admin.stats().expect("stats");
        (stat(&stats, "hibernated_sessions") == 1).then_some(stats)
    });
    // The spilled Mem(t) is on disk, out of the hot KV accounting.
    assert_eq!(stat(&stats, "sessions"), 0, "hibernated session must leave the hot map");
    assert_eq!(stat(&stats, "kv_bytes"), 0, "hibernated bytes are excluded from the KV budget");
    assert!(stat(&stats, "hibernated_bytes") > 0);
    assert!(stat(&stats, "spills") >= 1);
    assert!(snap_path(&root, 0, "s").exists(), "snapshot file must exist while hibernated");
    // The next touch rehydrates transparently on the SAME connection:
    // the session resumes at its pre-spill time step.
    assert_eq!(
        ack_t(&client.add_context("s", &[1, 2]).expect("context after spill")),
        3,
        "Mem(t) must resume where it left off, not restart"
    );
    let stats = admin.stats().expect("stats");
    assert!(stat(&stats, "rehydrations") >= 1);
    assert_eq!(stat(&stats, "hibernated_sessions"), 0);
    assert_eq!(stat(&stats, "snapshot_corrupt"), 0);
    assert!(!snap_path(&root, 0, "s").exists(), "rehydration must consume the snapshot");
    server.shutdown_join();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn every_corruption_fixture_degrades_to_a_fresh_session_not_an_error() {
    let root = hib_root("inproc-corrupt");
    let server = start_server(sim(), |cfg| {
        cfg.hibernate_dir = Some(root.clone());
        cfg.hibernate_after = Some(Duration::from_millis(50));
    });
    let ids = ["flip", "trunc", "crc", "vers"];
    let mut client = server.client();
    for id in &ids {
        assert_eq!(ack_t(&client.add_context(id, &[4, 5, 6]).expect("context 1")), 1);
        assert_eq!(ack_t(&client.add_context(id, &[7, 8]).expect("context 2")), 2);
    }
    let mut admin = server.client();
    poll_until(Duration::from_secs(10), "all four sessions to hibernate", || {
        let stats = admin.stats().expect("stats");
        (stat(&stats, "hibernated_sessions") == ids.len()).then_some(())
    });
    // Four distinct ways a snapshot can rot on disk.
    for id in &ids {
        let path = snap_path(&root, 0, id);
        let mut bytes = std::fs::read(&path).expect("snapshot on disk");
        match *id {
            // Payload bit-flip: the CRC (or a bounds check) trips.
            "flip" => bytes[bytes.len() / 2] ^= 0x5A,
            // Torn write: only a prefix survived.
            "trunc" => bytes.truncate(bytes.len() / 2),
            // Trailer corruption: the stored CRC itself is wrong.
            "crc" => *bytes.last_mut().expect("non-empty") ^= 0xFF,
            // Version skew: a future (unknown) codec version.
            "vers" => bytes[8] = 0xFF,
            other => unreachable!("{other}"),
        }
        std::fs::write(&path, &bytes).expect("write corrupted snapshot");
    }
    // Every fixture degrades to a fresh session at t=1 on the SAME
    // client connection — no panic, no refusal, no dropped socket.
    for (i, id) in ids.iter().enumerate() {
        let ack = client.add_context(id, &[1, 2]).expect("connection must survive corruption");
        assert_eq!(ack_t(&ack), 1, "{id}: corrupt snapshot must serve a FRESH session");
        let stats = admin.stats().expect("stats");
        assert_eq!(stat(&stats, "snapshot_corrupt"), i + 1, "{id}: corruption must be counted");
        assert!(!snap_path(&root, 0, id).exists(), "{id}: corrupt snapshot must be discarded");
    }
    // The fresh sessions keep working (and can hibernate again).
    assert_eq!(ack_t(&client.add_context("flip", &[3]).expect("second touch")), 2);
    server.shutdown_join();
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------
// Worker-process topology (spill state crosses the IPC hop and worker
// restarts).

fn hibernate_env(root: &std::path::Path, after_ms: u64) -> Vec<Vec<(String, String)>> {
    vec![vec![
        ("CCM_TEST_WORKER_HIBERNATE_DIR".to_string(), root.display().to_string()),
        ("CCM_TEST_WORKER_HIBERNATE_AFTER_MS".to_string(), after_ms.to_string()),
    ]]
}

#[test]
fn worker_topology_spills_and_rehydrates_over_the_wire() {
    let root = hib_root("worker-roundtrip");
    let server = start_worker_server("hibernate_worker_entry", 1, hibernate_env(&root, 50), |_| {});
    let mut admin = server.client();
    let stats = wait_workers_up(&mut admin, 1, Duration::from_secs(30));
    server.note_pids(&stats);
    let mut client = server.client();
    assert_eq!(ack_t(&client.add_context("w", &[4, 5, 6]).expect("context 1")), 1);
    assert_eq!(ack_t(&client.add_context("w", &[7, 8]).expect("context 2")), 2);
    let stats = poll_until(Duration::from_secs(10), "session to hibernate in the worker", || {
        let stats = admin.stats().expect("stats");
        (stat(&stats, "hibernated_sessions") == 1).then_some(stats)
    });
    // Merged stats carry the hibernation counters across the IPC hop.
    assert_eq!(stat(&stats, "sessions"), 0);
    assert_eq!(stat(&stats, "kv_bytes"), 0);
    assert!(stat(&stats, "hibernated_bytes") > 0);
    assert!(snap_path(&root, 0, "w").exists());
    assert_eq!(
        ack_t(&client.add_context("w", &[1]).expect("context after spill")),
        3,
        "the worker must rehydrate the session at its pre-spill time step"
    );
    let stats = admin.stats().expect("stats");
    assert!(stat(&stats, "rehydrations") >= 1);
    assert_eq!(stat(&stats, "snapshot_corrupt"), 0);
    server.shutdown_join();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn worker_topology_corrupt_snapshot_serves_fresh_session() {
    let root = hib_root("worker-corrupt");
    let server = start_worker_server("hibernate_worker_entry", 1, hibernate_env(&root, 50), |_| {});
    let mut admin = server.client();
    let stats = wait_workers_up(&mut admin, 1, Duration::from_secs(30));
    server.note_pids(&stats);
    let mut client = server.client();
    assert_eq!(ack_t(&client.add_context("wc", &[4, 5, 6]).expect("context 1")), 1);
    assert_eq!(ack_t(&client.add_context("wc", &[7, 8]).expect("context 2")), 2);
    poll_until(Duration::from_secs(10), "session to hibernate in the worker", || {
        let stats = admin.stats().expect("stats");
        (stat(&stats, "hibernated_sessions") == 1).then_some(())
    });
    let path = snap_path(&root, 0, "wc");
    let mut bytes = std::fs::read(&path).expect("snapshot on disk");
    bytes[bytes.len() / 2] ^= 0x5A;
    std::fs::write(&path, &bytes).expect("write corrupted snapshot");
    // The touch crosses the reactor, the IPC hop, and the worker's
    // rehydrate path — and still degrades to a fresh session.
    let ack = client.add_context("wc", &[1]).expect("connection must survive corruption");
    assert_eq!(ack_t(&ack), 1, "corrupt snapshot must serve a FRESH session");
    let stats = admin.stats().expect("stats");
    assert_eq!(stat(&stats, "snapshot_corrupt"), 1);
    assert!(!path.exists(), "corrupt snapshot must be discarded");
    server.shutdown_join();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn sigkilled_worker_leaves_decodable_snapshots_and_its_successor_rehydrates_them() {
    const SESSIONS: usize = 6;
    let root = hib_root("worker-kill");
    let server = start_worker_server("hibernate_worker_entry", 1, hibernate_env(&root, 30), |_| {});
    let mut admin = server.client();
    let stats = wait_workers_up(&mut admin, 1, Duration::from_secs(30));
    let pid0 = server.note_pids(&stats)[0].expect("worker pid");
    let mut client = server.client();
    let ids: Vec<String> = (0..SESSIONS).map(|i| format!("k{i}")).collect();
    for id in &ids {
        assert_eq!(ack_t(&client.add_context(id, &[4, 5, 6]).expect("context 1")), 1);
        assert_eq!(ack_t(&client.add_context(id, &[7, 8]).expect("context 2")), 2);
    }
    poll_until(Duration::from_secs(10), "all sessions to hibernate", || {
        let stats = admin.stats().expect("stats");
        (stat(&stats, "hibernated_sessions") == SESSIONS).then_some(())
    });
    // Plant the crash artifact a SIGKILL lands mid-spill: a partially
    // written `.snap.tmp` that was never renamed into place. Backdate
    // its mtime past the orphan grace so the successor's startup sweep
    // is allowed to remove it.
    let dir = shard_dir(&root, 0);
    let torn = dir.join("deadbeef.snap.tmp");
    std::fs::write(&torn, b"partial snapshot write interrupted by SIGKILL").expect("plant tmp");
    let f = std::fs::File::options().write(true).open(&torn).expect("open tmp");
    f.set_modified(std::time::SystemTime::now() - Duration::from_secs(600)).expect("backdate");
    drop(f);
    common::kill9(pid0);
    // The supervisor respawns the shard; wait for the NEW worker.
    poll_until(Duration::from_secs(30), "worker respawn", || {
        let stats = admin.stats().ok()?;
        let pids = server.note_pids(&stats);
        match pids.first().copied().flatten() {
            Some(p) if p != pid0 => Some(()),
            _ => None,
        }
    });
    wait_workers_up(&mut admin, 1, Duration::from_secs(30));
    // Startup sweep: the torn tmp is gone; tmp-then-rename means every
    // surviving `.snap` is a complete old snapshot (old-or-none).
    poll_until(Duration::from_secs(10), "startup sweep of the torn tmp", || {
        (!torn.exists()).then_some(())
    });
    let mut snaps = 0;
    for entry in std::fs::read_dir(&dir).expect("spill dir") {
        let path = entry.expect("dir entry").path();
        if path.extension().is_some_and(|e| e == "snap") {
            let bytes = std::fs::read(&path).expect("read snapshot");
            SessionSnapshot::decode(&bytes).expect("every surviving snapshot decodes cleanly");
            snaps += 1;
        }
    }
    assert_eq!(snaps, SESSIONS, "the kill must not have destroyed completed spills");
    // Every session rehydrates from the predecessor's spill dir and
    // resumes at its pre-kill time step — Mem(t) survived the crash.
    for id in &ids {
        let ack = poll_until(Duration::from_secs(10), "context served after respawn", || {
            let mut c = Client::connect(server.addr()).ok()?;
            let resp = c.add_context(id, &[1]).ok()?;
            (resp.opt("ok") == Some(&Json::Bool(true))).then_some(resp)
        });
        assert_eq!(ack_t(&ack), 3, "{id}: must resume at the pre-kill time step");
    }
    let stats = admin.stats().expect("stats");
    assert!(stat(&stats, "rehydrations") >= SESSIONS);
    assert_eq!(stat(&stats, "snapshot_corrupt"), 0);
    server.shutdown_join();
    let _ = std::fs::remove_dir_all(&root);
}
