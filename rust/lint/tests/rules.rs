//! Fixture tests for every ccm-lint rule: each fires at the right
//! file:line, and the documented annotation (`// SAFETY:` /
//! `// lint: allow(...)` / `// ordering:`) suppresses it. Paths matter:
//! the unwrap and lock-across-I/O rules are scoped to the serving core,
//! and `poll.rs` is exempt from the raw-fd rule.

use ccm_lint::lint_source;

const CORE: &str = "rust/src/server/fixture.rs";

fn rules_at(file: &str, src: &str) -> Vec<(usize, &'static str)> {
    lint_source(file, src).into_iter().map(|f| (f.line, f.rule)).collect()
}

#[test]
fn safety_rule_fires_on_bare_unsafe_and_accepts_the_comment() {
    let bare = "fn f() {\n    unsafe { g() };\n}\n";
    assert_eq!(rules_at("rust/src/util/x.rs", bare), vec![(2, ccm_lint::RULE_SAFETY)]);

    let commented = "fn f() {\n    // SAFETY: g has no preconditions.\n    unsafe { g() };\n}\n";
    assert_eq!(rules_at("rust/src/util/x.rs", commented), vec![]);

    // A blank line between comment and block breaks the adjacency.
    let gapped = "fn f() {\n    // SAFETY: stale.\n\n    unsafe { g() };\n}\n";
    assert_eq!(rules_at("rust/src/util/x.rs", gapped), vec![(4, ccm_lint::RULE_SAFETY)]);

    // `unsafe` inside strings or comments is not code.
    let quoted = "fn f() {\n    let s = \"unsafe { }\"; // unsafe in prose\n}\n";
    assert_eq!(rules_at("rust/src/util/x.rs", quoted), vec![]);
}

#[test]
fn unwrap_rule_is_scoped_to_the_serving_core() {
    let src = "fn f() {\n    x().unwrap();\n}\n";
    assert_eq!(rules_at(CORE, src), vec![(2, ccm_lint::RULE_UNWRAP)]);
    assert_eq!(rules_at("rust/src/coordinator/b.rs", src), vec![(2, ccm_lint::RULE_UNWRAP)]);
    // Outside the serving core the same code passes.
    assert_eq!(rules_at("rust/src/util/x.rs", src), vec![]);
    // And test modules inside core files are exempt.
    let in_tests = "#[cfg(test)]\nmod tests {\n    fn f() {\n        x().unwrap();\n    }\n}\n";
    assert_eq!(rules_at(CORE, in_tests), vec![]);
}

#[test]
fn unwrap_rule_accepts_the_allow_annotation_and_lock_idiom() {
    let allowed =
        "fn f() {\n    // lint: allow(unwrap) — checked two lines up.\n    x().unwrap();\n}\n";
    assert_eq!(rules_at(CORE, allowed), vec![]);

    let expect = "fn f() {\n    x().expect(\"always\");\n}\n";
    assert_eq!(rules_at(CORE, expect), vec![(2, ccm_lint::RULE_UNWRAP)]);

    // Mutex poisoning propagation is policy, not a lint finding.
    let lock = "fn f() {\n    let g = m.lock().unwrap();\n    drop(g);\n}\n";
    assert_eq!(rules_at(CORE, lock), vec![]);
}

#[test]
fn lock_across_io_rule_tracks_the_guard_scope() {
    let held = "fn f() {\n    let g = m.lock().unwrap();\n    s.write_all(b\"x\");\n}\n";
    assert_eq!(rules_at(CORE, held), vec![(3, ccm_lint::RULE_LOCK_IO)]);

    // An explicit drop before the I/O ends the tracked scope.
    let dropped =
        "fn f() {\n    let g = m.lock().unwrap();\n    drop(g);\n    s.write_all(b\"x\");\n}\n";
    assert_eq!(rules_at(CORE, dropped), vec![]);

    // The guard's block ending releases it too.
    let scoped =
        "fn f() {\n    {\n        let g = m.lock().unwrap();\n    }\n    s.write_all(b\"x\");\n}\n";
    assert_eq!(rules_at(CORE, scoped), vec![]);

    // A projected guard dies at its own statement: not tracked.
    let projected =
        "fn f() {\n    let v = std::mem::take(&mut *m.lock().unwrap());\n    s.write_all(&v);\n}\n";
    assert_eq!(rules_at(CORE, projected), vec![]);

    // The annotation acknowledges a deliberate hold.
    let allowed = "fn f() {\n    let g = m.lock().unwrap();\n    \
                   // lint: allow(lock_io) — single-threaded setup path.\n    \
                   s.write_all(b\"x\");\n}\n";
    assert_eq!(rules_at(CORE, allowed), vec![]);
}

#[test]
fn raw_fd_rule_confines_syscalls_to_poll_rs() {
    let call = "fn f() {\n    let fd = socket(2, 1, 0);\n}\n";
    assert_eq!(rules_at("rust/src/server/reactor.rs", call), vec![(2, ccm_lint::RULE_RAW_FD)]);
    // poll.rs IS the RAII boundary the rule protects.
    assert_eq!(rules_at("rust/src/server/poll.rs", call), vec![]);

    // Qualified paths and method calls are std wrappers, not raw fds.
    let wrapped = "fn f() {\n    let l = TcpListener::bind(addr);\n    sock.bind(addr);\n}\n";
    assert_eq!(rules_at("rust/src/server/reactor.rs", wrapped), vec![]);

    // An extern declaration outside poll.rs is a finding; an ordinary
    // local function that shares a name is not.
    let decl = "extern \"C\" {\n    fn bind(fd: i32) -> i32;\n}\n";
    assert_eq!(rules_at("rust/src/server/reactor.rs", decl), vec![(2, ccm_lint::RULE_RAW_FD)]);
    let local = "fn listen(port: u16) -> u16 {\n    port\n}\n";
    assert_eq!(rules_at("rust/src/server/reactor.rs", local), vec![]);

    // `writev` (the gathered-write path) is confined like the rest:
    // both the call and the extern declaration fire outside poll.rs,
    // and a local fn sharing the name does not.
    let gather = "fn f() {\n    let rc = writev(fd, iov.as_ptr(), iov.len() as i32);\n}\n";
    assert_eq!(rules_at("rust/src/server/ipc.rs", gather), vec![(2, ccm_lint::RULE_RAW_FD)]);
    assert_eq!(rules_at("rust/src/server/poll.rs", gather), vec![]);
    let gather_decl = "extern \"C\" {\n    fn writev(fd: i32, iov: *const IoVec) -> isize;\n}\n";
    assert_eq!(rules_at("rust/src/server/ipc.rs", gather_decl), vec![(2, ccm_lint::RULE_RAW_FD)]);
    let gather_local = "fn writev(bufs: &[Vec<u8>]) -> usize {\n    bufs.len()\n}\n";
    assert_eq!(rules_at("rust/src/server/worker.rs", gather_local), vec![]);
}

#[test]
fn relaxed_ordering_rule_wants_a_justification_outside_counters() {
    let bare = "fn f() {\n    let v = a.load(Ordering::Relaxed);\n}\n";
    assert_eq!(rules_at("rust/src/util/x.rs", bare), vec![(2, ccm_lint::RULE_ORDERING)]);

    let justified = "fn f() {\n    let v = a.load(Ordering::Relaxed); // ordering: stats only\n}\n";
    assert_eq!(rules_at("rust/src/util/x.rs", justified), vec![]);

    // Monotonic counter bumps are Relaxed by policy.
    let counter = "fn f() {\n    a.fetch_add(1, Ordering::Relaxed);\n}\n";
    assert_eq!(rules_at("rust/src/util/x.rs", counter), vec![]);
}

#[test]
fn set_var_rule_has_no_exemptions() {
    let src =
        "#[cfg(test)]\nmod t {\n    fn f() {\n        env::set_var(\"A\", \"1\");\n    }\n}\n";
    assert_eq!(rules_at("rust/tests/t.rs", src), vec![(4, ccm_lint::RULE_SET_VAR)]);
    // Prose mentions in comments are fine.
    let prose = "// callers must not use set_var for this\nfn f() {}\n";
    assert_eq!(rules_at("rust/tests/t.rs", prose), vec![]);
}

#[test]
fn findings_render_file_line_and_rule_id() {
    let src = "fn f() {\n    unsafe { g() };\n}\n";
    let findings = lint_source("rust/src/util/x.rs", src);
    assert_eq!(findings.len(), 1);
    let line = findings[0].to_string();
    assert!(line.starts_with("rust/src/util/x.rs:2: [safety-comment]"), "{line}");
}
