//! `ccm-lint` — a zero-dependency invariant linter for the ccm serving
//! core.
//!
//! rustc and clippy cannot express the repo-specific contracts this
//! codebase leans on: a `// SAFETY:` comment on every `unsafe`, no
//! stray `unwrap` on live-traffic paths, no `MutexGuard` held across
//! blocking socket I/O, raw fd syscalls confined to `poll.rs`,
//! justified `Ordering::Relaxed`, and no `std::env::set_var` anywhere
//! near the test suites. This crate checks them the same way `poll.rs`
//! does syscalls: by hand, with no dependencies, so the linter can
//! never be the thing that breaks the offline build.
//!
//! [`lex`] splits a file into per-line views with comment and
//! string/char literal bodies removed (so token scans cannot match
//! inside a string) while keeping every comment's text for the
//! annotation checks; the rules in [`lint_source`] operate on that
//! view. The rule catalogue, rationale, and allow-list syntax live in
//! `docs/INVARIANTS.md`. Run as:
//!
//! ```text
//! cargo run -p ccm-lint -- rust/src rust/tests examples
//! ```

use std::fmt;

/// Rule 1: every `unsafe` needs an immediately preceding `// SAFETY:`.
pub const RULE_SAFETY: &str = "safety-comment";
/// Rule 2: no `.unwrap()`/`.expect()` on serving-core paths.
pub const RULE_UNWRAP: &str = "unwrap";
/// Rule 3: no `MutexGuard` held lexically across blocking I/O.
pub const RULE_LOCK_IO: &str = "lock-across-io";
/// Rule 4: raw fd/socket syscalls only in `poll.rs`.
pub const RULE_RAW_FD: &str = "raw-fd-outside-poll";
/// Rule 5: `Ordering::Relaxed` outside counter bumps needs a reason.
pub const RULE_ORDERING: &str = "relaxed-ordering";
/// Rule 6: `std::env::set_var` is banned (process-global, UB with
/// concurrent test threads).
pub const RULE_SET_VAR: &str = "env-set-var";

/// One rule violation, rendered as `file:line: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

// ---------------------------------------------------------------------
// Lexer: split source into parallel per-line code / comment views.

/// A source file split into parallel per-line views: `code[i]` is line
/// `i` with comments removed and string/char literal bodies blanked
/// (quotes kept), `comments[i]` is the concatenated text of every
/// comment overlapping line `i`.
pub struct FileView {
    pub code: Vec<String>,
    pub comments: Vec<String>,
}

fn newline(code: &mut Vec<String>, comments: &mut Vec<String>) {
    code.push(String::new());
    comments.push(String::new());
}

fn push_ascii(dst: &mut String, c: u8) {
    dst.push(if c.is_ascii() { c as char } else { ' ' });
}

/// Tokenize `src` into a [`FileView`], understanding line comments,
/// nested block comments, string / byte-string / raw-string literals,
/// char and byte-char literals, and lifetimes.
pub fn lex(src: &str) -> FileView {
    let b = src.as_bytes();
    let n = b.len();
    let mut code = vec![String::new()];
    let mut comments = vec![String::new()];
    let mut i = 0usize;
    // True when the previous code byte could end an identifier: an `r`
    // there is part of a name, not a raw-string prefix.
    let mut prev_ident = false;
    while i < n {
        match b[i] {
            b'\n' => {
                newline(&mut code, &mut comments);
                prev_ident = false;
                i += 1;
            }
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                let start = i;
                while i < n && b[i] != b'\n' {
                    i += 1;
                }
                for &c in &b[start..i] {
                    push_ascii(comments.last_mut().expect("line"), c);
                }
                code.last_mut().expect("line").push(' ');
                prev_ident = false;
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                i += 2;
                let mut depth = 1usize;
                while i < n && depth > 0 {
                    if b[i] == b'\n' {
                        newline(&mut code, &mut comments);
                        i += 1;
                    } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else {
                        push_ascii(comments.last_mut().expect("line"), b[i]);
                        i += 1;
                    }
                }
                code.last_mut().expect("line").push(' ');
                prev_ident = false;
            }
            b'"' => {
                i = consume_string(b, i, &mut code, &mut comments);
                prev_ident = false;
            }
            b'r' | b'b' if !prev_ident && is_raw_string_start(b, i) => {
                i = consume_raw_string(b, i, &mut code, &mut comments);
                prev_ident = false;
            }
            b'\'' => {
                let escaped = i + 1 < n && b[i + 1] == b'\\';
                let delimited = i + 2 < n && b[i + 1] != b'\'' && b[i + 2] == b'\'';
                if escaped || delimited {
                    code.last_mut().expect("line").push_str("''");
                    i += 1;
                    while i < n {
                        match b[i] {
                            b'\\' if i + 1 < n => i += 2,
                            b'\'' => {
                                i += 1;
                                break;
                            }
                            b'\n' => break,
                            _ => i += 1,
                        }
                    }
                } else {
                    // A lifetime: keep the tick, the name flows as code.
                    code.last_mut().expect("line").push('\'');
                    i += 1;
                }
                prev_ident = false;
            }
            c => {
                push_ascii(code.last_mut().expect("line"), c);
                prev_ident = c.is_ascii_alphanumeric() || c == b'_';
                i += 1;
            }
        }
    }
    FileView { code, comments }
}

/// Consume a `"..."` literal starting at the opening quote; returns the
/// index just past the closing quote. Bodies are dropped from the code
/// view; `\`-newline continuations and multi-line strings keep the line
/// count honest.
fn consume_string(
    b: &[u8],
    mut i: usize,
    code: &mut Vec<String>,
    comments: &mut Vec<String>,
) -> usize {
    code.last_mut().expect("line").push('"');
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' if i + 1 < b.len() => {
                if b[i + 1] == b'\n' {
                    newline(code, comments);
                }
                i += 2;
            }
            b'"' => {
                code.last_mut().expect("line").push('"');
                return i + 1;
            }
            b'\n' => {
                newline(code, comments);
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

fn is_raw_string_start(b: &[u8], i: usize) -> bool {
    let mut j = i + 1;
    if b[i] == b'b' {
        if j >= b.len() || b[j] != b'r' {
            return false;
        }
        j += 1;
    }
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    j < b.len() && b[j] == b'"'
}

/// Consume `r"..."` / `r#"..."#` / `br#"..."#` starting at the `r`/`b`;
/// returns the index just past the closing delimiter.
fn consume_raw_string(
    b: &[u8],
    mut i: usize,
    code: &mut Vec<String>,
    comments: &mut Vec<String>,
) -> usize {
    if b[i] == b'b' {
        code.last_mut().expect("line").push('b');
        i += 1;
    }
    code.last_mut().expect("line").push('r');
    i += 1;
    let mut hashes = 0usize;
    while i < b.len() && b[i] == b'#' {
        code.last_mut().expect("line").push('#');
        hashes += 1;
        i += 1;
    }
    code.last_mut().expect("line").push('"');
    i += 1; // the opening quote
    while i < b.len() {
        if b[i] == b'"' {
            let tail = &b[i + 1..];
            if tail.len() >= hashes && tail[..hashes].iter().all(|&c| c == b'#') {
                code.last_mut().expect("line").push('"');
                return i + 1 + hashes;
            }
        }
        if b[i] == b'\n' {
            newline(code, comments);
        }
        i += 1;
    }
    i
}

// ---------------------------------------------------------------------
// Structural helpers over the code view.

fn is_ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte offsets of whole-word occurrences of `word` in `line`.
fn find_word(line: &str, word: &str) -> Vec<usize> {
    let b = line.as_bytes();
    let mut out = Vec::new();
    let mut start = 0usize;
    while let Some(pos) = line[start..].find(word) {
        let at = start + pos;
        let end = at + word.len();
        let before_ok = at == 0 || !is_ident_byte(b[at - 1]);
        let after_ok = end >= b.len() || !is_ident_byte(b[end]);
        if before_ok && after_ok {
            out.push(at);
        }
        start = at + word.len();
    }
    out
}

/// Running brace depth at the start of each code line.
fn line_depths(code: &[String]) -> Vec<i32> {
    let mut out = Vec::with_capacity(code.len());
    let mut depth = 0i32;
    for line in code {
        out.push(depth);
        for c in line.bytes() {
            match c {
                b'{' => depth += 1,
                b'}' => depth -= 1,
                _ => {}
            }
        }
    }
    out
}

/// Find the `{ ... }` block starting at or after (`line`, `col`);
/// returns its inclusive (start_line, end_line), or `None` when a `;`
/// ends the item before any block opens.
fn brace_block_after(code: &[String], line: usize, col: usize) -> Option<(usize, usize)> {
    let mut depth = 0i32;
    let mut started = false;
    let mut start_line = line;
    let mut l = line;
    let mut c = col;
    while l < code.len() {
        let bytes = code[l].as_bytes();
        while c < bytes.len() {
            match bytes[c] {
                b'{' => {
                    if !started {
                        started = true;
                        start_line = l;
                    }
                    depth += 1;
                }
                b'}' if started => {
                    depth -= 1;
                    if depth == 0 {
                        return Some((start_line, l));
                    }
                }
                b';' if !started => return None,
                _ => {}
            }
            c += 1;
        }
        l += 1;
        c = 0;
    }
    None
}

/// Inclusive line ranges covered by `#[cfg(test)]` items (the brace
/// block following the attribute, attribute line included).
pub fn test_regions(code: &[String]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for (i, line) in code.iter().enumerate() {
        let Some(at) = line.find("#[cfg(test)]") else { continue };
        if let Some((_, end)) = brace_block_after(code, i, at) {
            out.push((i, end));
        }
    }
    out
}

/// Inclusive line ranges of `extern "..." { ... }` blocks.
fn extern_regions(code: &[String]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for (i, line) in code.iter().enumerate() {
        for at in find_word(line, "extern") {
            if let Some(r) = brace_block_after(code, i, at) {
                out.push(r);
            }
        }
    }
    out
}

fn in_regions(line: usize, regions: &[(usize, usize)]) -> bool {
    regions.iter().any(|&(s, e)| line >= s && line <= e)
}

/// True when `needle` appears in a comment on line `i` or in the
/// contiguous run of comment-only lines directly above it (no blank
/// line or code line may intervene).
fn annotated(view: &FileView, i: usize, needle: &str) -> bool {
    if view.comments[i].contains(needle) {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let comment_only = !view.comments[j].is_empty() && view.code[j].trim().is_empty();
        if !comment_only {
            return false;
        }
        if view.comments[j].contains(needle) {
            return true;
        }
    }
    false
}

fn finding(file: &str, line: usize, rule: &'static str, msg: String) -> Finding {
    Finding { file: file.to_string(), line: line + 1, rule, msg }
}

// ---------------------------------------------------------------------
// Rules.

fn rule_safety(file: &str, view: &FileView, out: &mut Vec<Finding>) {
    for (i, line) in view.code.iter().enumerate() {
        if find_word(line, "unsafe").is_empty() || annotated(view, i, "SAFETY:") {
            continue;
        }
        out.push(finding(
            file,
            i,
            RULE_SAFETY,
            "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string(),
        ));
    }
}

fn rule_unwrap(file: &str, view: &FileView, tests: &[(usize, usize)], out: &mut Vec<Finding>) {
    for (i, line) in view.code.iter().enumerate() {
        if in_regions(i, tests) {
            continue;
        }
        let mut hit = false;
        for pat in [".unwrap()", ".expect("] {
            let mut start = 0usize;
            while let Some(pos) = line[start..].find(pat) {
                let at = start + pos;
                // Mutex/RwLock poisoning propagation is policy (a
                // poisoned lock means a holder already panicked): the
                // idiom `.lock().unwrap()` is exempt.
                if !line[..at].ends_with(".lock()") {
                    hit = true;
                }
                start = at + pat.len();
            }
        }
        if !hit || annotated(view, i, "lint: allow(unwrap)") {
            continue;
        }
        out.push(finding(
            file,
            i,
            RULE_UNWRAP,
            "`.unwrap()`/`.expect()` on a serving path; return an error reply or annotate \
             `// lint: allow(unwrap) — <why this cannot fail / why dying is right>`"
                .to_string(),
        ));
    }
}

const BLOCKING_IO: [&str; 4] = [".write_all(", ".read(", ".connect(", ".accept("];

fn rule_lock_io(
    file: &str,
    view: &FileView,
    tests: &[(usize, usize)],
    depths: &[i32],
    out: &mut Vec<Finding>,
) {
    for (i, line) in view.code.iter().enumerate() {
        if in_regions(i, tests) {
            continue;
        }
        let Some(ident) = guard_binding(line) else { continue };
        if annotated(view, i, "lint: allow(lock_io)") {
            continue;
        }
        let d0 = depths[i];
        let mut j = i;
        loop {
            let code = &view.code[j];
            for pat in BLOCKING_IO {
                if code.contains(pat) && !annotated(view, j, "lint: allow(lock_io)") {
                    let call = pat.trim_start_matches('.').trim_end_matches('(');
                    out.push(finding(
                        file,
                        j,
                        RULE_LOCK_IO,
                        format!(
                            "blocking I/O `{call}` while MutexGuard `{ident}` (line {}) is \
                             held; drop the guard first or annotate `// lint: allow(lock_io) \
                             — <reason>`",
                            i + 1
                        ),
                    ));
                }
            }
            if code.contains(&format!("drop({ident})")) {
                break;
            }
            j += 1;
            if j >= view.code.len() || depths[j] < d0 {
                break;
            }
        }
    }
}

/// `Some(name)` when `line` is a `let` statement that binds a
/// `MutexGuard` for the rest of its block: the initializer ends in
/// `.lock()` or `.lock().unwrap()`. A projected guard (for example
/// `*m.lock().unwrap() = x`, or `take(&mut *m.lock().unwrap())`) dies
/// at the end of its own statement and is not tracked.
fn guard_binding(line: &str) -> Option<&str> {
    let t = line.trim();
    let rest = t.strip_prefix("let ")?;
    let init = t.trim_end_matches(';').trim_end();
    if !init.ends_with(".lock()") && !init.ends_with(".lock().unwrap()") {
        return None;
    }
    let rest = rest.trim_start();
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let rest = rest.strip_prefix('(').unwrap_or(rest);
    let end = rest.find(|c: char| !c.is_alphanumeric() && c != '_').unwrap_or(rest.len());
    if end == 0 {
        return None;
    }
    Some(&rest[..end])
}

/// The raw symbols `poll.rs` owns. `close`/`read`/`write` are left out:
/// as whole words they collide with ordinary method names everywhere,
/// and every call site outside `poll.rs` goes through `std` wrappers
/// that own their fds anyway.
const RAW_FD_CALLS: [&str; 9] = [
    "socket",
    "bind",
    "setsockopt",
    "listen",
    "epoll_create1",
    "epoll_ctl",
    "epoll_wait",
    "eventfd",
    "writev",
];

fn rule_raw_fd(file: &str, view: &FileView, externs: &[(usize, usize)], out: &mut Vec<Finding>) {
    for (i, line) in view.code.iter().enumerate() {
        let bytes = line.as_bytes();
        for name in RAW_FD_CALLS {
            for at in find_word(line, name) {
                if bytes.get(at + name.len()) != Some(&b'(') {
                    continue; // not a call or declaration
                }
                let before = line[..at].trim_end();
                if before.ends_with('.') || before.ends_with(':') {
                    continue; // method call or qualified path, not the raw symbol
                }
                let fn_decl = before.ends_with("fn")
                    && (before.len() == 2 || !is_ident_byte(before.as_bytes()[before.len() - 3]));
                if fn_decl && !in_regions(i, externs) {
                    continue; // an ordinary function sharing the name
                }
                out.push(finding(
                    file,
                    i,
                    RULE_RAW_FD,
                    format!(
                        "raw fd/socket symbol `{name}` outside `poll.rs`, the RAII boundary \
                         that owns every raw descriptor"
                    ),
                ));
            }
        }
    }
}

fn rule_ordering(file: &str, view: &FileView, tests: &[(usize, usize)], out: &mut Vec<Finding>) {
    for (i, line) in view.code.iter().enumerate() {
        if in_regions(i, tests) || !line.contains("Ordering::Relaxed") {
            continue;
        }
        if line.contains("fetch_add(") || line.contains("fetch_sub(") {
            continue; // monotonic counter bumps are Relaxed by policy
        }
        if annotated(view, i, "ordering:") {
            continue;
        }
        out.push(finding(
            file,
            i,
            RULE_ORDERING,
            "`Ordering::Relaxed` outside a counter bump needs an `// ordering: <why relaxed \
             is sound here>` justification"
                .to_string(),
        ));
    }
}

fn rule_set_var(file: &str, view: &FileView, out: &mut Vec<Finding>) {
    for (i, line) in view.code.iter().enumerate() {
        if find_word(line, "set_var").is_empty() {
            continue;
        }
        out.push(finding(
            file,
            i,
            RULE_SET_VAR,
            "`std::env::set_var` is process-global and UB with concurrent test threads; \
             pass configuration explicitly instead"
                .to_string(),
        ));
    }
}

// ---------------------------------------------------------------------
// Entry point.

fn is_core_path(file: &str) -> bool {
    let f = file.replace('\\', "/");
    f.contains("src/server/")
        || f.contains("src/coordinator/")
        || f.contains("src/model/")
        // The per-session strategy seam (CompressionStrategy impls and
        // tier configs) sits directly on the admission/batch hot path.
        || f.contains("src/compress/")
        // The loadgen user hot loop runs thousands of concurrent
        // synthetic-user threads against live servers; a stray unwrap
        // there kills a whole user's replay mid-run.
        || f.contains("src/bench/loadgen.rs")
}

fn is_poll_rs(file: &str) -> bool {
    std::path::Path::new(file).file_name().is_some_and(|n| n == "poll.rs")
}

/// Lint one file's source text. `file` is used both for reporting and
/// for the path-scoped rules: the unwrap and lock-across-I/O rules
/// police only live-traffic paths (`src/server/`, `src/coordinator/`,
/// `src/model/`, `src/compress/`, and the `src/bench/loadgen.rs`
/// replay hot loop), and
/// `poll.rs` is exempt from the raw-fd rule because it IS the RAII
/// boundary the rule protects.
pub fn lint_source(file: &str, src: &str) -> Vec<Finding> {
    let view = lex(src);
    let tests = test_regions(&view.code);
    let externs = extern_regions(&view.code);
    let depths = line_depths(&view.code);
    let mut out = Vec::new();
    rule_safety(file, &view, &mut out);
    if is_core_path(file) {
        rule_unwrap(file, &view, &tests, &mut out);
        rule_lock_io(file, &view, &tests, &depths, &mut out);
    }
    if !is_poll_rs(file) {
        rule_raw_fd(file, &view, &externs, &mut out);
    }
    rule_ordering(file, &view, &tests, &mut out);
    rule_set_var(file, &view, &mut out);
    out.sort_by_key(|f| f.line);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexer_blanks_strings_and_keeps_comments() {
        let view = lex("let a = \"unsafe { }\"; // SAFETY: not really\nb();\n");
        assert!(view.code[0].contains("let a"));
        assert!(!view.code[0].contains("unsafe"));
        assert!(view.comments[0].contains("SAFETY:"));
        assert_eq!(view.code[1].trim(), "b();");
    }

    #[test]
    fn lexer_handles_raw_strings_and_char_literals() {
        let view = lex("let r = r#\"socket( \"# ; let c = '{'; let l: &'static str = \"x\";\n");
        assert!(!view.code[0].contains("socket"));
        // The `{` inside a char literal must not skew the running brace
        // depth carried into the next line.
        assert_eq!(line_depths(&view.code)[1], 0);
        assert!(view.code[0].contains("'static"));
    }

    #[test]
    fn lexer_tracks_lines_across_string_continuations() {
        let src = "let s = \"a\\\n b\";\nsecond();\n";
        let view = lex(src);
        assert_eq!(view.code.len(), 4); // 3 lines + trailing empty
        assert_eq!(view.code[2].trim(), "second();");
    }

    #[test]
    fn cfg_test_region_covers_the_whole_mod() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}\n";
        let view = lex(src);
        let regions = test_regions(&view.code);
        assert_eq!(regions, vec![(1, 4)]);
    }
}
