//! CLI for `ccm-lint`: lint every `.rs` file under the given paths and
//! exit non-zero if any serving-core invariant is violated.
//!
//! CI runs `cargo run -p ccm-lint -- rust/src rust/tests examples` from
//! the workspace root as a hard gate next to fmt and clippy; the rule
//! catalogue lives in `docs/INVARIANTS.md`.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if std::fs::metadata(path)?.is_dir() {
        for entry in std::fs::read_dir(path)? {
            collect_rs(&entry?.path(), out)?;
        }
    } else if path.extension().is_some_and(|e| e == "rs") {
        out.push(path.to_path_buf());
    }
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!("usage: ccm-lint <file-or-dir>...");
        return ExitCode::from(2);
    }
    let mut files: Vec<PathBuf> = Vec::new();
    for arg in &args {
        if let Err(e) = collect_rs(Path::new(arg), &mut files) {
            eprintln!("ccm-lint: {arg}: {e}");
            return ExitCode::from(2);
        }
    }
    files.sort();
    files.dedup();
    let mut findings = Vec::new();
    for file in &files {
        let display = file.display().to_string();
        match std::fs::read_to_string(file) {
            Ok(src) => findings.extend(ccm_lint::lint_source(&display, &src)),
            Err(e) => {
                eprintln!("ccm-lint: {display}: {e}");
                return ExitCode::from(2);
            }
        }
    }
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        eprintln!("ccm-lint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("ccm-lint: {} finding(s) across {} files", findings.len(), files.len());
        ExitCode::FAILURE
    }
}
