//! Offline stub of the `xla` crate (xla-rs over xla_extension 0.5.1).
//!
//! The real crate links the XLA C++ runtime, which is not available in
//! this build environment. This stub mirrors the API surface used by
//! `ccm::runtime` so the crate compiles and all host-side paths (masks,
//! batcher, sessions, server protocol, datagen, eval bookkeeping) run;
//! any attempt to load or execute an AOT artifact returns a clear
//! "backend unavailable" error, which callers treat as "artifacts
//! missing" and skip gracefully.
//!
//! To run against real artifacts, replace this path dependency with the
//! real `xla` crate (it is API-compatible: same types, same methods).

use std::fmt;

/// Error type matching the real crate's `Error` role; implements
/// `std::error::Error` so it converts into `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct XlaError {
    msg: String,
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError {
        msg: format!(
            "xla backend unavailable in this offline build: {what} \
             (swap rust/vendor/xla for the real xla crate to execute artifacts)"
        ),
    }
}

/// Parsed HLO module (stub: never constructible from disk).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("cannot parse HLO text {path:?}")))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// PJRT client handle (stub: constructible, cannot compile).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }
}

/// Compiled executable handle (stub: never constructible).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

/// Device buffer handle (stub: never constructible).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Element types a [`Literal`] can hold.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host literal: the only stub type with working behavior (staging
/// literals is pure host work and keeps call sites exercisable).
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }

    fn unwrap(data: &Data) -> Option<Vec<f32>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            Data::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }

    fn unwrap(data: &Data) -> Option<Vec<i32>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            Data::F32(_) => None,
        }
    }
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = match &self.data {
            Data::F32(v) => v.len() as i64,
            Data::I32(v) => v.len() as i64,
        };
        if want != have {
            return Err(unavailable(&format!("reshape {have} elements to {dims:?}")));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable("decompose_tuple"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| unavailable("literal dtype mismatch"))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn execution_paths_report_unavailable() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-stub");
        let lits = [Literal::vec1(&[0i32])];
        let err = PjRtLoadedExecutable { _private: () }.execute::<Literal>(&lits).unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }
}
