//! Offline drop-in subset of the `anyhow` crate.
//!
//! The real crate is unavailable in this build (no registry access), so
//! this shim provides the exact surface the `ccm` crate uses: `Error`,
//! `Result`, the `Context` extension trait for `Result` and `Option`,
//! and the `anyhow!` / `bail!` / `ensure!` macros. Error values carry an
//! outermost-first chain of messages; `{}` prints the outermost frame,
//! `{:#}` joins the chain with `": "` (matching anyhow's alternate
//! formatting), and `{:?}` prints the anyhow-style "Caused by:" report.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost-first chain of message frames.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message (root of the chain).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.chain[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: any std error converts, collecting its source
// chain. `Error` itself does not implement `std::error::Error`, so this
// blanket impl does not overlap the reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err()).context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros_work() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero");
        assert_eq!(f(-2).unwrap_err().to_string(), "negative -2");
        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(5).with_context(|| "unused").unwrap(), 5);
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
