//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the serving/training hot paths.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU). Executables are
//! compiled lazily on first use and cached for the process lifetime; the
//! signature from the manifest is validated against every call in debug
//! builds so shape bugs surface at the boundary, not inside XLA.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::model::manifest::{ArtifactSig, Manifest};
use crate::tensor::{IntTensor, Tensor};

/// Host value staged into an artifact call.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl Value {
    pub fn scalar_f32(v: f32) -> Value {
        Value::F32(Tensor::scalar(v))
    }

    pub fn scalar_i32(v: i32) -> Value {
        Value::I32(IntTensor::scalar(v))
    }

    pub fn vec_f32(shape: &[usize], data: Vec<f32>) -> Result<Value> {
        Ok(Value::F32(Tensor::from_vec(shape, data)?))
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Value::F32(_) => "float32",
            Value::I32(_) => "int32",
        }
    }

    fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        Ok(match self {
            Value::F32(t) => Literal::vec1(&t.data).reshape(&dims)?,
            Value::I32(t) => Literal::vec1(&t.data).reshape(&dims)?,
        })
    }
}

/// Execution statistics (feeds the coordinator metrics + §Perf numbers).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub calls: HashMap<String, (u64, f64)>, // name -> (count, total_ms)
    pub compile_ms: HashMap<String, f64>,
}

impl RuntimeStats {
    pub fn record(&mut self, name: &str, ms: f64) {
        let e = self.calls.entry(name.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += ms;
    }

    pub fn report(&self) -> Vec<(String, u64, f64)> {
        let mut rows: Vec<_> =
            self.calls.iter().map(|(k, (n, ms))| (k.clone(), *n, ms / *n as f64)).collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }
}

/// The runtime: one PJRT CPU client + compiled-executable cache.
pub struct Runtime {
    pub manifest: Manifest,
    client: PjRtClient,
    executables: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    pub stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Load a runtime for one artifact config directory.
    pub fn load(manifest: Manifest) -> Result<Runtime> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            manifest,
            client,
            executables: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn from_config(config: &str) -> Result<Runtime> {
        let dir = crate::model::artifact_dir(config);
        let manifest = Manifest::load(&dir)?;
        Self::load(manifest)
    }

    /// Compile (or fetch cached) executable for a named artifact.
    pub fn executable(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.executables.borrow().get(name) {
            return Ok(e.clone());
        }
        let sig = self.manifest.artifact(name)?;
        let path = self.manifest.dir.join(&sig.file);
        let t = Instant::now();
        let proto = HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let ms = t.elapsed().as_secs_f64() * 1e3;
        crate::debug!("compiled {name} in {ms:.0} ms");
        self.stats.borrow_mut().compile_ms.insert(name.to_string(), ms);
        let rc = Rc::new(exe);
        self.executables.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Pre-compile a set of artifacts (avoids first-request latency).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute an artifact; returns the decomposed output tuple.
    pub fn execute(&self, name: &str, inputs: &[Value]) -> Result<Vec<Literal>> {
        let sig = self.manifest.artifact(name)?;
        validate_inputs(sig, inputs)?;
        let exe = self.executable(name)?;
        let literals: Vec<Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let t = Instant::now();
        let result = exe.execute::<Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        self.stats.borrow_mut().record(name, t.elapsed().as_secs_f64() * 1e3);
        let mut tuple = tuple;
        Ok(tuple.decompose_tuple()?)
    }

    /// Execute and convert every output to host f32 tensors (casts i32
    /// outputs — none of our artifacts emit integer outputs).
    pub fn execute_f32(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let sig = self.manifest.artifact(name)?;
        let shapes: Vec<Vec<usize>> = sig.outputs.iter().map(|o| o.shape.clone()).collect();
        let outs = self.execute(name, inputs)?;
        let mut tensors = Vec::with_capacity(outs.len());
        for (lit, shape) in outs.iter().zip(shapes) {
            let data = lit.to_vec::<f32>()?;
            tensors.push(Tensor::from_vec(&shape, data)?);
        }
        Ok(tensors)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

fn validate_inputs(sig: &ArtifactSig, inputs: &[Value]) -> Result<()> {
    if inputs.len() != sig.inputs.len() {
        bail!("{}: {} inputs given, signature wants {}", sig.name, inputs.len(), sig.inputs.len());
    }
    for (i, (v, s)) in inputs.iter().zip(&sig.inputs).enumerate() {
        if v.shape() != s.shape.as_slice() {
            bail!(
                "{} input #{i} ({}): shape {:?} != signature {:?}",
                sig.name,
                s.name,
                v.shape(),
                s.shape
            );
        }
        if v.dtype() != s.dtype {
            bail!("{} input #{i} ({}): dtype {} != {}", sig.name, s.name, v.dtype(), s.dtype);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shapes_and_dtypes() {
        let v = Value::scalar_f32(1.0);
        assert_eq!(v.shape(), &[] as &[usize]);
        assert_eq!(v.dtype(), "float32");
        let v = Value::I32(IntTensor::zeros(&[2, 3]));
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.dtype(), "int32");
    }

    #[test]
    fn validate_catches_mismatches() {
        let sig = ArtifactSig {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            inputs: vec![crate::model::manifest::TensorSig {
                name: "x".into(),
                dtype: "float32".into(),
                shape: vec![2],
            }],
            outputs: vec![],
        };
        assert!(validate_inputs(&sig, &[]).is_err());
        let bad_shape = Value::F32(Tensor::zeros(&[3]));
        assert!(validate_inputs(&sig, &[bad_shape]).is_err());
        let bad_dtype = Value::I32(IntTensor::zeros(&[2]));
        assert!(validate_inputs(&sig, &[bad_dtype]).is_err());
        let ok = Value::F32(Tensor::zeros(&[2]));
        assert!(validate_inputs(&sig, &[ok]).is_ok());
    }
}
