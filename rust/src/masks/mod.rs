//! Attention-mask + merge-matrix builders — the Rust mirror of
//! `python/compile/masks.py`.
//!
//! The AOT artifacts take the mask and merge matrix as *inputs*, so the
//! coordinator builds them per batch at serve/train time. Semantics are
//! pinned by the golden vectors in the manifest (`verify_goldens`), which
//! the integration tests run for every artifact config.

use anyhow::{bail, Result};

use crate::model::manifest::MaskGolden;
use crate::tensor::Tensor;

/// Segment kinds (mirror of masks.py constants).
pub const PAD: i32 = 0;
pub const CHUNK: i32 = 1;
pub const COMP: i32 = 2;
pub const INPUT: i32 = 3;

/// Compression method selector (mirror of masks.METHODS).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Full,
    NoContext,
    CcmConcat,
    CcmMerge,
    Gist,
    Compressive,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "full" => Method::Full,
            "nocontext" => Method::NoContext,
            "ccm-concat" => Method::CcmConcat,
            "ccm-merge" => Method::CcmMerge,
            "gist" => Method::Gist,
            "compressive" => Method::Compressive,
            _ => bail!("unknown method {s:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Full => "full",
            Method::NoContext => "nocontext",
            Method::CcmConcat => "ccm-concat",
            Method::CcmMerge => "ccm-merge",
            Method::Gist => "gist",
            Method::Compressive => "compressive",
        }
    }

    /// Does this method insert `<COMP>` tokens into the sequence?
    pub fn uses_comp_tokens(&self) -> bool {
        matches!(self, Method::CcmConcat | Method::CcmMerge | Method::Gist)
    }

    pub const ALL: [Method; 6] = [
        Method::Full,
        Method::NoContext,
        Method::CcmConcat,
        Method::CcmMerge,
        Method::Gist,
        Method::Compressive,
    ];
}

/// Merge-update scheme (paper Section 3.1 + Table 16).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MergeScheme {
    /// Arithmetic average: a_t = 1/t (the paper's main choice).
    Avg,
    /// Exponential moving average with constant a (a_1 = 1).
    Ema(f32),
}

impl MergeScheme {
    pub fn parse(s: &str) -> Result<MergeScheme> {
        if s == "avg" {
            return Ok(MergeScheme::Avg);
        }
        if let Some(a) = s.strip_prefix("ema:") {
            return Ok(MergeScheme::Ema(a.parse()?));
        }
        bail!("unknown merge scheme {s:?}")
    }

    /// Update coefficient a_t at time step t (1-based).
    pub fn coeff(&self, t: usize) -> f32 {
        match self {
            MergeScheme::Avg => 1.0 / t as f32,
            MergeScheme::Ema(a) => {
                if t == 1 {
                    1.0
                } else {
                    *a
                }
            }
        }
    }
}

/// Token-position layout of one packed sample (mirror of masks.Layout).
#[derive(Debug, Clone)]
pub struct Layout {
    pub kind: Vec<i32>,
    pub step: Vec<i32>,
    pub comp_slot: Vec<i32>,
    pub seq: usize,
    pub t: usize,
    pub comp_len: usize,
    pub chunk_lens: Vec<usize>,
    pub input_len: usize,
}

impl Layout {
    pub fn n_tokens(&self) -> usize {
        self.kind.iter().filter(|&&k| k != PAD).count()
    }

    /// First position of the input segment.
    pub fn input_start(&self) -> usize {
        self.kind.iter().position(|&k| k == INPUT).unwrap_or(self.seq)
    }
}

/// Pack chunks (+ <COMP> tokens) and the input into `seq` positions.
pub fn build_layout(
    chunk_lens: &[usize],
    comp_len: usize,
    input_len: usize,
    seq: usize,
) -> Result<Layout> {
    let mut kind = vec![PAD; seq];
    let mut step = vec![0i32; seq];
    let mut comp_slot = vec![0i32; seq];
    let mut pos = 0usize;
    for (j, &clen) in chunk_lens.iter().enumerate() {
        let j = j as i32 + 1;
        if pos + clen + comp_len > seq {
            bail!("layout overflow: chunks need {} > seq {}", pos + clen + comp_len, seq);
        }
        for _ in 0..clen {
            kind[pos] = CHUNK;
            step[pos] = j;
            pos += 1;
        }
        for s in 0..comp_len {
            kind[pos] = COMP;
            step[pos] = j;
            comp_slot[pos] = s as i32 + 1;
            pos += 1;
        }
    }
    if pos + input_len > seq {
        bail!("layout overflow: input needs {} > seq {}", pos + input_len, seq);
    }
    for _ in 0..input_len {
        kind[pos] = INPUT;
        pos += 1;
    }
    Ok(Layout {
        kind,
        step,
        comp_slot,
        seq,
        t: chunk_lens.len(),
        comp_len,
        chunk_lens: chunk_lens.to_vec(),
        input_len,
    })
}

/// Closed-form merge weights w[g][j]: Mem(g) = Σ_{j<=g} w[g][j] h(j).
pub fn merge_weights(t: usize, scheme: MergeScheme) -> Vec<Vec<f32>> {
    let mut w = vec![vec![0.0f32; t + 1]; t + 1];
    for g in 1..=t {
        match scheme {
            MergeScheme::Avg => {
                for j in 1..=g {
                    w[g][j] = 1.0 / g as f32;
                }
            }
            MergeScheme::Ema(a) => {
                for j in 1..=g {
                    let aj = if j == 1 { 1.0 } else { a };
                    w[g][j] = aj * (1.0 - a).powi((g - j) as i32);
                }
            }
        }
    }
    w
}

/// Build (mask [S, M+S], P [M, S]) for one sample. Mirror of
/// masks.build_masks — see that file for the semantics derivation.
pub fn build_masks(
    method: Method,
    lay: &Layout,
    mem_slots: usize,
    scheme: MergeScheme,
    pool: usize,
) -> Result<(Tensor, Tensor)> {
    let (s, m, t, cl) = (lay.seq, mem_slots, lay.t, lay.comp_len);
    let pool = if pool == 0 { cl.max(1) } else { pool };
    let mut mask = Tensor::zeros(&[s, m + s]);
    let mut p = Tensor::zeros(&[m, s]);
    let (kind, step, slot) = (&lay.kind, &lay.step, &lay.comp_slot);

    // --- merge matrix -----------------------------------------------------
    match method {
        Method::CcmMerge => {
            if t * cl > m {
                bail!("merge needs {} slots > {}", t * cl, m);
            }
            let w = merge_weights(t, scheme);
            for g in 1..=t {
                for sp in 1..=cl {
                    let row = (g - 1) * cl + (sp - 1);
                    for j in 1..=g {
                        let src = (0..s)
                            .find(|&i| {
                                kind[i] == COMP && step[i] == j as i32 && slot[i] == sp as i32
                            })
                            .ok_or_else(|| anyhow::anyhow!("missing comp ({j},{sp})"))?;
                        p.set(&[row, src], w[g][j]);
                    }
                }
            }
        }
        Method::Compressive => {
            if t * pool > m {
                bail!("compressive needs {} slots > {}", t * pool, m);
            }
            for g in 1..=t {
                let src: Vec<usize> =
                    (0..s).filter(|&i| kind[i] == CHUNK && step[i] == g as i32).collect();
                let windows = split_windows(&src, pool.min(src.len()));
                for (wi, wnd) in windows.iter().enumerate() {
                    let row = (g - 1) * pool + wi;
                    for &c in wnd {
                        p.set(&[row, c], 1.0 / wnd.len() as f32);
                    }
                }
            }
        }
        _ => {}
    }

    // Live compressive slots (short chunks fill fewer than `pool`).
    let live: Vec<bool> = (0..m).map(|r| p.row(&[r]).iter().any(|&x| x != 0.0)).collect();

    // --- attention mask ----------------------------------------------------
    for i in 0..s {
        let k = kind[i];
        if k == PAD {
            mask.set(&[i, m + i], 1.0); // inert but keeps softmax finite
            continue;
        }
        let j = step[i] as usize;
        let allow_tok = |mask: &mut Tensor, pred: &dyn Fn(usize) -> bool| {
            for c in 0..s {
                if pred(c) {
                    mask.set(&[i, m + c], 1.0);
                }
            }
        };
        let self_causal = |c: usize| kind[c] == k && step[c] == step[i] && c <= i;
        match method {
            Method::Full => {
                allow_tok(&mut mask, &|c| kind[c] != PAD && c <= i);
            }
            Method::NoContext => {
                if k == INPUT {
                    allow_tok(&mut mask, &|c| kind[c] == INPUT && c <= i);
                } else {
                    mask.set(&[i, m + i], 1.0);
                }
            }
            Method::CcmConcat => {
                allow_tok(&mut mask, &self_causal);
                if k == COMP {
                    allow_tok(&mut mask, &|c| kind[c] == CHUNK && step[c] == j as i32 && c <= i);
                    allow_tok(&mut mask, &|c| kind[c] == COMP && (step[c] as usize) < j);
                } else if k == CHUNK {
                    allow_tok(&mut mask, &|c| kind[c] == COMP && (step[c] as usize) < j);
                } else {
                    allow_tok(&mut mask, &|c| kind[c] == COMP && (step[c] as usize) <= t);
                }
            }
            Method::CcmMerge => {
                allow_tok(&mut mask, &self_causal);
                let group = |mask: &mut Tensor, g: usize| {
                    for c in (g - 1) * cl..g * cl {
                        mask.set(&[i, c], 1.0);
                    }
                };
                if k == COMP {
                    allow_tok(&mut mask, &|c| kind[c] == CHUNK && step[c] == j as i32 && c <= i);
                    if j >= 2 {
                        group(&mut mask, j - 1);
                    }
                } else if k == CHUNK {
                    if j >= 2 {
                        group(&mut mask, j - 1);
                    }
                } else if t >= 1 {
                    group(&mut mask, t);
                }
            }
            Method::Gist => {
                allow_tok(&mut mask, &self_causal);
                if k == COMP {
                    allow_tok(&mut mask, &|c| kind[c] == CHUNK && step[c] == j as i32 && c <= i);
                } else if k == INPUT {
                    allow_tok(&mut mask, &|c| kind[c] == COMP && (step[c] as usize) <= t);
                }
            }
            Method::Compressive => {
                allow_tok(&mut mask, &self_causal);
                let groups = |mask: &mut Tensor, upto: usize| {
                    for g in 1..=upto {
                        for c in (g - 1) * pool..g * pool {
                            if live[c] {
                                mask.set(&[i, c], 1.0);
                            }
                        }
                    }
                };
                if k == CHUNK && j >= 2 {
                    groups(&mut mask, j - 1);
                } else if k == INPUT {
                    groups(&mut mask, t);
                }
            }
        }
    }
    Ok((mask, p))
}

fn split_windows(src: &[usize], n: usize) -> Vec<Vec<usize>> {
    // Mirror of numpy.array_split: first (len % n) windows get one extra.
    if n == 0 || src.is_empty() {
        return vec![];
    }
    let base = src.len() / n;
    let extra = src.len() % n;
    let mut out = Vec::with_capacity(n);
    let mut i = 0;
    for w in 0..n {
        let len = base + usize::from(w < extra);
        out.push(src[i..i + len].to_vec());
        i += len;
    }
    out
}

/// LoRA gate vector (1.0 where the conditional adapter fires).
pub fn lora_gate(lay: &Layout, conditional: bool) -> Vec<f32> {
    lay.kind
        .iter()
        .map(|&k| {
            if conditional {
                f32::from(k == COMP)
            } else {
                f32::from(k != PAD)
            }
        })
        .collect()
}

/// comp_slot input vector (0 = normal token, k>=1 = `<COMP>` slot k).
pub fn comp_slot_input(lay: &Layout) -> Vec<i32> {
    lay.comp_slot.clone()
}

/// Absolute position ids over the packed layout.
pub fn position_ids(lay: &Layout) -> Vec<i32> {
    (0..lay.seq as i32).collect()
}

/// Loss mask marking the last `target_len` input positions.
pub fn loss_mask_for_target(lay: &Layout, target_len: usize) -> Result<Vec<f32>> {
    let inputs: Vec<usize> =
        (0..lay.seq).filter(|&i| lay.kind[i] == INPUT).collect();
    if target_len > inputs.len() {
        bail!("target {} longer than input segment {}", target_len, inputs.len());
    }
    let mut m = vec![0.0f32; lay.seq];
    for &i in &inputs[inputs.len() - target_len..] {
        m[i] = 1.0;
    }
    Ok(m)
}

/// Verify the Rust builders against every golden case from the manifest.
/// Returns the number of cases checked.
pub fn verify_goldens(goldens: &[MaskGolden]) -> Result<usize> {
    for g in goldens {
        let method = Method::parse(&g.method)?;
        let scheme = MergeScheme::parse(&g.scheme)?;
        let lay = build_layout(&g.chunk_lens, g.comp_len, g.input_len, g.seq)?;
        if lay.kind != g.kind || lay.step != g.step || lay.comp_slot != g.comp_slot {
            bail!("layout mismatch for golden {}/{}", g.method, g.scheme);
        }
        let (mask, p) = build_masks(method, &lay, g.mem_slots, scheme, g.pool)?;
        for (r, row) in g.mask_rows.iter().enumerate() {
            for (c, ch) in row.bytes().enumerate() {
                let want = f32::from(ch == b'1');
                let got = mask.get(&[r, c]);
                if got != want {
                    bail!(
                        "mask mismatch {}/{} at ({r},{c}): got {got}, want {want}",
                        g.method,
                        g.scheme
                    );
                }
            }
        }
        let mut want_p = Tensor::zeros(&[g.mem_slots, g.seq]);
        for &(r, c, v) in &g.p_nonzero {
            want_p.set(&[r, c], v);
        }
        for r in 0..g.mem_slots {
            for c in 0..g.seq {
                let (a, b) = (p.get(&[r, c]), want_p.get(&[r, c]));
                if (a - b).abs() > 1e-6 {
                    bail!(
                        "P mismatch {}/{} at ({r},{c}): got {a}, want {b}",
                        g.method,
                        g.scheme
                    );
                }
            }
        }
    }
    Ok(goldens.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_packs_consecutively() {
        let lay = build_layout(&[3, 4], 2, 5, 24).unwrap();
        assert_eq!(lay.n_tokens(), 3 + 2 + 4 + 2 + 5);
        assert_eq!(&lay.kind[..5], &[CHUNK, CHUNK, CHUNK, COMP, COMP]);
        assert_eq!(lay.input_start(), 11);
        assert!(build_layout(&[30], 2, 5, 24).is_err());
    }

    #[test]
    fn concat_input_sees_only_comp() {
        let lay = build_layout(&[4, 4], 1, 4, 20).unwrap();
        let (mask, _) = build_masks(Method::CcmConcat, &lay, 4, MergeScheme::Avg, 1).unwrap();
        let i0 = lay.input_start();
        for c in 0..lay.seq {
            let allowed = mask.get(&[i0, 4 + c]) > 0.0;
            let is_comp = lay.kind[c] == COMP;
            let is_self = c == i0;
            assert_eq!(allowed, is_comp || is_self, "col {c}");
        }
    }

    #[test]
    fn merge_group_weights_sum_to_one() {
        let w = merge_weights(5, MergeScheme::Avg);
        for g in 1..=5 {
            let sum: f32 = w[g].iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        let w = merge_weights(5, MergeScheme::Ema(0.3));
        for g in 1..=5 {
            let sum: f32 = w[g].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "g={g} sum={sum}");
        }
    }

    #[test]
    fn merge_scheme_coeffs() {
        assert_eq!(MergeScheme::Avg.coeff(1), 1.0);
        assert_eq!(MergeScheme::Avg.coeff(4), 0.25);
        assert_eq!(MergeScheme::Ema(0.3).coeff(1), 1.0);
        assert_eq!(MergeScheme::Ema(0.3).coeff(9), 0.3);
    }

    #[test]
    fn chunks_never_see_other_chunks_raw() {
        for method in [Method::CcmConcat, Method::CcmMerge, Method::Gist, Method::Compressive] {
            let cl = usize::from(method.uses_comp_tokens());
            let lay = build_layout(&[4, 4, 4], cl, 4, 32).unwrap();
            let (mask, _) = build_masks(method, &lay, 8, MergeScheme::Avg, 2).unwrap();
            for i in 0..lay.seq {
                if lay.kind[i] != CHUNK {
                    continue;
                }
                for c in 0..lay.seq {
                    if lay.kind[c] == CHUNK && lay.step[c] != lay.step[i] {
                        assert_eq!(
                            mask.get(&[i, 8 + c]),
                            0.0,
                            "{method:?}: chunk pos {i} sees raw chunk pos {c}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn loss_mask_counts() {
        let lay = build_layout(&[3], 1, 6, 16).unwrap();
        let m = loss_mask_for_target(&lay, 2).unwrap();
        assert_eq!(m.iter().filter(|&&x| x > 0.0).count(), 2);
        assert!(loss_mask_for_target(&lay, 7).is_err());
    }

    #[test]
    fn gate_vectors() {
        let lay = build_layout(&[3, 3], 2, 4, 20).unwrap();
        let g = lora_gate(&lay, true);
        assert_eq!(g.iter().filter(|&&x| x > 0.0).count(), 4);
        let gu = lora_gate(&lay, false);
        assert_eq!(gu.iter().filter(|&&x| x > 0.0).count(), lay.n_tokens());
    }
}
