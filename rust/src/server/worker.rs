//! Cross-process shard workers: the worker-process serve loop
//! (`ccm worker --shard K`) and the front-end supervisor that spawns,
//! monitors, and respawns workers behind the routing hash.
//!
//! ## Topology
//!
//! `serve_workers` keeps the whole connection front-end (reactors,
//! admission, reply ordering) in the front-end process but runs every
//! shard executor in its own OS process — the one-XLA-device-per-
//! process deployment PJRT wants, which in-process [`BackendFactory`]
//! shards cannot express. Sessions still route by the same stable
//! [`super::shard_for`] hash, so Mem(t) stays pinned to one worker as
//! the fleet scales past a single process.
//!
//! Each worker binds a loopback listener (port 0 by default), prints a
//! one-line stdout handshake (`CCM_WORKER_READY <addr>`), and serves
//! the IPC protocol of [`super::ipc`] over a single front-end
//! connection — length-prefixed binary frames when the connection's
//! hello negotiated them (the worker grants binary only when started
//! with `--ipc-codec binary`), newline-framed JSON otherwise, each
//! request answered in the codec it arrived in. Request frames feed
//! the worker's [`Executor`] (its own Compute backend, batcher,
//! session manager, and KV-budget slice — `kv_budget_bytes` is the
//! global budget, partitioned by the worker's `--shard`/`--shards`
//! exactly like in-process shards), reply frames carry the executor's
//! replies back tagged with the request id, flushed in gathered-write
//! bursts.
//!
//! ## Supervision and failure semantics
//!
//! One supervisor thread per worker owns its lifecycle: spawn → read
//! the ready handshake → connect (with backoff) → attach the proxy →
//! wait for process exit. When a worker dies unexpectedly, its
//! in-flight requests fail over immediately to the documented
//! `{"ok":false,"error":"shard_unavailable"}` reply (never a hang or a
//! dropped connection), requests routed to the shard keep getting that
//! refusal while it is down, and the supervisor respawns it with
//! exponential backoff — the respawned worker starts with FRESH
//! sessions (the compressed memory died with the process; that is the
//! honest semantics of losing the owner of Mem(t)) and the per-worker
//! `restarts` counter (summed as `shard_restarts` in merged stats)
//! increments. `WorkerMode::Connect` supervises externally-started
//! workers (`--worker-addr`): connection-only, reconnect with backoff,
//! no spawning or respawn.
//!
//! Shutdown fans out across the IPC boundary: every worker drains its
//! executor, acks, and exits; the front-end acks its clients only after
//! every worker is drained AND the listener is released — the same
//! "ack means port released" contract as in-process serving. A worker
//! that dies mid-drain counts as maximally drained (its sessions are
//! gone); a worker that stalls past a kill deadline is SIGKILLed so
//! shutdown always completes.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::process::{ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::model::manifest::Manifest;
use crate::server::executor::Executor;
use crate::server::ipc::{self, WorkerProxy, WorkerStatsTable};
use crate::server::router::{Router, ShardHandle};
use crate::server::{BackendFactory, IpcCodec, Reply, Request, ServerConfig, SHUTDOWN_ACK};
use crate::util::json::escape;

/// Stdout handshake line prefix a worker prints once its IPC listener
/// is bound: `CCM_WORKER_READY 127.0.0.1:41234`. The supervisor scans
/// child stdout for it (skipping unrelated lines, e.g. test-harness
/// noise when a test binary hosts the worker entry).
pub const WORKER_READY_PREFIX: &str = "CCM_WORKER_READY ";

/// Builds the command that starts worker `shard` (the supervisor adds
/// nothing: shard identity, addresses, and backend flags all travel in
/// the command/env the launcher prepares).
pub type WorkerLauncher = Box<dyn Fn(usize) -> Command + Send + Sync>;

/// How the front-end obtains its workers.
pub enum WorkerMode {
    /// Spawn `count` worker processes via `launcher` and supervise
    /// them: crashed workers are respawned (fresh sessions, `restarts`
    /// counter) with exponential backoff.
    Spawn { count: usize, launcher: WorkerLauncher },
    /// Connect to externally-started workers (`--worker-addr`), one
    /// address per shard. Connection-only supervision: reconnect with
    /// backoff, no spawning.
    Connect { addrs: Vec<String> },
}

const SUPERVISE_TICK: Duration = Duration::from_millis(15);
const CONNECT_RETRY: Duration = Duration::from_millis(20);
const CONNECT_DEADLINE: Duration = Duration::from_secs(10);
const READY_DEADLINE: Duration = Duration::from_secs(30);
// The respawn backoff schedule (`cfg.respawn_backoff_min`/`_max`) and
// the drain kill deadline (`cfg.shutdown_kill_after`) are operator
// posture, configurable via `ccm serve --respawn-backoff-min-ms`,
// `--respawn-backoff-max-ms`, and `--shutdown-kill-after-secs`;
// defaults live in `ServerConfig::new`.
/// Once the drain contract is already satisfied (`drain_done`: the
/// worker acked, or the requesters were recorded while it was down), a
/// lingering process only gets this long to exit by itself.
const DRAINED_EXIT_GRACE: Duration = Duration::from_secs(1);

/// Run a server whose shards are worker processes. The front-end keeps
/// the normal transport (`cfg.reactor`/`cfg.reactors`) and router;
/// `cfg.shards` is set to the worker count. `cfg.kv_budget_bytes` is
/// echoed in merged stats but enforced worker-side (each worker
/// partitions the global budget by its shard index, exactly like
/// in-process shards) — launchers must forward the budget flags.
///
/// `ready` fires when the FRONT-END port is bound; workers are still
/// starting at that point, and requests racing a worker's startup get
/// the same `shard_unavailable` refusal as any down worker (by design:
/// the topology never queues into a process that may not appear).
/// Operators and tests can poll merged stats until every `per_worker`
/// row reports `up`.
pub fn serve_workers(
    mut cfg: ServerConfig,
    workers: WorkerMode,
    ready: Option<Sender<String>>,
) -> Result<()> {
    let count = match &workers {
        WorkerMode::Spawn { count, .. } => *count,
        WorkerMode::Connect { addrs } => addrs.len(),
    };
    if count == 0 {
        bail!("worker topology needs at least one worker");
    }
    cfg.shards = count;
    let table = Arc::new(WorkerStatsTable::new(count));
    let proxies: Vec<Arc<WorkerProxy>> =
        (0..count).map(|i| Arc::new(WorkerProxy::new(i, table.clone(), cfg.ipc_codec))).collect();
    let handles: Vec<ShardHandle> =
        proxies.iter().map(|p| ShardHandle::Remote(p.clone())).collect();
    let router = Router::with_workers(handles, &cfg, table);
    let cfg = &cfg;
    let proxies = &proxies;
    let workers = &workers;
    super::run_server(cfg, router, ready, move || {
        let results: Vec<Result<()>> = std::thread::scope(|s| {
            let threads: Vec<_> = proxies
                .iter()
                .map(|proxy| {
                    let proxy = proxy.clone();
                    s.spawn(move || match workers {
                        WorkerMode::Spawn { launcher, .. } => {
                            supervise_spawned(&proxy, launcher, cfg)
                        }
                        WorkerMode::Connect { addrs } => {
                            supervise_external(&proxy, &addrs[proxy.shard()], cfg)
                        }
                    })
                })
                .collect();
            // lint: allow(unwrap) — a panicked supervisor is a bug in
            // the respawn loop itself; re-raise it on the shell.
            threads.into_iter().map(|t| t.join().expect("supervisor thread")).collect()
        });
        let mut replies = Vec::new();
        let mut first_err = None;
        for (proxy, result) in proxies.iter().zip(results) {
            replies.extend(proxy.take_drained());
            if let Err(e) = result {
                first_err = first_err.or(Some(e));
            }
        }
        (replies, first_err.map_or(Ok(()), Err))
    })
}

/// Spawn-mode supervisor loop for one worker: returns once a requested
/// shutdown has completed (worker drained and exited, or proved
/// unreachable). Start failures and crashes are retried/respawned with
/// exponential backoff forever — while the worker is down, the shard
/// answers `shard_unavailable`, never hangs.
fn supervise_spawned(
    proxy: &Arc<WorkerProxy>,
    launcher: &WorkerLauncher,
    cfg: &ServerConfig,
) -> Result<()> {
    let shard = proxy.shard();
    let mut backoff = cfg.respawn_backoff_min;
    loop {
        if proxy.shutdown_requested() {
            return Ok(());
        }
        let mut cmd = launcher(shard);
        cmd.stdin(Stdio::null()).stdout(Stdio::piped());
        let mut child = match cmd.spawn() {
            Ok(child) => child,
            Err(e) => {
                crate::info!("worker {shard}: spawn failed: {e}; retrying in {backoff:?}");
                sleep_unless_shutdown(proxy, backoff);
                backoff = (backoff * 2).min(cfg.respawn_backoff_max);
                continue;
            }
        };
        proxy.slot().pid.store(u64::from(child.id()), Ordering::SeqCst);
        // lint: allow(unwrap) — spawn() above configured piped stdout,
        // and this is the first take().
        let ready_rx = watch_stdout(child.stdout.take().expect("piped stdout"));
        // Handshake wait in shutdown-aware ticks: a shutdown must not
        // sit behind the full 30 s deadline of a wedged worker start
        // (the requesters are already recorded; this child just gets
        // killed below).
        let ready_deadline = Instant::now() + READY_DEADLINE;
        let addr = loop {
            match ready_rx.recv_timeout(SUPERVISE_TICK) {
                Ok(addr) => break Some(addr),
                Err(RecvTimeoutError::Timeout) => {
                    if proxy.shutdown_requested() || Instant::now() >= ready_deadline {
                        break None;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => break None,
            }
        };
        let attached = addr.as_ref().is_some_and(|addr| {
            connect_with_backoff(addr, CONNECT_DEADLINE, proxy)
                .is_some_and(|stream| proxy.attach(stream).is_ok())
        });
        if !attached {
            crate::info!("worker {shard}: failed to come up; killing and retrying");
            let _ = child.kill();
            let _ = child.wait();
            proxy.slot().pid.store(0, Ordering::SeqCst);
            sleep_unless_shutdown(proxy, backoff);
            backoff = (backoff * 2).min(cfg.respawn_backoff_max);
            continue;
        }
        // lint: allow(unwrap) — the !attached branch continued above,
        // and a successful attach always records the address.
        let addr = addr.expect("attached implies addr");
        backoff = cfg.respawn_backoff_min; // healthy start resets the schedule
        // Wait for the process to exit. A dropped socket with the
        // process still alive is reconnected (the worker re-accepts);
        // a stalled shutdown drain is bounded by a hard kill.
        let mut kill_at: Option<Instant> = None;
        let mut next_reconnect = Instant::now();
        let status = loop {
            match child.try_wait() {
                Ok(Some(status)) => break Some(status),
                Ok(None) => {}
                Err(_) => break None,
            }
            if proxy.shutdown_requested() {
                // Full deadline while a drain may still be in progress;
                // once the contract is satisfied (ack received or
                // recorded), only a short exit grace remains — e.g. a
                // shutdown that raced a respawn: the fresh worker holds
                // no sessions and was never asked to drain.
                let grace =
                    if proxy.drain_done() { DRAINED_EXIT_GRACE } else { cfg.shutdown_kill_after };
                let target = Instant::now() + grace;
                let at = kill_at.map_or(target, |cur: Instant| cur.min(target));
                kill_at = Some(at);
                if Instant::now() >= at {
                    crate::info!("worker {shard}: shutdown drain stalled; killing");
                    let _ = child.kill();
                }
            } else if !proxy.is_up() && Instant::now() >= next_reconnect {
                next_reconnect = Instant::now() + Duration::from_millis(100);
                if let Ok(stream) = TcpStream::connect(&addr) {
                    let _ = proxy.attach(stream);
                }
            }
            std::thread::sleep(SUPERVISE_TICK);
        };
        proxy.force_detach();
        proxy.slot().pid.store(0, Ordering::SeqCst);
        if proxy.shutdown_requested() {
            return Ok(());
        }
        proxy.slot().restarts.fetch_add(1, Ordering::SeqCst);
        crate::info!(
            "worker {shard}: process exited unexpectedly ({status:?}); respawning with fresh \
             sessions in {backoff:?}"
        );
        sleep_unless_shutdown(proxy, backoff);
        backoff = (backoff * 2).min(cfg.respawn_backoff_max);
    }
}

/// Connect-mode supervisor for an externally-started worker: keep one
/// connection up (reconnect with backoff), return once a requested
/// shutdown has drained. The drain wait is bounded like spawn mode's:
/// past `cfg.shutdown_kill_after` a wedged external worker is abandoned
/// (detached, its shutdown requesters recorded) — there is no process
/// to kill, but shutdown must still complete.
fn supervise_external(proxy: &Arc<WorkerProxy>, addr: &str, cfg: &ServerConfig) -> Result<()> {
    let mut backoff = cfg.respawn_backoff_min;
    let mut drain_deadline: Option<Instant> = None;
    loop {
        if proxy.drain_done() {
            return Ok(());
        }
        if proxy.shutdown_requested() {
            if !proxy.is_up() {
                // Down at shutdown: the dispatch already recorded the
                // requesters as trivially drained.
                return Ok(());
            }
            let at = *drain_deadline.get_or_insert_with(|| Instant::now() + cfg.shutdown_kill_after);
            if Instant::now() >= at {
                crate::info!(
                    "worker {}: external worker did not drain in time; abandoning it",
                    proxy.shard()
                );
                proxy.force_detach();
                return Ok(());
            }
            std::thread::sleep(SUPERVISE_TICK);
            continue;
        }
        if proxy.is_up() {
            std::thread::sleep(SUPERVISE_TICK);
            continue;
        }
        if let Ok(stream) = TcpStream::connect(addr) {
            if proxy.attach(stream).is_ok() {
                backoff = cfg.respawn_backoff_min;
                continue;
            }
        }
        sleep_unless_shutdown(proxy, backoff);
        backoff = (backoff * 2).min(cfg.respawn_backoff_max);
    }
}

fn sleep_unless_shutdown(proxy: &WorkerProxy, total: Duration) {
    let deadline = Instant::now() + total;
    while !proxy.shutdown_requested() {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return;
        }
        std::thread::sleep(left.min(SUPERVISE_TICK));
    }
}

/// Scan child stdout for the ready handshake on a helper thread (child
/// stdout cannot be read with a timeout directly), then keep draining
/// it so the worker never blocks on a full pipe.
fn watch_stdout(stdout: ChildStdout) -> Receiver<String> {
    let (tx, rx) = channel();
    std::thread::spawn(move || {
        let mut reader = BufReader::new(stdout);
        let mut line = String::new();
        let mut announced = false;
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {
                    if !announced {
                        if let Some(addr) = line.trim().strip_prefix(WORKER_READY_PREFIX) {
                            let _ = tx.send(addr.trim().to_string());
                            announced = true;
                        }
                    }
                }
            }
        }
    });
    rx
}

fn connect_with_backoff(addr: &str, deadline: Duration, proxy: &WorkerProxy) -> Option<TcpStream> {
    let until = Instant::now() + deadline;
    loop {
        // A requested shutdown aborts the attach outright (requesters
        // were recorded while the proxy was down; the caller kills the
        // child and its supervisor exits).
        if proxy.shutdown_requested() {
            return None;
        }
        match TcpStream::connect(addr) {
            Ok(stream) => return Some(stream),
            Err(_) => {
                if Instant::now() >= until {
                    return None;
                }
                std::thread::sleep(CONNECT_RETRY);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Worker-process side.

#[derive(Default)]
struct WorkerShared {
    /// The executor thread has returned: drained after a shutdown (acks
    /// already queued to the writer) or failed.
    done: AtomicBool,
}

/// Grace before a once-connected worker whose front-end dropped away
/// concludes it is orphaned and exits (so a SIGKILLed front-end never
/// leaks worker processes). The FIRST-connection grace is operator
/// posture — `ccm worker --orphan-grace-secs`, default
/// [`crate::server::ORPHAN_GRACE_DEFAULT`] — because slow fleets
/// (cold-started backends, packed hosts) legitimately need longer than
/// any constant baked in here.
const ORPHAN_RECONNECT: Duration = Duration::from_secs(10);
const ACCEPT_TICK: Duration = Duration::from_millis(25);

/// Run one shard worker: bind the IPC listener (`cfg.addr`, port 0 by
/// default), print the `CCM_WORKER_READY <addr>` stdout handshake, and
/// serve request frames from the front-end into a full [`Executor`]
/// (built from `factory` on the executor thread, since backends may own
/// thread-bound PJRT state). `cfg.shards`/`shard` position this worker
/// in the fleet: the KV budget partitions exactly as for in-process
/// shards. Returns after a drained shutdown, after the front-end stays
/// away past the orphan grace period, or on executor failure.
pub fn run_worker<'a>(
    manifest: &Manifest,
    factory: BackendFactory<'a>,
    cfg: ServerConfig,
    shard: usize,
    ready: Option<Sender<String>>,
) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
    listener.set_nonblocking(true).context("worker listener nonblocking")?;
    let local = listener.local_addr()?.to_string();
    // The stdout handshake the supervisor scans for; all logging goes
    // to stderr so this stays the only load-bearing stdout line.
    println!("{WORKER_READY_PREFIX}{local}");
    std::io::stdout().flush().ok();
    crate::info!("worker {shard} serving IPC on {local}");
    if let Some(tx) = ready {
        let _ = tx.send(local);
    }
    // Startup sweep of the hibernation tier: a predecessor of this
    // shard killed mid-spill leaves `.tmp` files behind. Anything
    // older than the orphan grace is provably garbage (its writer
    // would have concluded it was orphaned and exited by then);
    // younger tmp files are left for a lingering predecessor to
    // rename into place.
    if let Some(root) = &cfg.hibernate_dir {
        match crate::server::hibernate::SpillStore::open(root, shard) {
            Ok(store) => {
                let swept = store.sweep_stale_tmp(cfg.orphan_grace);
                if swept > 0 {
                    crate::info!("worker {shard}: swept {swept} stale spill tmp files");
                }
            }
            Err(e) => crate::info!("worker {shard}: spill dir unavailable for sweep: {e:#}"),
        }
    }
    let shared = WorkerShared::default();
    let (req_tx, req_rx) = channel::<(Request, Reply)>();
    let cfg = &cfg;
    let shared = &shared;
    std::thread::scope(|s| {
        let exec = s.spawn(move || {
            let result = (|| -> Result<()> {
                let backend = factory()?;
                let repliers = Executor::new(manifest, backend, cfg, shard).run(req_rx)?;
                // Worker-side drain ack; the front-end stashes it until
                // its own listener is released.
                for reply in repliers {
                    let _ = reply.send(SHUTDOWN_ACK.into());
                }
                Ok(())
            })();
            shared.done.store(true, Ordering::SeqCst);
            if let Err(e) = &result {
                crate::info!("worker {shard}: executor failed: {e:#}");
            }
            result
        });
        let allow_binary = cfg.ipc_codec == IpcCodec::Binary;
        let accept_result =
            accept_loop(&listener, &req_tx, shared, shard, allow_binary, cfg.orphan_grace);
        drop(req_tx);
        // lint: allow(unwrap) — a panicked executor thread is a bug;
        // re-raise the panic instead of fabricating an exit status.
        let exec_result = exec.join().expect("worker executor thread");
        exec_result.and(accept_result)
    })
}

/// Accept front-end connections serially: one connection serves at a
/// time (the front-end holds exactly one and reconnects after a
/// transient drop); losing it without a shutdown re-enters accept under
/// the orphan grace period.
fn accept_loop(
    listener: &TcpListener,
    req_tx: &Sender<(Request, Reply)>,
    shared: &WorkerShared,
    shard: usize,
    allow_binary: bool,
    first_conn_grace: Duration,
) -> Result<()> {
    let mut grace_until = Instant::now() + first_conn_grace;
    loop {
        if shared.done.load(Ordering::SeqCst) {
            return Ok(());
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                crate::info!("worker {shard}: front-end connected from {peer}");
                if matches!(serve_ipc_conn(stream, req_tx, shared, allow_binary)?, ConnEnd::Done) {
                    return Ok(());
                }
                crate::info!("worker {shard}: front-end disconnected; awaiting reconnect");
                grace_until = Instant::now() + ORPHAN_RECONNECT;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= grace_until {
                    crate::info!("worker {shard}: no front-end within grace period; exiting");
                    return Ok(());
                }
                std::thread::sleep(ACCEPT_TICK);
            }
            Err(e) => return Err(e).context("worker accept"),
        }
    }
}

enum ConnEnd {
    /// The executor finished (drained shutdown or failure): exit.
    Done,
    /// The front-end connection dropped: await a reconnect.
    Lost,
}

/// Serve one front-end connection: request frames in, tagged replies
/// out through a writer thread. Reads poll on a short timeout so the
/// loop observes the executor finishing (the drain acks are flushed by
/// joining the writer before the connection closes).
///
/// Each reply goes out in the codec its request arrived in; a
/// `hello` line is answered at this layer (granting binary only when
/// `allow_binary`, i.e. the worker was started with the binary codec)
/// and never reaches the executor. The writer drains its queue in
/// batches through one gathered write per burst, reusing encode
/// buffers from a local free list.
fn serve_ipc_conn(
    stream: TcpStream,
    req_tx: &Sender<(Request, Reply)>,
    shared: &WorkerShared,
    allow_binary: bool,
) -> Result<ConnEnd> {
    let _ = stream.set_nodelay(true);
    stream.set_read_timeout(Some(Duration::from_millis(50))).context("ipc read timeout")?;
    let write_half = stream.try_clone().context("clone ipc stream")?;
    let (out_tx, out_rx) = channel::<(u64, String, bool)>();
    let writer = std::thread::spawn(move || {
        let write_half = write_half;
        let mut free: Vec<Vec<u8>> = Vec::new();
        let mut batch: Vec<Vec<u8>> = Vec::new();
        let mut encode = |free: &mut Vec<Vec<u8>>, (id, resp, bin): (u64, String, bool)| {
            let mut frame = free.pop().unwrap_or_default();
            if bin {
                ipc::encode_reply_bin(id, &resp, &mut frame);
            } else {
                frame.clear();
                frame.extend_from_slice(ipc::encode_reply(id, &resp).as_bytes());
            }
            frame
        };
        while let Ok(msg) = out_rx.recv() {
            batch.push(encode(&mut free, msg));
            while batch.len() < ipc::IPC_WRITE_BATCH {
                match out_rx.try_recv() {
                    Ok(msg) => batch.push(encode(&mut free, msg)),
                    Err(_) => break,
                }
            }
            if crate::server::poll::write_gathered(&write_half, &batch).is_err() {
                break;
            }
            for mut b in batch.drain(..) {
                // Same retention cap as the proxy's pool: a one-off
                // giant reply must not pin its buffer forever.
                if b.capacity() <= 64 * 1024 {
                    b.clear();
                    free.push(b);
                }
            }
        }
    });
    let mut stream = stream;
    let mut frames = ipc::FrameBuf::new(ipc::IPC_MAX_FRAME);
    let mut scratch = [0u8; 64 * 1024];
    'conn: loop {
        match stream.read(&mut scratch) {
            Ok(0) => break,
            Ok(n) => {
                frames.feed(&scratch[..n]);
                while let Some(frame) = frames.next_frame() {
                    let (id, req, bin) = match frame {
                        ipc::Frame::Line(line) => match ipc::decode_line(&line) {
                            Ok(ipc::LineFrame::Hello { id, codec }) => {
                                let granted = if allow_binary && codec == IpcCodec::Binary {
                                    IpcCodec::Binary
                                } else {
                                    IpcCodec::Json
                                };
                                let _ = out_tx.send((id, ipc::hello_ack(granted), false));
                                continue;
                            }
                            Ok(ipc::LineFrame::Req(id, req)) => (id, req, false),
                            Err(e) => {
                                // Malformed body with a recoverable id
                                // is answered; id-less garbage is
                                // skipped and framing resynchronises
                                // (never desyncs).
                                if let Some(id) = ipc::frame_id(&line) {
                                    let err = escape(&e.to_string());
                                    let msg = format!("{{\"ok\":false,\"error\":{err}}}");
                                    let _ = out_tx.send((id, msg, false));
                                } else {
                                    crate::debug!("worker: skipping unframeable line: {e:#}");
                                }
                                continue;
                            }
                        },
                        ipc::Frame::Bin(payload) => match ipc::decode_request_bin(payload) {
                            Ok((id, req)) => (id, req, true),
                            Err(e) => {
                                // A binary frame is length-delimited,
                                // so a bad body never desyncs framing;
                                // its id (if any) is untrustworthy, so
                                // it is dropped rather than answered.
                                crate::debug!("worker: dropping undecodable binary frame: {e:#}");
                                continue;
                            }
                        },
                    };
                    let reply = Reply::Ipc(ipc::IpcReplyHandle { id, bin, out: out_tx.clone() });
                    if req_tx.send((req, reply)).is_err() {
                        break 'conn; // executor gone
                    }
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if shared.done.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
    drop(out_tx);
    if shared.done.load(Ordering::SeqCst) {
        // Drained: the executor returned, so no reply handles remain;
        // joining the writer flushes the queued acks onto the wire
        // before the connection (and then the process) goes away.
        let _ = writer.join();
        Ok(ConnEnd::Done)
    } else {
        // Lost mid-flight: the writer dies with its channel once the
        // executor drops the orphaned reply handles; late replies hit a
        // closed socket and are dropped, like the reactor's late
        // replies for timed-out requests.
        Ok(ConnEnd::Lost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compute, SimCompute};
    use crate::coordinator::session::SessionPolicy;
    use crate::util::json::Json;
    use std::collections::HashMap;

    fn start_toy_worker() -> (String, std::thread::JoinHandle<Result<()>>) {
        start_toy_worker_codec(IpcCodec::Binary)
    }

    fn start_toy_worker_codec(codec: IpcCodec) -> (String, std::thread::JoinHandle<Result<()>>) {
        let (ready_tx, ready_rx) = channel();
        let handle = std::thread::spawn(move || {
            let m = Manifest::toy();
            let sim = SimCompute::from_manifest(&m);
            let factory: BackendFactory<'static> =
                Box::new(move || Ok(Box::new(sim) as Box<dyn Compute>));
            let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
            cfg.max_wait = Duration::ZERO;
            cfg.ipc_codec = codec;
            run_worker(&m, factory, cfg, 0, Some(ready_tx))
        });
        let addr = ready_rx.recv_timeout(Duration::from_secs(10)).expect("worker ready");
        (addr, handle)
    }

    /// Read reply frames until `want` distinct ids have answered.
    fn read_replies(stream: &mut TcpStream, want: usize) -> HashMap<u64, Json> {
        read_frames(stream, want).into_iter().map(|(id, (_, j))| (id, j)).collect()
    }

    /// Read reply frames of either codec until `want` distinct ids have
    /// answered; the bool records whether a reply arrived binary.
    fn read_frames(stream: &mut TcpStream, want: usize) -> HashMap<u64, (bool, Json)> {
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut frames = ipc::FrameBuf::new(ipc::IPC_MAX_FRAME);
        let mut scratch = [0u8; 16 * 1024];
        let mut out = HashMap::new();
        while out.len() < want {
            let n = stream.read(&mut scratch).expect("read reply frames");
            assert!(n > 0, "worker closed early with {}/{want} replies", out.len());
            frames.feed(&scratch[..n]);
            while let Some(frame) = frames.next_frame() {
                let (bin, (id, resp)) = match frame {
                    ipc::Frame::Line(line) => {
                        (false, ipc::decode_reply(&line).expect("valid reply frame"))
                    }
                    ipc::Frame::Bin(payload) => {
                        (true, ipc::decode_reply_bin(payload).expect("valid binary reply"))
                    }
                };
                out.insert(id, (bin, Json::parse(&resp).expect("valid reply JSON")));
            }
        }
        out
    }

    #[test]
    fn worker_serves_frames_and_drains_on_shutdown() {
        let (addr, worker) = start_toy_worker();
        let mut stream = TcpStream::connect(&addr).unwrap();
        let frames: String = [
            ipc::encode_request(0, &Request::Context { session: "u".into(), tokens: vec![4, 5], strategy: None }),
            ipc::encode_request(
                1,
                &Request::Query { session: "u".into(), tokens: vec![7], topk: 1 },
            ),
            ipc::encode_request(2, &Request::Stats(crate::server::StatsQuery::default())),
            ipc::encode_request(3, &Request::Shutdown),
        ]
        .concat();
        stream.write_all(frames.as_bytes()).unwrap();
        let replies = read_replies(&mut stream, 4);
        assert_eq!(replies[&0].get("t").unwrap().i64().unwrap(), 1, "context ack");
        let next = replies[&1].get("next").unwrap().arr().unwrap();
        assert_eq!(next[0].arr().unwrap()[0].i64().unwrap(), 7, "query echo");
        assert_eq!(replies[&2].get("shard").unwrap().usize().unwrap(), 0, "stats shard id");
        assert_eq!(replies[&2].get("kind").unwrap().str().unwrap(), "stats");
        assert_eq!(replies[&3].get("kind").unwrap().str().unwrap(), "shutdown");
        // After the drain ack the worker closes the connection and the
        // serve loop returns cleanly.
        let mut tail = [0u8; 64];
        let eof = loop {
            match stream.read(&mut tail) {
                Ok(0) => break true,
                Ok(_) => {}
                Err(_) => break false,
            }
        };
        assert!(eof, "worker must close after the drain ack");
        worker.join().expect("worker thread").expect("worker result");
    }

    #[test]
    fn worker_answers_malformed_frames_and_resyncs_on_garbage() {
        let (addr, worker) = start_toy_worker();
        let mut stream = TcpStream::connect(&addr).unwrap();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"%%% not json at all\n"); // id-less: skipped
        bytes.extend_from_slice(b"{\"id\":5,\"op\":\"bogus\"}\n"); // id: answered
        let query = Request::Query { session: "q".into(), tokens: vec![3], topk: 1 };
        bytes.extend_from_slice(ipc::encode_request(6, &query).as_bytes());
        stream.write_all(&bytes).unwrap();
        let replies = read_replies(&mut stream, 2);
        assert_eq!(replies[&5].get("ok").unwrap(), &Json::Bool(false));
        assert!(replies[&5].get("error").unwrap().str().unwrap().contains("unknown op"));
        assert_eq!(
            replies[&6].get("next").unwrap().arr().unwrap()[0].arr().unwrap()[0]
                .i64()
                .unwrap(),
            3,
            "frames after garbage must still serve"
        );
        stream.write_all(ipc::encode_request(7, &Request::Shutdown).as_bytes()).unwrap();
        let replies = read_replies(&mut stream, 1);
        assert_eq!(replies[&7].get("kind").unwrap().str().unwrap(), "shutdown");
        drop(stream);
        worker.join().expect("worker thread").expect("worker result");
    }

    #[test]
    fn worker_exits_when_the_front_end_disappears() {
        // Orphan semantics: EOF without a shutdown re-enters accept
        // under the reconnect grace; a second connection then drives a
        // normal shutdown (covering the supervisor's reconnect path).
        let (addr, worker) = start_toy_worker();
        {
            let mut stream = TcpStream::connect(&addr).unwrap();
            stream
                .write_all(
                    ipc::encode_request(
                        0,
                        &Request::Context { session: "a".into(), tokens: vec![1], strategy: None },
                    )
                    .as_bytes(),
                )
                .unwrap();
            let replies = read_replies(&mut stream, 1);
            assert_eq!(replies[&0].get("t").unwrap().i64().unwrap(), 1);
        } // dropped: EOF without shutdown
        let mut stream = TcpStream::connect(&addr).expect("worker must re-accept");
        // Session state survived the reconnect (same process).
        stream
            .write_all(
                ipc::encode_request(1, &Request::Context { session: "a".into(), tokens: vec![2], strategy: None })
                    .as_bytes(),
            )
            .unwrap();
        let replies = read_replies(&mut stream, 1);
        assert_eq!(replies[&1].get("t").unwrap().i64().unwrap(), 2);
        stream.write_all(ipc::encode_request(2, &Request::Shutdown).as_bytes()).unwrap();
        let replies = read_replies(&mut stream, 1);
        assert_eq!(replies[&2].get("kind").unwrap().str().unwrap(), "shutdown");
        worker.join().expect("worker thread").expect("worker result");
    }

    #[test]
    fn orphan_grace_is_configurable_and_startup_sweeps_stale_spill_tmp() {
        // Regression: the first-connection orphan grace was a
        // hard-coded 120 s, so a worker in a test (or a fast-failing
        // deployment) lingered for two minutes. The grace now comes
        // from the config (default unchanged); with a zero grace and
        // no front-end the worker must exit on its own.
        assert_eq!(
            ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2)).orphan_grace,
            crate::server::ORPHAN_GRACE_DEFAULT,
            "default grace stays 120 s"
        );
        let root = std::env::temp_dir().join(format!("ccm-worker-hib-{}", std::process::id()));
        std::fs::remove_dir_all(&root).ok();
        let dir = crate::server::hibernate::shard_dir(&root, 0);
        std::fs::create_dir_all(&dir).unwrap();
        // A predecessor's torn tmp next to a complete snapshot: the
        // startup sweep must remove the first and keep the second
        // (content validity is rehydration's problem, not the sweep's).
        let snap = crate::server::hibernate::snap_path(&root, 0, "u");
        std::fs::write(&snap, b"complete snapshot bytes").unwrap();
        let tmp = dir.join("6261.snap.tmp");
        std::fs::write(&tmp, b"torn partial write").unwrap();
        let (ready_tx, ready_rx) = channel();
        let worker_root = root.clone();
        let handle = std::thread::spawn(move || {
            let m = Manifest::toy();
            let sim = SimCompute::from_manifest(&m);
            let factory: BackendFactory<'static> =
                Box::new(move || Ok(Box::new(sim) as Box<dyn Compute>));
            let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
            cfg.max_wait = Duration::ZERO;
            cfg.hibernate_dir = Some(worker_root);
            cfg.orphan_grace = Duration::ZERO;
            run_worker(&m, factory, cfg, 0, Some(ready_tx))
        });
        let _addr = ready_rx.recv_timeout(Duration::from_secs(10)).expect("worker ready");
        handle.join().expect("worker thread").expect("orphaned worker exits cleanly");
        assert!(!tmp.exists(), "stale spill tmp swept at startup");
        assert!(snap.exists(), "complete snapshots survive the sweep");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn worker_grants_hello_and_mirrors_binary_frames() {
        let (addr, worker) = start_toy_worker_codec(IpcCodec::Binary);
        let mut stream = TcpStream::connect(&addr).unwrap();
        let mut bytes = ipc::encode_hello(0, IpcCodec::Binary).into_bytes();
        let mut frame = Vec::new();
        ipc::encode_request_bin(
            1,
            &Request::Context {
                session: "b".into(),
                tokens: vec![4, 5],
                strategy: Some(crate::compress::StrategyKind::SlidingWindow),
            },
            ipc::IPC_VERSION,
            &mut frame,
        );
        bytes.extend_from_slice(&frame);
        ipc::encode_request_bin(
            2,
            &Request::Query { session: "b".into(), tokens: vec![9], topk: 1 },
            ipc::IPC_VERSION,
            &mut frame,
        );
        bytes.extend_from_slice(&frame);
        stream.write_all(&bytes).unwrap();
        let replies = read_frames(&mut stream, 3);
        // The hello ack is line-mode (its request was); it grants
        // binary because the worker runs the binary codec.
        let (ack_bin, ack) = &replies[&0];
        assert!(!ack_bin, "hello ack must mirror the line-mode hello");
        assert_eq!(ack.get("codec").unwrap().str().unwrap(), "binary");
        // Replies to binary requests come back binary, with the same
        // payloads the JSON codec would carry.
        let (ctx_bin, ctx) = &replies[&1];
        assert!(ctx_bin, "binary request must get a binary reply");
        assert_eq!(ctx.get("t").unwrap().i64().unwrap(), 1, "context ack");
        assert_eq!(
            ctx.get("strategy").unwrap().str().unwrap(),
            "sliding-window",
            "the v2 strategy byte must reach admission"
        );
        let (q_bin, q) = &replies[&2];
        assert!(q_bin);
        let next = q.get("next").unwrap().arr().unwrap();
        assert_eq!(next[0].arr().unwrap()[0].i64().unwrap(), 9, "query echo");
        ipc::encode_request_bin(3, &Request::Shutdown, ipc::IPC_VERSION, &mut frame);
        stream.write_all(&frame).unwrap();
        let replies = read_frames(&mut stream, 1);
        let (sd_bin, sd) = &replies[&3];
        assert!(sd_bin);
        assert_eq!(sd.get("kind").unwrap().str().unwrap(), "shutdown");
        worker.join().expect("worker thread").expect("worker result");
    }

    #[test]
    fn worker_declines_hello_when_configured_json_only() {
        let (addr, worker) = start_toy_worker_codec(IpcCodec::Json);
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.write_all(ipc::encode_hello(0, IpcCodec::Binary).as_bytes()).unwrap();
        let replies = read_frames(&mut stream, 1);
        let (ack_bin, ack) = &replies[&0];
        assert!(!ack_bin);
        assert_eq!(ack.get("ok").unwrap(), &Json::Bool(true));
        assert_eq!(
            ack.get("codec").unwrap().str().unwrap(),
            "json",
            "a json-only worker negotiates the connection down"
        );
        // The connection then serves normally in JSON.
        stream.write_all(ipc::encode_request(1, &Request::Shutdown).as_bytes()).unwrap();
        let replies = read_frames(&mut stream, 1);
        let (sd_bin, sd) = &replies[&1];
        assert!(!sd_bin);
        assert_eq!(sd.get("kind").unwrap().str().unwrap(), "shutdown");
        worker.join().expect("worker thread").expect("worker result");
    }
}
