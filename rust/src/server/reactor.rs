//! Event-driven connection front-end (`--reactor epoll`).
//!
//! One reactor thread owns the listener and every accepted connection;
//! readiness is multiplexed through [`poll::Poller`] (epoll on Linux, a
//! portable scan loop elsewhere), so 10k+ concurrent sessions cost one
//! thread and one `Conn` struct each instead of one OS thread stack.
//!
//! Per connection the reactor keeps an explicit [`Conn`]:
//!
//! * a read buffer with incremental newline framing (capped at
//!   `max_line_bytes`: an overlong line gets a `line_too_long` reply
//!   and the framing resynchronises at the next newline, so a
//!   slow-loris peer cannot pin memory),
//! * a write buffer with partial-write continuation (write interest is
//!   registered only while bytes are buffered; reads pause while the
//!   backlog exceeds [`WRITE_PAUSE_BYTES`] — backpressure instead of
//!   unbounded growth when a client reads slowly),
//! * a pending-reply queue preserving request order: requests are
//!   dispatched to shard executors as soon as they are framed, replies
//!   come back through the [`CompletionQueue`], and are written out
//!   strictly in request order (late replies for timed-out requests
//!   are dropped).
//!
//! Executor shards never touch sockets: [`super::Reply::Completion`]
//! pushes the reply into the completion queue and rings the poller's
//! eventfd waker, which pops the reactor out of `epoll_wait` to
//! deliver. Shutdown is a staged handshake via [`Ctl`]: the serve
//! shell asks the reactor to close the listener (releasing the port),
//! waits for confirmation, sends the shutdown acks through the
//! completion queue, then signals the final flush-and-exit.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::server::poll::{self, Poller};
use crate::server::router::Router;
use crate::server::{
    LINE_TOO_LONG_REPLY, Reply, Request, REPLY_TIMEOUT, ServerConfig, TIMEOUT_REPLY,
    TOO_MANY_CONNS_REPLY,
};
use crate::util::json::escape;

const LISTENER_TOKEN: poll::Token = 0;
/// Pause reading a connection while this many reply bytes are buffered.
const WRITE_PAUSE_BYTES: usize = 1 << 20;
/// Compact the write buffer once this many bytes have been written out.
const WRITE_COMPACT_BYTES: usize = 64 * 1024;
/// After a non-`WouldBlock` accept failure (EMFILE/ENFILE: the backlog
/// entry stays pending, so a level-triggered listener would hot-spin
/// the event loop), accepting pauses this long before re-arming.
const ACCEPT_BACKOFF: Duration = Duration::from_millis(50);

// ---------------------------------------------------------------------
// Completion delivery (executor shard -> reactor).

/// One reply produced by an executor for a reactor-owned connection.
pub(crate) struct Completion {
    conn: poll::Token,
    req: u64,
    msg: String,
}

/// Shared reply queue: executors push, the reactor drains. Every push
/// rings the poller's waker so delivery latency is one epoll wakeup.
pub(crate) struct CompletionQueue {
    items: Mutex<Vec<Completion>>,
    waker: poll::Waker,
}

impl CompletionQueue {
    pub(crate) fn new(waker: poll::Waker) -> CompletionQueue {
        CompletionQueue { items: Mutex::new(Vec::new()), waker }
    }

    fn push(&self, completion: Completion) {
        self.items.lock().unwrap().push(completion);
        self.waker.wake();
    }

    fn drain(&self) -> Vec<Completion> {
        std::mem::take(&mut *self.items.lock().unwrap())
    }
}

/// The reactor-mode [`Reply`]: identifies (connection, request) so the
/// reactor can slot the reply into the per-conn pending queue.
#[derive(Clone)]
pub(crate) struct CompletionHandle {
    queue: Arc<CompletionQueue>,
    conn: poll::Token,
    req: u64,
}

impl CompletionHandle {
    pub(crate) fn send(&self, msg: String) {
        self.queue.push(Completion { conn: self.conn, req: self.req, msg });
    }
}

// ---------------------------------------------------------------------
// Shutdown handshake (serve shell -> reactor).

pub(crate) const CTL_RUNNING: u8 = 0;
/// Serve shell asks: close the listener (port must be released before
/// shutdown acks are sent — the ack's documented meaning).
pub(crate) const CTL_CLOSE_LISTENER: u8 = 1;
/// Reactor confirms: listener dropped, port free.
pub(crate) const CTL_LISTENER_CLOSED: u8 = 2;
/// Serve shell asks: flush buffered replies (the shutdown acks) and
/// exit, closing every connection.
pub(crate) const CTL_FINISH: u8 = 3;

/// Monotonic shutdown stage shared between the serve shell and the
/// reactor thread. Stages only advance.
#[derive(Default)]
pub(crate) struct Ctl {
    stage: Mutex<u8>,
    cv: Condvar,
}

impl Ctl {
    pub(crate) fn advance(&self, stage: u8) {
        let mut s = self.stage.lock().unwrap();
        if *s < stage {
            *s = stage;
        }
        self.cv.notify_all();
    }

    pub(crate) fn stage(&self) -> u8 {
        *self.stage.lock().unwrap()
    }

    /// Wait until the stage reaches `stage`; false on timeout (the
    /// reactor died — callers degrade rather than hang).
    pub(crate) fn wait_at_least(&self, stage: u8, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut s = self.stage.lock().unwrap();
        while *s < stage {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return false;
            }
            let (guard, _) = self.cv.wait_timeout(s, left).unwrap();
            s = guard;
        }
        true
    }
}

// ---------------------------------------------------------------------
// Per-connection state.

enum PendingState {
    /// Dispatched to an executor; the reply will arrive as a completion.
    Waiting,
    /// Reply ready (or synthesized locally: parse error, overlong line,
    /// timeout); written out once every earlier request is done.
    Done(String),
}

struct Pending {
    req: u64,
    deadline: Instant,
    state: PendingState,
}

/// One accepted connection owned by the reactor.
struct Conn {
    stream: TcpStream,
    token: poll::Token,
    read_buf: Vec<u8>,
    write_buf: Vec<u8>,
    write_pos: usize,
    /// Replies leave in request order, whatever order shards finish in.
    pending: VecDeque<Pending>,
    next_req: u64,
    /// Overlong line seen: drop bytes until the next newline.
    discarding: bool,
    read_eof: bool,
    /// No further requests are read (shutdown seen, or aborted).
    stop_reading: bool,
    /// Close once this request's reply has been queued for write.
    close_after_req: Option<u64>,
    /// Close once the write buffer drains.
    close_when_flushed: bool,
    /// Registered epoll interest (avoid redundant `epoll_ctl`).
    reg_read: bool,
    reg_write: bool,
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream, token: poll::Token) -> Conn {
        Conn {
            stream,
            token,
            read_buf: Vec::new(),
            write_buf: Vec::new(),
            write_pos: 0,
            pending: VecDeque::new(),
            next_req: 0,
            discarding: false,
            read_eof: false,
            stop_reading: false,
            close_after_req: None,
            close_when_flushed: false,
            reg_read: true,
            reg_write: false,
            dead: false,
        }
    }

    fn backlog(&self) -> usize {
        self.write_buf.len() - self.write_pos
    }

    /// Non-blocking read until `WouldBlock`, EOF, or the buffer holds a
    /// full overlong line for `process_lines` to refuse.
    fn fill(&mut self, max_buffered: usize) {
        let mut scratch = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut scratch) {
                Ok(0) => {
                    self.read_eof = true;
                    return;
                }
                Ok(n) => {
                    self.read_buf.extend_from_slice(&scratch[..n]);
                    if self.read_buf.len() > max_buffered {
                        return; // cap enforcement runs before the next fill
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
    }

    /// Non-blocking write of the buffered backlog; keeps `write_pos`
    /// across partial writes and compacts once enough has shipped.
    fn flush(&mut self) {
        while self.write_pos < self.write_buf.len() {
            match self.stream.write(&self.write_buf[self.write_pos..]) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => self.write_pos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
        if self.write_pos == self.write_buf.len() {
            self.write_buf.clear();
            self.write_pos = 0;
        } else if self.write_pos > WRITE_COMPACT_BYTES {
            self.write_buf.drain(..self.write_pos);
            self.write_pos = 0;
        }
    }

    /// Append a locally-synthesized reply at this conn's next slot in
    /// the ordered pending queue.
    fn enqueue_done(&mut self, msg: String) {
        let req = self.next_req;
        self.next_req += 1;
        let deadline = Instant::now() + REPLY_TIMEOUT;
        self.pending.push_back(Pending { req, deadline, state: PendingState::Done(msg) });
    }

    /// Move the done prefix of the pending queue into the write buffer
    /// (strict request order). Returns the number of entries popped.
    fn promote_done_replies(&mut self) -> usize {
        let mut popped = 0;
        while matches!(self.pending.front().map(|p| &p.state), Some(PendingState::Done(_))) {
            let p = self.pending.pop_front().expect("checked front");
            if let PendingState::Done(msg) = p.state {
                self.write_buf.extend_from_slice(msg.as_bytes());
                self.write_buf.push(b'\n');
            }
            if self.close_after_req == Some(p.req) {
                self.close_when_flushed = true;
            }
            popped += 1;
        }
        popped
    }
}

// ---------------------------------------------------------------------
// The reactor proper.

pub(crate) struct Reactor {
    poller: Poller,
    listener: Option<TcpListener>,
    router: Router,
    completions: Arc<CompletionQueue>,
    ctl: Arc<Ctl>,
    conns: HashMap<poll::Token, Conn>,
    next_token: poll::Token,
    /// Pending-reply entries across all conns (drives the poll timeout
    /// and the deadline scan; symmetric with promote/removal pops).
    outstanding: usize,
    last_expiry_scan: Instant,
    /// Accepting is paused (listener interest dropped) until this
    /// deadline — the [`ACCEPT_BACKOFF`] after an accept failure.
    accept_paused_until: Option<Instant>,
    max_conns: usize,
    max_line_bytes: usize,
}

impl Reactor {
    pub(crate) fn new(
        listener: TcpListener,
        router: Router,
        cfg: &ServerConfig,
        mut poller: Poller,
        completions: Arc<CompletionQueue>,
        ctl: Arc<Ctl>,
    ) -> Result<Reactor> {
        poller.add(poll::source_fd(&listener), LISTENER_TOKEN, true, false)?;
        Ok(Reactor {
            poller,
            listener: Some(listener),
            router,
            completions,
            ctl,
            conns: HashMap::new(),
            next_token: 1,
            outstanding: 0,
            last_expiry_scan: Instant::now(),
            accept_paused_until: None,
            max_conns: cfg.max_conns,
            max_line_bytes: cfg.max_line_bytes,
        })
    }

    pub(crate) fn run(mut self) {
        if let Err(e) = self.run_loop() {
            crate::info!("reactor: fatal: {e:#}");
        }
        // Unblock a serve shell waiting on the handshake even after a
        // fatal poller error (it degrades instead of hanging).
        self.ctl.advance(CTL_LISTENER_CLOSED);
    }

    fn run_loop(&mut self) -> Result<()> {
        let mut events: Vec<poll::Event> = Vec::new();
        loop {
            // With replies outstanding, wake at least every 500 ms so
            // per-request deadlines fire; with accepting paused, wake
            // when the backoff elapses; fully idle, park until the
            // waker rings (a new completion or the ctl handshake).
            let mut timeout =
                if self.outstanding > 0 { Some(Duration::from_millis(500)) } else { None };
            if let Some(at) = self.accept_paused_until {
                let left = at.saturating_duration_since(Instant::now());
                timeout = Some(timeout.map_or(left, |t| t.min(left)));
            }
            self.poller.wait(&mut events, timeout)?;
            for ev in &events {
                match ev.token {
                    poll::WAKER_TOKEN => {}
                    LISTENER_TOKEN => self.accept_ready(),
                    token => self.conn_event(token, ev.readable, ev.writable),
                }
            }
            self.drain_completions();
            self.expire_deadlines();
            self.resume_accept_if_due();
            if self.handle_ctl() {
                return Ok(());
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            let accepted = match &self.listener {
                Some(listener) => listener.accept(),
                None => return,
            };
            match accepted {
                Ok((stream, _)) => self.register_conn(stream),
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) => {
                    // EMFILE/ENFILE and friends: the backlog entry is
                    // still pending, so the level-triggered listener
                    // would report readable forever. Back off instead
                    // of hot-spinning the whole event loop.
                    crate::debug!("reactor: accept error (pausing accepts): {e}");
                    self.pause_accept();
                    return;
                }
            }
        }
    }

    /// Drop listener read interest for [`ACCEPT_BACKOFF`].
    fn pause_accept(&mut self) {
        if let Some(listener) = &self.listener {
            let _ = self.poller.modify(poll::source_fd(listener), LISTENER_TOKEN, false, false);
        }
        self.accept_paused_until = Some(Instant::now() + ACCEPT_BACKOFF);
    }

    /// Re-arm the listener once the accept backoff has elapsed and try
    /// the pending backlog again.
    fn resume_accept_if_due(&mut self) {
        let due = self.accept_paused_until.is_some_and(|at| Instant::now() >= at);
        if !due {
            return;
        }
        self.accept_paused_until = None;
        if let Some(listener) = &self.listener {
            let _ = self.poller.modify(poll::source_fd(listener), LISTENER_TOKEN, true, false);
        }
        self.accept_ready();
    }

    fn register_conn(&mut self, stream: TcpStream) {
        if self.conns.len() >= self.max_conns {
            // Best-effort refusal line, then drop (closes the socket).
            let mut stream = stream;
            let _ = stream.set_nonblocking(true);
            let _ = stream.write_all(format!("{TOO_MANY_CONNS_REPLY}\n").as_bytes());
            crate::debug!("reactor: refusing connection over max_conns={}", self.max_conns);
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        let token = self.next_token;
        self.next_token += 1;
        if self.poller.add(poll::source_fd(&stream), token, true, false).is_err() {
            return;
        }
        self.conns.insert(token, Conn::new(stream, token));
    }

    fn conn_event(&mut self, token: poll::Token, readable: bool, writable: bool) {
        {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if writable {
                conn.flush();
            }
            let paused = conn.backlog() >= WRITE_PAUSE_BYTES;
            if readable && !conn.stop_reading && !conn.read_eof && !conn.dead && !paused {
                conn.fill(self.max_line_bytes);
            }
        }
        self.process_conn_lines(token);
        self.service_conn(token);
    }

    fn process_conn_lines(&mut self, token: poll::Token) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        let pushed =
            Self::process_lines(&self.router, &self.completions, conn, self.max_line_bytes);
        self.outstanding += pushed;
    }

    /// Frame and dispatch every complete line buffered on `conn`.
    /// Returns the number of pending-reply entries created. Framing
    /// advances a cursor and compacts the consumed prefix once at the
    /// end — a per-line front drain would memmove the whole remaining
    /// buffer per request and make pipelined bursts quadratic.
    fn process_lines(
        router: &Router,
        completions: &Arc<CompletionQueue>,
        conn: &mut Conn,
        max_line: usize,
    ) -> usize {
        let mut pushed = 0;
        let mut cursor = 0usize;
        loop {
            if conn.stop_reading {
                conn.read_buf.clear();
                cursor = 0;
                break;
            }
            if conn.discarding {
                match find_newline(&conn.read_buf[cursor..]) {
                    Some(rel) => {
                        cursor += rel + 1;
                        conn.discarding = false;
                    }
                    None => {
                        conn.read_buf.clear();
                        cursor = 0;
                        break;
                    }
                }
            }
            let Some(rel) = find_newline(&conn.read_buf[cursor..]) else {
                if conn.read_buf.len() - cursor > max_line {
                    // Slow-loris guard: refuse the line, drop what is
                    // buffered, resynchronise at the next newline.
                    conn.enqueue_done(LINE_TOO_LONG_REPLY.to_string());
                    pushed += 1;
                    conn.read_buf.clear();
                    cursor = 0;
                    conn.discarding = true;
                }
                break;
            };
            let (start, len) = (cursor, rel);
            cursor += rel + 1;
            if len > max_line {
                // Overlong but terminated (arrived in one burst).
                conn.enqueue_done(LINE_TOO_LONG_REPLY.to_string());
                pushed += 1;
                continue;
            }
            let text_owned =
                String::from_utf8_lossy(&conn.read_buf[start..start + len]).into_owned();
            let text = text_owned.trim();
            if text.is_empty() {
                continue;
            }
            match Request::parse(text) {
                Ok(req) => {
                    let shutdown = matches!(req, Request::Shutdown);
                    let req_id = conn.next_req;
                    conn.next_req += 1;
                    conn.pending.push_back(Pending {
                        req: req_id,
                        deadline: Instant::now() + REPLY_TIMEOUT,
                        state: PendingState::Waiting,
                    });
                    pushed += 1;
                    let reply = Reply::Completion(CompletionHandle {
                        queue: completions.clone(),
                        conn: conn.token,
                        req: req_id,
                    });
                    if !router.dispatch(req, reply) {
                        // No executor reachable and no reply delivered:
                        // flush what is done and close, like the
                        // threads mode dropping its connection.
                        conn.stop_reading = true;
                        conn.close_when_flushed = true;
                        conn.read_buf.clear();
                        cursor = 0;
                        break;
                    }
                    if shutdown {
                        // Mirror the threads mode: nothing after a
                        // shutdown request is read; the conn closes
                        // once its ack has been written out.
                        conn.stop_reading = true;
                        conn.close_after_req = Some(req_id);
                        conn.read_buf.clear();
                        cursor = 0;
                        break;
                    }
                }
                Err(e) => {
                    let msg = format!("{{\"ok\":false,\"error\":{}}}", escape(&e.to_string()));
                    conn.enqueue_done(msg);
                    pushed += 1;
                }
            }
        }
        if cursor > 0 {
            // One compaction for everything consumed this pass.
            conn.read_buf.drain(..cursor);
        }
        pushed
    }

    /// Route drained completions into their conns' pending queues, then
    /// flush every touched conn. Late replies (request already timed
    /// out and popped) and replies for closed conns are dropped.
    fn drain_completions(&mut self) {
        let items = self.completions.drain();
        if items.is_empty() {
            return;
        }
        let mut touched: Vec<poll::Token> = Vec::with_capacity(items.len());
        for completion in items {
            let Some(conn) = self.conns.get_mut(&completion.conn) else { continue };
            if let Some(p) = conn.pending.iter_mut().find(|p| p.req == completion.req) {
                if matches!(p.state, PendingState::Waiting) {
                    p.state = PendingState::Done(completion.msg);
                    touched.push(completion.conn);
                }
            }
        }
        touched.sort_unstable();
        touched.dedup();
        for token in touched {
            self.service_conn(token);
        }
    }

    /// Answer requests that blew the per-request deadline (the reactor
    /// equivalent of the threads mode's `recv_timeout` reply). Scans at
    /// most every 500 ms and only while replies are outstanding.
    fn expire_deadlines(&mut self) {
        if self.outstanding == 0 || self.last_expiry_scan.elapsed() < Duration::from_millis(500) {
            return;
        }
        self.last_expiry_scan = Instant::now();
        let now = Instant::now();
        let mut touched = Vec::new();
        for (token, conn) in self.conns.iter_mut() {
            let mut hit = false;
            for p in conn.pending.iter_mut() {
                if matches!(p.state, PendingState::Waiting) && p.deadline <= now {
                    p.state = PendingState::Done(TIMEOUT_REPLY.to_string());
                    hit = true;
                }
            }
            if hit {
                touched.push(*token);
            }
        }
        for token in touched {
            self.service_conn(token);
        }
    }

    /// Promote ordered replies, flush, reconcile epoll interest
    /// (pausing reads under write backpressure), and retire the conn
    /// when it is finished.
    fn service_conn(&mut self, token: poll::Token) {
        let popped = match self.conns.get_mut(&token) {
            Some(conn) => {
                let popped = conn.promote_done_replies();
                conn.flush();
                let backlog = conn.backlog();
                if !conn.dead {
                    if conn.close_when_flushed && backlog == 0 {
                        conn.dead = true;
                    } else if conn.read_eof && conn.pending.is_empty() && backlog == 0 {
                        conn.dead = true;
                    }
                }
                if !conn.dead {
                    let want_read =
                        !conn.stop_reading && !conn.read_eof && backlog < WRITE_PAUSE_BYTES;
                    let want_write = backlog > 0;
                    if (want_read, want_write) != (conn.reg_read, conn.reg_write) {
                        let fd = poll::source_fd(&conn.stream);
                        match self.poller.modify(fd, token, want_read, want_write) {
                            Ok(()) => {
                                conn.reg_read = want_read;
                                conn.reg_write = want_write;
                            }
                            Err(_) => conn.dead = true,
                        }
                    }
                }
                popped
            }
            None => 0,
        };
        self.outstanding = self.outstanding.saturating_sub(popped);
        self.reap_if_dead(token);
    }

    fn reap_if_dead(&mut self, token: poll::Token) {
        if self.conns.get(&token).is_some_and(|c| c.dead) {
            if let Some(conn) = self.conns.remove(&token) {
                let _ = self.poller.delete(poll::source_fd(&conn.stream));
                self.outstanding = self.outstanding.saturating_sub(conn.pending.len());
            }
        }
    }

    /// React to the shutdown handshake. Returns true when the reactor
    /// should exit.
    fn handle_ctl(&mut self) -> bool {
        match self.ctl.stage() {
            CTL_CLOSE_LISTENER => {
                if let Some(listener) = self.listener.take() {
                    let _ = self.poller.delete(poll::source_fd(&listener));
                }
                self.ctl.advance(CTL_LISTENER_CLOSED);
                false
            }
            CTL_FINISH => {
                // Degraded path: if the shell skipped the close stage
                // (handshake timeout), still release the port.
                drop(self.listener.take());
                // The shutdown acks were pushed before FINISH was
                // advanced, but possibly after this iteration's drain
                // already ran: drain once more so the final flush sees
                // every completion instead of silently dropping acks.
                self.drain_completions();
                self.final_flush();
                true
            }
            _ => false,
        }
    }

    /// Last chance for buffered replies (notably the shutdown acks):
    /// switch each conn to blocking writes with a short deadline and
    /// push the remainder out before everything closes.
    fn final_flush(&mut self) {
        for conn in self.conns.values_mut() {
            conn.promote_done_replies();
            if conn.backlog() > 0 && !conn.dead {
                let _ = conn.stream.set_nonblocking(false);
                let _ = conn.stream.set_write_timeout(Some(Duration::from_secs(2)));
                let _ = conn.stream.write_all(&conn.write_buf[conn.write_pos..]);
            }
        }
    }
}

fn find_newline(buf: &[u8]) -> Option<usize> {
    buf.iter().position(|&b| b == b'\n')
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctl_stages_are_monotonic_and_waitable() {
        let ctl = Arc::new(Ctl::default());
        assert_eq!(ctl.stage(), CTL_RUNNING);
        ctl.advance(CTL_LISTENER_CLOSED);
        // A stale lower stage never rolls the handshake back.
        ctl.advance(CTL_CLOSE_LISTENER);
        assert_eq!(ctl.stage(), CTL_LISTENER_CLOSED);
        assert!(ctl.wait_at_least(CTL_LISTENER_CLOSED, Duration::from_millis(10)));
        assert!(!ctl.wait_at_least(CTL_FINISH, Duration::from_millis(20)), "must time out");
        let ctl2 = ctl.clone();
        let waiter =
            std::thread::spawn(move || ctl2.wait_at_least(CTL_FINISH, Duration::from_secs(10)));
        ctl.advance(CTL_FINISH);
        assert!(waiter.join().unwrap());
    }

    #[test]
    fn completion_queue_drains_in_push_order_and_wakes() {
        let mut poller = Poller::new().unwrap();
        let queue = Arc::new(CompletionQueue::new(poller.waker()));
        let handle_a = CompletionHandle { queue: queue.clone(), conn: 1, req: 0 };
        let handle_b = CompletionHandle { queue: queue.clone(), conn: 1, req: 1 };
        handle_b.send("second".into());
        handle_a.send("first".into());
        // The pushes rang the waker: a wait pops immediately.
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == poll::WAKER_TOKEN));
        let drained = queue.drain();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].msg, "second");
        assert_eq!(drained[1].req, 0);
        assert!(queue.drain().is_empty());
    }

    #[test]
    fn pending_queue_releases_replies_in_request_order() {
        // Out-of-order completions (two shards finishing at different
        // speeds) must still leave the socket in request order.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        stream.set_nonblocking(true).unwrap();
        let mut conn = Conn::new(stream, 1);
        for req in 0..3u64 {
            conn.pending.push_back(Pending {
                req,
                deadline: Instant::now() + REPLY_TIMEOUT,
                state: PendingState::Waiting,
            });
            conn.next_req += 1;
        }
        // Reply 2 lands first: nothing can be promoted yet.
        conn.pending[2].state = PendingState::Done("r2".into());
        assert_eq!(conn.promote_done_replies(), 0);
        assert!(conn.write_buf.is_empty());
        // Reply 0 lands: only the done prefix (r0) ships.
        conn.pending[0].state = PendingState::Done("r0".into());
        assert_eq!(conn.promote_done_replies(), 1);
        assert_eq!(conn.write_buf, b"r0\n");
        // Reply 1 completes the prefix: r1 then r2, in order.
        conn.pending[0].state = PendingState::Done("r1".into());
        assert_eq!(conn.promote_done_replies(), 2);
        assert_eq!(conn.write_buf, b"r0\nr1\nr2\n");
        assert!(conn.pending.is_empty());
    }

    #[test]
    fn close_after_req_marks_conn_for_close_once_promoted() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let mut conn = Conn::new(stream, 1);
        conn.pending.push_back(Pending {
            req: 0,
            deadline: Instant::now() + REPLY_TIMEOUT,
            state: PendingState::Waiting,
        });
        conn.next_req = 1;
        conn.close_after_req = Some(0);
        assert_eq!(conn.promote_done_replies(), 0);
        assert!(!conn.close_when_flushed, "ack not yet delivered");
        conn.pending[0].state = PendingState::Done("ack".into());
        assert_eq!(conn.promote_done_replies(), 1);
        assert!(conn.close_when_flushed, "conn closes once the ack is queued");
    }
}
