//! Event-driven connection front-end (`--reactor epoll`).
//!
//! The transport is sharded into N reactor threads (`--reactors N`).
//! Each reactor owns its own [`poll::Poller`] (epoll on Linux, a
//! portable scan loop elsewhere), eventfd waker, connection table, and
//! [`CompletionQueue`]; with `SO_REUSEPORT` available every reactor
//! also owns its own listener on the shared address and the kernel
//! hash-balances accepts across them. Without it (non-Linux, old
//! kernels, or `CCM_FORCE_ACCEPT_HANDOFF=1`) reactor 0 owns the single
//! listener and hands accepted sockets round-robin to its peers
//! through per-reactor inboxes ([`HandoffPeer`]). A connection lives
//! its whole life on one reactor, so 10k+ concurrent sessions cost N
//! threads and one `Conn` struct each instead of one OS thread stack.
//!
//! Per connection the reactor keeps an explicit [`Conn`]:
//!
//! * a read buffer with incremental newline framing (capped at
//!   `max_line_bytes`: an overlong line gets a `line_too_long` reply
//!   and the framing resynchronises at the next newline, so a
//!   slow-loris peer cannot pin memory),
//! * a write buffer with partial-write continuation (write interest is
//!   registered only while bytes are buffered; reads pause while the
//!   backlog exceeds [`WRITE_PAUSE_BYTES`] — backpressure instead of
//!   unbounded growth when a client reads slowly),
//! * a pending-reply queue preserving request order: requests are
//!   dispatched to shard executors as soon as they are framed, replies
//!   come back through the [`CompletionQueue`], and are written out
//!   strictly in request order (late replies for timed-out requests
//!   are dropped).
//!
//! Executor shards never touch sockets: [`super::Reply::Completion`]
//! carries the owning reactor's queue, so a reply lands directly in
//! that reactor's [`CompletionQueue`] and rings that reactor's waker —
//! no cross-reactor routing step. Per-request deadlines drive the poll
//! timeout directly (the earliest pending deadline across conns), so a
//! timed-out request is answered promptly rather than on a coarse scan
//! tick. Shutdown is a staged handshake via one [`Ctl`] per reactor,
//! fanned out by the serve shell: every reactor closes its listener
//! (releasing the port) and confirms BEFORE any shutdown ack is
//! written, then the acks travel the normal completion path, then a
//! final flush-and-exit stage closes every connection.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::server::poll::{self, Poller};
use crate::server::router::Router;
use crate::server::{
    LINE_TOO_LONG_REPLY, Reply, Request, ServerConfig, TIMEOUT_REPLY, TOO_MANY_CONNS_REPLY,
};
use crate::util::json::escape;

const LISTENER_TOKEN: poll::Token = 0;
/// Pause reading a connection while this many reply bytes are buffered.
const WRITE_PAUSE_BYTES: usize = 1 << 20;
/// Compact the write buffer once this many bytes have been written out.
const WRITE_COMPACT_BYTES: usize = 64 * 1024;
// The accept backoff (pause after a non-`WouldBlock` accept failure —
// EMFILE/ENFILE, where a level-triggered listener would hot-spin) and
// the refusal linger are operator posture,
// configurable via `ccm serve --accept-backoff-ms` /
// `--refusal-linger-secs` (`cfg.accept_backoff` /
// `cfg.refusal_linger`); defaults live in `ServerConfig::new`.

// ---------------------------------------------------------------------
// Per-reactor transport counters (the stats `per_reactor` breakdown).

/// Live transport counters for one reactor, surfaced through stats.
#[derive(Default)]
pub(crate) struct ReactorStats {
    /// Currently open admitted connections (gauge).
    pub(crate) conns: AtomicUsize,
    /// Total admitted connections (the accept-sharding balance gate).
    pub(crate) accepted: AtomicUsize,
    /// Request lines framed (parsed, refused, or overlong alike).
    pub(crate) lines: AtomicUsize,
    /// `too_many_connections` refusals issued by this reactor.
    pub(crate) refusals: AtomicUsize,
}

/// One slot per reactor; empty in threads mode. Shared between the
/// reactors (writers) and the router (stats reader).
pub(crate) struct ReactorStatsTable {
    slots: Vec<ReactorStats>,
}

impl ReactorStatsTable {
    pub(crate) fn new(reactors: usize) -> ReactorStatsTable {
        ReactorStatsTable { slots: (0..reactors).map(|_| ReactorStats::default()).collect() }
    }

    pub(crate) fn len(&self) -> usize {
        self.slots.len()
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub(crate) fn slot(&self, reactor: usize) -> &ReactorStats {
        &self.slots[reactor]
    }

    /// Comma-joined JSON objects, one per reactor (the caller wraps
    /// them in `"per_reactor":[...]`).
    pub(crate) fn render_rows(&self) -> String {
        let rows: Vec<String> = self
            .slots
            .iter()
            .enumerate()
            .map(|(i, s)| {
                format!(
                    "{{\"reactor\":{i},\"conns\":{},\"accepted\":{},\"lines\":{},\
                     \"refusals\":{}}}",
                    s.conns.load(Ordering::Relaxed), // ordering: stats snapshot
                    s.accepted.load(Ordering::Relaxed), // ordering: stats snapshot
                    s.lines.load(Ordering::Relaxed), // ordering: stats snapshot
                    s.refusals.load(Ordering::Relaxed), // ordering: stats snapshot
                )
            })
            .collect();
        rows.join(",")
    }
}

// ---------------------------------------------------------------------
// Completion delivery (executor shard -> reactor).

/// One reply produced by an executor for a reactor-owned connection.
pub(crate) struct Completion {
    conn: poll::Token,
    req: u64,
    msg: String,
}

/// Shared reply queue: executors push, the owning reactor drains. Every
/// push rings that reactor's waker so delivery latency is one epoll
/// wakeup, and because the [`CompletionHandle`] pins the queue of the
/// reactor that dispatched the request, replies never need a
/// cross-reactor routing step.
pub(crate) struct CompletionQueue {
    items: Mutex<Vec<Completion>>,
    waker: poll::Waker,
}

impl CompletionQueue {
    pub(crate) fn new(waker: poll::Waker) -> CompletionQueue {
        CompletionQueue { items: Mutex::new(Vec::new()), waker }
    }

    fn push(&self, completion: Completion) {
        self.items.lock().unwrap().push(completion);
        self.waker.wake();
    }

    fn drain(&self) -> Vec<Completion> {
        std::mem::take(&mut *self.items.lock().unwrap())
    }
}

/// The reactor-mode [`Reply`]: identifies (connection, request) on the
/// owning reactor so it can slot the reply into the per-conn pending
/// queue.
#[derive(Clone)]
pub(crate) struct CompletionHandle {
    queue: Arc<CompletionQueue>,
    conn: poll::Token,
    req: u64,
}

impl CompletionHandle {
    pub(crate) fn send(&self, msg: String) {
        self.queue.push(Completion { conn: self.conn, req: self.req, msg });
    }
}

// ---------------------------------------------------------------------
// Shutdown handshake (serve shell -> reactor).

pub(crate) const CTL_RUNNING: u8 = 0;
/// Serve shell asks: close the listener (every reactor's port share
/// must be released before shutdown acks are sent — the ack's
/// documented meaning).
pub(crate) const CTL_CLOSE_LISTENER: u8 = 1;
/// Reactor confirms: listener dropped, port free.
pub(crate) const CTL_LISTENER_CLOSED: u8 = 2;
/// Serve shell asks: flush buffered replies (the shutdown acks) and
/// exit, closing every connection.
pub(crate) const CTL_FINISH: u8 = 3;

/// Monotonic shutdown stage shared between the serve shell and one
/// reactor thread (the shell holds one per reactor). Stages only
/// advance.
#[derive(Default)]
pub(crate) struct Ctl {
    stage: Mutex<u8>,
    cv: Condvar,
}

impl Ctl {
    pub(crate) fn advance(&self, stage: u8) {
        let mut s = self.stage.lock().unwrap();
        if *s < stage {
            *s = stage;
        }
        self.cv.notify_all();
    }

    pub(crate) fn stage(&self) -> u8 {
        *self.stage.lock().unwrap()
    }

    /// Wait until the stage reaches `stage`; false on timeout (the
    /// reactor died — callers degrade rather than hang).
    pub(crate) fn wait_at_least(&self, stage: u8, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut s = self.stage.lock().unwrap();
        while *s < stage {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return false;
            }
            // lint: allow(unwrap) — condvar poisoning means a notifier
            // panicked mid-update; propagate the crash.
            let (guard, _) = self.cv.wait_timeout(s, left).unwrap();
            s = guard;
        }
        true
    }
}

// ---------------------------------------------------------------------
// Per-connection state.

enum PendingState {
    /// Dispatched to an executor; the reply will arrive as a completion.
    Waiting,
    /// Reply ready (or synthesized locally: parse error, overlong line,
    /// timeout); written out once every earlier request is done.
    Done(String),
}

struct Pending {
    req: u64,
    deadline: Instant,
    state: PendingState,
}

/// One accepted connection owned by the reactor.
struct Conn {
    stream: TcpStream,
    token: poll::Token,
    read_buf: Vec<u8>,
    write_buf: Vec<u8>,
    write_pos: usize,
    /// Replies leave in request order, whatever order shards finish in.
    pending: VecDeque<Pending>,
    next_req: u64,
    /// Per-request reply deadline (from [`ServerConfig`]).
    reply_timeout: Duration,
    /// Overlong line seen: drop bytes until the next newline.
    discarding: bool,
    read_eof: bool,
    /// No further requests are read (shutdown seen, or aborted).
    stop_reading: bool,
    /// Close once this request's reply has been queued for write.
    close_after_req: Option<u64>,
    /// Close once the write buffer drains.
    close_when_flushed: bool,
    /// Hard kill deadline (refused conns: drop even if the peer never
    /// drains the refusal line).
    expire_at: Option<Instant>,
    /// Holds a `max_conns` slot (false for over-limit refusal conns).
    counted: bool,
    /// Registered epoll interest (avoid redundant `epoll_ctl`).
    reg_read: bool,
    reg_write: bool,
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream, token: poll::Token, reply_timeout: Duration) -> Conn {
        Conn {
            stream,
            token,
            read_buf: Vec::new(),
            write_buf: Vec::new(),
            write_pos: 0,
            pending: VecDeque::new(),
            next_req: 0,
            reply_timeout,
            discarding: false,
            read_eof: false,
            stop_reading: false,
            close_after_req: None,
            close_when_flushed: false,
            expire_at: None,
            counted: true,
            reg_read: true,
            reg_write: false,
            dead: false,
        }
    }

    fn backlog(&self) -> usize {
        self.write_buf.len() - self.write_pos
    }

    /// Non-blocking read until `WouldBlock`, EOF, or the buffer holds a
    /// full overlong line for `process_lines` to refuse.
    fn fill(&mut self, max_buffered: usize) {
        let mut scratch = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut scratch) {
                Ok(0) => {
                    self.read_eof = true;
                    return;
                }
                Ok(n) => {
                    self.read_buf.extend_from_slice(&scratch[..n]);
                    if self.read_buf.len() > max_buffered {
                        return; // cap enforcement runs before the next fill
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
    }

    /// Non-blocking write of the buffered backlog; keeps `write_pos`
    /// across partial writes and compacts once enough has shipped.
    fn flush(&mut self) {
        while self.write_pos < self.write_buf.len() {
            match self.stream.write(&self.write_buf[self.write_pos..]) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => self.write_pos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
        if self.write_pos == self.write_buf.len() {
            self.write_buf.clear();
            self.write_pos = 0;
        } else if self.write_pos > WRITE_COMPACT_BYTES {
            self.write_buf.drain(..self.write_pos);
            self.write_pos = 0;
        }
    }

    /// Append a locally-synthesized reply at this conn's next slot in
    /// the ordered pending queue.
    fn enqueue_done(&mut self, msg: String) {
        let req = self.next_req;
        self.next_req += 1;
        let deadline = Instant::now() + self.reply_timeout;
        self.pending.push_back(Pending { req, deadline, state: PendingState::Done(msg) });
    }

    /// Move the done prefix of the pending queue into the write buffer
    /// (strict request order). Returns the number of entries popped.
    fn promote_done_replies(&mut self) -> usize {
        let mut popped = 0;
        while matches!(self.pending.front().map(|p| &p.state), Some(PendingState::Done(_))) {
            // lint: allow(unwrap) — the loop condition just matched a
            // Done entry at the front.
            let p = self.pending.pop_front().expect("checked front");
            if let PendingState::Done(msg) = p.state {
                self.write_buf.extend_from_slice(msg.as_bytes());
                self.write_buf.push(b'\n');
            }
            if self.close_after_req == Some(p.req) {
                self.close_when_flushed = true;
            }
            popped += 1;
        }
        popped
    }
}

// ---------------------------------------------------------------------
// The reactor proper.

/// Round-robin handoff target (single-listener fallback): reactor 0
/// pushes an accepted socket into a peer's inbox and rings its waker.
pub(crate) struct HandoffPeer {
    pub(crate) inbox: Arc<Mutex<Vec<TcpStream>>>,
    pub(crate) waker: poll::Waker,
}

/// Everything a reactor thread is born with. Built by the serve shell
/// (`run_server_reactor`), one per reactor.
pub(crate) struct ReactorSetup {
    pub(crate) id: usize,
    /// This reactor's own SO_REUSEPORT listener, or (handoff mode) the
    /// single shared listener on reactor 0 only.
    pub(crate) listener: Option<TcpListener>,
    /// Where reactor 0 deposits handed-off sockets for this reactor.
    pub(crate) inbox: Option<Arc<Mutex<Vec<TcpStream>>>>,
    /// Handoff targets, indexed by reactor id (reactor 0 in handoff
    /// mode only; empty means "register accepts locally").
    pub(crate) peers: Vec<HandoffPeer>,
    pub(crate) poller: Poller,
    pub(crate) completions: Arc<CompletionQueue>,
    pub(crate) ctl: Arc<Ctl>,
    /// Admitted-connection count shared across reactors (`--max-conns`
    /// stays a global bound however accepts are sharded).
    pub(crate) conn_count: Arc<AtomicUsize>,
    pub(crate) stats: Arc<ReactorStatsTable>,
}

pub(crate) struct Reactor {
    id: usize,
    poller: Poller,
    listener: Option<TcpListener>,
    inbox: Option<Arc<Mutex<Vec<TcpStream>>>>,
    peers: Vec<HandoffPeer>,
    next_peer: usize,
    router: Router,
    completions: Arc<CompletionQueue>,
    ctl: Arc<Ctl>,
    conns: HashMap<poll::Token, Conn>,
    next_token: poll::Token,
    /// Earliest pending-reply deadline or refusal linger across conns:
    /// drives the poll timeout, so expiries fire when due instead of on
    /// a coarse 500 ms tick. `None` with nothing outstanding.
    next_deadline: Option<Instant>,
    /// Accepting is paused (listener interest dropped) until this
    /// deadline — the `cfg.accept_backoff` after an accept failure.
    accept_paused_until: Option<Instant>,
    conn_count: Arc<AtomicUsize>,
    stats: Arc<ReactorStatsTable>,
    max_conns: usize,
    max_line_bytes: usize,
    reply_timeout: Duration,
    /// Pause after a non-`WouldBlock` accept failure (EMFILE/ENFILE)
    /// before the listener re-arms.
    accept_backoff: Duration,
    /// How long a refused (over `max_conns`) connection may linger
    /// while its refusal line drains to a slow peer.
    refusal_linger: Duration,
}

impl Reactor {
    pub(crate) fn new(setup: ReactorSetup, router: Router, cfg: &ServerConfig) -> Result<Reactor> {
        let ReactorSetup {
            id,
            listener,
            inbox,
            peers,
            mut poller,
            completions,
            ctl,
            conn_count,
            stats,
        } = setup;
        if let Some(listener) = &listener {
            poller.add(poll::source_fd(listener), LISTENER_TOKEN, true, false)?;
        }
        Ok(Reactor {
            id,
            poller,
            listener,
            inbox,
            peers,
            next_peer: 0,
            router,
            completions,
            ctl,
            conns: HashMap::new(),
            next_token: 1,
            next_deadline: None,
            accept_paused_until: None,
            conn_count,
            stats,
            max_conns: cfg.max_conns,
            max_line_bytes: cfg.max_line_bytes,
            reply_timeout: cfg.reply_timeout,
            accept_backoff: cfg.accept_backoff,
            refusal_linger: cfg.refusal_linger,
        })
    }

    fn stat(&self) -> &ReactorStats {
        self.stats.slot(self.id)
    }

    /// Pull `next_deadline` earlier (never later: expiry scans push it
    /// forward only after re-deriving it from live state).
    fn bump_deadline(&mut self, at: Instant) {
        self.next_deadline = Some(self.next_deadline.map_or(at, |cur| cur.min(at)));
    }

    pub(crate) fn run(mut self) {
        if let Err(e) = self.run_loop() {
            crate::info!("reactor {}: fatal: {e:#}", self.id);
        }
        // Unblock a serve shell waiting on the handshake even after a
        // fatal poller error (it degrades instead of hanging).
        self.ctl.advance(CTL_LISTENER_CLOSED);
    }

    fn run_loop(&mut self) -> Result<()> {
        let mut events: Vec<poll::Event> = Vec::new();
        loop {
            // Wake exactly when the earliest pending deadline (reply
            // timeout or refusal linger) is due, or when an accept
            // backoff elapses; fully idle, park until the waker rings
            // (a new completion, a handed-off socket, or the ctl
            // handshake).
            let now = Instant::now();
            let mut timeout = self.next_deadline.map(|at| at.saturating_duration_since(now));
            if let Some(at) = self.accept_paused_until {
                let left = at.saturating_duration_since(now);
                timeout = Some(timeout.map_or(left, |t| t.min(left)));
            }
            self.poller.wait(&mut events, timeout)?;
            for ev in &events {
                match ev.token {
                    poll::WAKER_TOKEN => {}
                    LISTENER_TOKEN => self.accept_ready(),
                    token => self.conn_event(token, ev.readable, ev.writable),
                }
            }
            self.drain_inbox();
            self.drain_completions();
            self.expire_deadlines();
            self.resume_accept_if_due();
            if self.handle_ctl() {
                return Ok(());
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            let accepted = match &self.listener {
                Some(listener) => listener.accept(),
                None => return,
            };
            match accepted {
                Ok((stream, _)) => self.place_conn(stream),
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) => {
                    // EMFILE/ENFILE and friends: the backlog entry is
                    // still pending, so the level-triggered listener
                    // would report readable forever. Back off instead
                    // of hot-spinning the whole event loop.
                    crate::debug!("reactor {}: accept error (pausing accepts): {e}", self.id);
                    self.pause_accept();
                    return;
                }
            }
        }
    }

    /// Route a freshly-accepted socket to its owning reactor: locally
    /// in sharded-accept mode (`peers` empty), round-robin across the
    /// peer inboxes in single-listener handoff mode.
    fn place_conn(&mut self, stream: TcpStream) {
        if self.peers.is_empty() {
            self.register_conn(stream);
            return;
        }
        let target = self.next_peer;
        self.next_peer = (self.next_peer + 1) % self.peers.len();
        if target == self.id {
            self.register_conn(stream);
            return;
        }
        let peer = &self.peers[target];
        peer.inbox.lock().unwrap().push(stream);
        peer.waker.wake();
    }

    /// Adopt sockets handed off by reactor 0 (single-listener mode).
    fn drain_inbox(&mut self) {
        let streams = match &self.inbox {
            Some(inbox) => std::mem::take(&mut *inbox.lock().unwrap()),
            None => return,
        };
        for stream in streams {
            self.register_conn(stream);
        }
    }

    /// Drop listener read interest for `accept_backoff`.
    fn pause_accept(&mut self) {
        if let Some(listener) = &self.listener {
            let _ = self.poller.modify(poll::source_fd(listener), LISTENER_TOKEN, false, false);
        }
        self.accept_paused_until = Some(Instant::now() + self.accept_backoff);
    }

    /// Re-arm the listener once the accept backoff has elapsed and try
    /// the pending backlog again.
    fn resume_accept_if_due(&mut self) {
        let due = self.accept_paused_until.is_some_and(|at| Instant::now() >= at);
        if !due {
            return;
        }
        self.accept_paused_until = None;
        if let Some(listener) = &self.listener {
            let _ = self.poller.modify(poll::source_fd(listener), LISTENER_TOKEN, true, false);
        }
        self.accept_ready();
    }

    fn register_conn(&mut self, stream: TcpStream) {
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        // `max_conns` is global across reactors: claim a slot first,
        // give it back if the bound was already reached.
        if self.conn_count.fetch_add(1, Ordering::SeqCst) >= self.max_conns {
            self.conn_count.fetch_sub(1, Ordering::SeqCst);
            self.refuse_conn(stream);
            return;
        }
        let token = self.next_token;
        self.next_token += 1;
        if self.poller.add(poll::source_fd(&stream), token, true, false).is_err() {
            self.conn_count.fetch_sub(1, Ordering::SeqCst);
            return;
        }
        self.stat().accepted.fetch_add(1, Ordering::Relaxed);
        self.stat().conns.fetch_add(1, Ordering::Relaxed);
        self.conns.insert(token, Conn::new(stream, token, self.reply_timeout));
    }

    /// Refuse a connection over `max_conns`. The socket was just set
    /// nonblocking, so a bare `write_all` could hit `WouldBlock` (or a
    /// partial write) and silently drop the refusal line; instead the
    /// refused socket becomes a short-lived tracked conn owing exactly
    /// one reply — it participates in normal write continuation, closes
    /// once the line is flushed, and a `refusal_linger` deadline
    /// drops it even if the peer never reads.
    fn refuse_conn(&mut self, stream: TcpStream) {
        crate::debug!("reactor {}: refusing connection over max_conns={}", self.id, self.max_conns);
        self.stat().refusals.fetch_add(1, Ordering::Relaxed);
        let token = self.next_token;
        self.next_token += 1;
        if self.poller.add(poll::source_fd(&stream), token, false, false).is_err() {
            return; // cannot even watch the socket: drop it
        }
        let mut conn = Conn::new(stream, token, self.reply_timeout);
        conn.counted = false;
        conn.stop_reading = true;
        conn.reg_read = false;
        conn.enqueue_done(TOO_MANY_CONNS_REPLY.to_string());
        conn.close_after_req = Some(0);
        let expire = Instant::now() + self.refusal_linger;
        conn.expire_at = Some(expire);
        self.bump_deadline(expire);
        self.conns.insert(token, conn);
        self.service_conn(token);
    }

    fn conn_event(&mut self, token: poll::Token, readable: bool, writable: bool) {
        {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if writable {
                conn.flush();
            }
            let paused = conn.backlog() >= WRITE_PAUSE_BYTES;
            if readable && !conn.stop_reading && !conn.read_eof && !conn.dead && !paused {
                conn.fill(self.max_line_bytes);
            }
        }
        self.process_conn_lines(token);
        self.service_conn(token);
    }

    fn process_conn_lines(&mut self, token: poll::Token) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        let pushed =
            Self::process_lines(&self.router, &self.completions, conn, self.max_line_bytes);
        if pushed > 0 {
            self.stat().lines.fetch_add(pushed, Ordering::Relaxed);
            // The entries' deadlines were taken inside process_lines; a
            // bound taken here is never earlier, so expiry cannot fire
            // late because of it.
            self.bump_deadline(Instant::now() + self.reply_timeout);
        }
    }

    /// Frame and dispatch every complete line buffered on `conn`.
    /// Returns the number of pending-reply entries created. Framing
    /// advances a cursor and compacts the consumed prefix once at the
    /// end — a per-line front drain would memmove the whole remaining
    /// buffer per request and make pipelined bursts quadratic.
    fn process_lines(
        router: &Router,
        completions: &Arc<CompletionQueue>,
        conn: &mut Conn,
        max_line: usize,
    ) -> usize {
        let mut pushed = 0;
        let mut cursor = 0usize;
        loop {
            if conn.stop_reading {
                conn.read_buf.clear();
                cursor = 0;
                break;
            }
            if conn.discarding {
                match find_newline(&conn.read_buf[cursor..]) {
                    Some(rel) => {
                        cursor += rel + 1;
                        conn.discarding = false;
                    }
                    None => {
                        conn.read_buf.clear();
                        cursor = 0;
                        break;
                    }
                }
            }
            let Some(rel) = find_newline(&conn.read_buf[cursor..]) else {
                if conn.read_buf.len() - cursor > max_line {
                    // Slow-loris guard: refuse the line, drop what is
                    // buffered, resynchronise at the next newline.
                    conn.enqueue_done(LINE_TOO_LONG_REPLY.to_string());
                    pushed += 1;
                    conn.read_buf.clear();
                    cursor = 0;
                    conn.discarding = true;
                }
                break;
            };
            let (start, len) = (cursor, rel);
            cursor += rel + 1;
            if len > max_line {
                // Overlong but terminated (arrived in one burst).
                conn.enqueue_done(LINE_TOO_LONG_REPLY.to_string());
                pushed += 1;
                continue;
            }
            let text_owned =
                String::from_utf8_lossy(&conn.read_buf[start..start + len]).into_owned();
            let text = text_owned.trim();
            if text.is_empty() {
                continue;
            }
            match Request::parse(text) {
                Ok(req) => {
                    let shutdown = matches!(req, Request::Shutdown);
                    let req_id = conn.next_req;
                    conn.next_req += 1;
                    conn.pending.push_back(Pending {
                        req: req_id,
                        deadline: Instant::now() + conn.reply_timeout,
                        state: PendingState::Waiting,
                    });
                    pushed += 1;
                    let reply = Reply::Completion(CompletionHandle {
                        queue: completions.clone(),
                        conn: conn.token,
                        req: req_id,
                    });
                    if !router.dispatch(req, reply) {
                        // No executor reachable and no reply delivered:
                        // flush what is done and close, like the
                        // threads mode dropping its connection.
                        conn.stop_reading = true;
                        conn.close_when_flushed = true;
                        conn.read_buf.clear();
                        cursor = 0;
                        break;
                    }
                    if shutdown {
                        // Mirror the threads mode: nothing after a
                        // shutdown request is read; the conn closes
                        // once its ack has been written out.
                        conn.stop_reading = true;
                        conn.close_after_req = Some(req_id);
                        conn.read_buf.clear();
                        cursor = 0;
                        break;
                    }
                }
                Err(e) => {
                    let msg = format!("{{\"ok\":false,\"error\":{}}}", escape(&e.to_string()));
                    conn.enqueue_done(msg);
                    pushed += 1;
                }
            }
        }
        if cursor > 0 {
            // One compaction for everything consumed this pass.
            conn.read_buf.drain(..cursor);
        }
        pushed
    }

    /// Route drained completions into their conns' pending queues, then
    /// flush every touched conn. Late replies (request already timed
    /// out and popped) and replies for closed conns are dropped.
    fn drain_completions(&mut self) {
        let items = self.completions.drain();
        if items.is_empty() {
            return;
        }
        let mut touched: Vec<poll::Token> = Vec::with_capacity(items.len());
        for completion in items {
            let Some(conn) = self.conns.get_mut(&completion.conn) else { continue };
            if let Some(p) = conn.pending.iter_mut().find(|p| p.req == completion.req) {
                if matches!(p.state, PendingState::Waiting) {
                    p.state = PendingState::Done(completion.msg);
                    touched.push(completion.conn);
                }
            }
        }
        touched.sort_unstable();
        touched.dedup();
        for token in touched {
            self.service_conn(token);
        }
    }

    /// Answer requests that blew the per-request deadline (the reactor
    /// equivalent of the threads mode's `recv_timeout` reply) and drop
    /// refusal conns past their linger. Runs when `next_deadline` is
    /// due — `run_loop` computes its poll timeout from that same
    /// deadline, so expiry latency is one poll wakeup, not a flat
    /// 500 ms tick plus a coarse scan gate.
    fn expire_deadlines(&mut self) {
        if !self.next_deadline.is_some_and(|at| Instant::now() >= at) {
            return;
        }
        let now = Instant::now();
        let mut touched = Vec::new();
        let mut kill = Vec::new();
        let mut next: Option<Instant> = None;
        for (token, conn) in self.conns.iter_mut() {
            if let Some(at) = conn.expire_at {
                if at <= now {
                    kill.push(*token);
                    continue;
                }
                next = Some(next.map_or(at, |n| n.min(at)));
            }
            let mut hit = false;
            for p in conn.pending.iter_mut() {
                if !matches!(p.state, PendingState::Waiting) {
                    continue;
                }
                if p.deadline <= now {
                    p.state = PendingState::Done(TIMEOUT_REPLY.to_string());
                    hit = true;
                } else {
                    // Deadlines grow with request order, so the first
                    // live one is this conn's minimum.
                    next = Some(next.map_or(p.deadline, |n| n.min(p.deadline)));
                    break;
                }
            }
            if hit {
                touched.push(*token);
            }
        }
        self.next_deadline = next;
        for token in kill {
            if let Some(conn) = self.conns.get_mut(&token) {
                conn.dead = true;
            }
            self.reap_if_dead(token);
        }
        for token in touched {
            self.service_conn(token);
        }
    }

    /// Promote ordered replies, flush, reconcile epoll interest
    /// (pausing reads under write backpressure), and retire the conn
    /// when it is finished.
    fn service_conn(&mut self, token: poll::Token) {
        if let Some(conn) = self.conns.get_mut(&token) {
            conn.promote_done_replies();
            conn.flush();
            let backlog = conn.backlog();
            if !conn.dead {
                if conn.close_when_flushed && backlog == 0 {
                    conn.dead = true;
                } else if conn.read_eof && conn.pending.is_empty() && backlog == 0 {
                    conn.dead = true;
                }
            }
            if !conn.dead {
                let want_read =
                    !conn.stop_reading && !conn.read_eof && backlog < WRITE_PAUSE_BYTES;
                let want_write = backlog > 0;
                if (want_read, want_write) != (conn.reg_read, conn.reg_write) {
                    let fd = poll::source_fd(&conn.stream);
                    match self.poller.modify(fd, token, want_read, want_write) {
                        Ok(()) => {
                            conn.reg_read = want_read;
                            conn.reg_write = want_write;
                        }
                        Err(_) => conn.dead = true,
                    }
                }
            }
        }
        self.reap_if_dead(token);
    }

    fn reap_if_dead(&mut self, token: poll::Token) {
        if self.conns.get(&token).is_some_and(|c| c.dead) {
            if let Some(conn) = self.conns.remove(&token) {
                let _ = self.poller.delete(poll::source_fd(&conn.stream));
                if conn.counted {
                    self.conn_count.fetch_sub(1, Ordering::SeqCst);
                    self.stat().conns.fetch_sub(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// React to the shutdown handshake. Returns true when the reactor
    /// should exit.
    fn handle_ctl(&mut self) -> bool {
        match self.ctl.stage() {
            CTL_CLOSE_LISTENER => {
                if let Some(listener) = self.listener.take() {
                    let _ = self.poller.delete(poll::source_fd(&listener));
                }
                self.ctl.advance(CTL_LISTENER_CLOSED);
                false
            }
            CTL_FINISH => {
                // Degraded path: if the shell skipped the close stage
                // (handshake timeout), still release the port.
                drop(self.listener.take());
                // The shutdown acks were pushed before FINISH was
                // advanced, but possibly after this iteration's drain
                // already ran: drain once more so the final flush sees
                // every completion instead of silently dropping acks.
                self.drain_completions();
                self.final_flush();
                true
            }
            _ => false,
        }
    }

    /// Last chance for buffered replies (notably the shutdown acks):
    /// switch each conn to blocking writes with a short deadline and
    /// push the remainder out before everything closes.
    fn final_flush(&mut self) {
        for conn in self.conns.values_mut() {
            conn.promote_done_replies();
            if conn.backlog() > 0 && !conn.dead {
                let _ = conn.stream.set_nonblocking(false);
                let _ = conn.stream.set_write_timeout(Some(Duration::from_secs(2)));
                let _ = conn.stream.write_all(&conn.write_buf[conn.write_pos..]);
            }
        }
    }
}

fn find_newline(buf: &[u8]) -> Option<usize> {
    buf.iter().position(|&b| b == b'\n')
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::REPLY_TIMEOUT;

    #[test]
    fn ctl_stages_are_monotonic_and_waitable() {
        let ctl = Arc::new(Ctl::default());
        assert_eq!(ctl.stage(), CTL_RUNNING);
        ctl.advance(CTL_LISTENER_CLOSED);
        // A stale lower stage never rolls the handshake back.
        ctl.advance(CTL_CLOSE_LISTENER);
        assert_eq!(ctl.stage(), CTL_LISTENER_CLOSED);
        assert!(ctl.wait_at_least(CTL_LISTENER_CLOSED, Duration::from_millis(10)));
        assert!(!ctl.wait_at_least(CTL_FINISH, Duration::from_millis(20)), "must time out");
        let ctl2 = ctl.clone();
        let waiter =
            std::thread::spawn(move || ctl2.wait_at_least(CTL_FINISH, Duration::from_secs(10)));
        ctl.advance(CTL_FINISH);
        assert!(waiter.join().unwrap());
    }

    #[test]
    fn completion_queue_drains_in_push_order_and_wakes() {
        let mut poller = Poller::new().unwrap();
        let queue = Arc::new(CompletionQueue::new(poller.waker()));
        let handle_a = CompletionHandle { queue: queue.clone(), conn: 1, req: 0 };
        let handle_b = CompletionHandle { queue: queue.clone(), conn: 1, req: 1 };
        handle_b.send("second".into());
        handle_a.send("first".into());
        // The pushes rang the waker: a wait pops immediately.
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == poll::WAKER_TOKEN));
        let drained = queue.drain();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].msg, "second");
        assert_eq!(drained[1].req, 0);
        assert!(queue.drain().is_empty());
    }

    #[test]
    fn pending_queue_releases_replies_in_request_order() {
        // Out-of-order completions (two shards finishing at different
        // speeds) must still leave the socket in request order.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        stream.set_nonblocking(true).unwrap();
        let mut conn = Conn::new(stream, 1, REPLY_TIMEOUT);
        for req in 0..3u64 {
            conn.pending.push_back(Pending {
                req,
                deadline: Instant::now() + REPLY_TIMEOUT,
                state: PendingState::Waiting,
            });
            conn.next_req += 1;
        }
        // Reply 2 lands first: nothing can be promoted yet.
        conn.pending[2].state = PendingState::Done("r2".into());
        assert_eq!(conn.promote_done_replies(), 0);
        assert!(conn.write_buf.is_empty());
        // Reply 0 lands: only the done prefix (r0) ships.
        conn.pending[0].state = PendingState::Done("r0".into());
        assert_eq!(conn.promote_done_replies(), 1);
        assert_eq!(conn.write_buf, b"r0\n");
        // Reply 1 completes the prefix: r1 then r2, in order.
        conn.pending[0].state = PendingState::Done("r1".into());
        assert_eq!(conn.promote_done_replies(), 2);
        assert_eq!(conn.write_buf, b"r0\nr1\nr2\n");
        assert!(conn.pending.is_empty());
    }

    #[test]
    fn close_after_req_marks_conn_for_close_once_promoted() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let mut conn = Conn::new(stream, 1, REPLY_TIMEOUT);
        conn.pending.push_back(Pending {
            req: 0,
            deadline: Instant::now() + REPLY_TIMEOUT,
            state: PendingState::Waiting,
        });
        conn.next_req = 1;
        conn.close_after_req = Some(0);
        assert_eq!(conn.promote_done_replies(), 0);
        assert!(!conn.close_when_flushed, "ack not yet delivered");
        conn.pending[0].state = PendingState::Done("ack".into());
        assert_eq!(conn.promote_done_replies(), 1);
        assert!(conn.close_when_flushed, "conn closes once the ack is queued");
    }

    #[test]
    fn reactor_stats_table_renders_one_row_per_reactor() {
        let table = ReactorStatsTable::new(2);
        assert_eq!(table.len(), 2);
        assert!(!table.is_empty());
        assert!(ReactorStatsTable::new(0).is_empty());
        table.slot(0).accepted.fetch_add(3, Ordering::Relaxed);
        table.slot(0).conns.fetch_add(2, Ordering::Relaxed);
        table.slot(1).lines.fetch_add(7, Ordering::Relaxed);
        table.slot(1).refusals.fetch_add(1, Ordering::Relaxed);
        let json = format!("[{}]", table.render_rows());
        let parsed = crate::util::json::Json::parse(&json).expect("valid JSON rows");
        let rows = parsed.arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("reactor").unwrap().usize().unwrap(), 0);
        assert_eq!(rows[0].get("accepted").unwrap().usize().unwrap(), 3);
        assert_eq!(rows[0].get("conns").unwrap().usize().unwrap(), 2);
        assert_eq!(rows[1].get("lines").unwrap().usize().unwrap(), 7);
        assert_eq!(rows[1].get("refusals").unwrap().usize().unwrap(), 1);
    }
}
