//! Per-shard executor: one continuously-pumped intake → pump → deliver
//! loop owning its own [`Compute`] backend, dynamic batcher, and
//! session manager. PR 1's single global executor, turned into the
//! replicated unit of multi-executor serving: each shard enforces its
//! own slice of the global KV budget, reaps its own idle sessions, and
//! keeps its own [`crate::coordinator::metrics::Metrics`]; the router
//! merges the per-shard stats into the global view. The executor is
//! transport-agnostic — the same loop runs on an in-process shard
//! thread (`serve_sharded`) or inside a `ccm worker` process behind
//! the IPC boundary (`worker.rs`): only the [`Reply`] flavor differs.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::compress::{Compute, StrategyKind};
use crate::coordinator::batcher::WorkKind;
use crate::coordinator::session::Session;
use crate::coordinator::Coordinator;
use crate::model::manifest::Manifest;
use crate::server::hibernate::SpillStore;
use crate::server::router::partition_budget;
use crate::server::{Reply, Request, ServerConfig, StatsQuery};
use crate::util::json::escape;

/// A query whose batch has not executed yet. The response is formatted
/// from the STAGED input length carried with the result (retained-
/// context tiers prepend history to the query tokens).
struct WaitingQuery {
    seq: u64,
    reply: Reply,
    topk: usize,
}

/// One serving shard: the intake/pump/deliver loop plus the request
/// admission state. Constructed per shard (its KV budget is the
/// shard's slice of the global budget) and consumed by [`Executor::run`]
/// on the shard's executor thread.
pub(crate) struct Executor<'a> {
    coord: Coordinator<'a>,
    shard: usize,
    max_wait: Duration,
    /// Admission control: queued work items beyond this are refused.
    max_pending: usize,
    /// This shard's slice of the global compressed-KV budget.
    kv_budget: Option<usize>,
    session_ttl: Option<Duration>,
    /// Artifact shape limits (validated at admission so an oversized
    /// request is a per-request error, not a batch-execution failure).
    chunk_max: usize,
    input_max: usize,
    waiting: VecDeque<WaitingQuery>,
    draining: bool,
    /// Everyone who asked for shutdown; all are acked once drained.
    shutdown_replies: Vec<Reply>,
    /// On-disk hibernation tier (`--hibernate-dir`): `None` disables.
    spill: Option<SpillStore>,
    /// Idle threshold before a resident session spills (resolved from
    /// the config; meaningful only with `spill`).
    hibernate_after: Duration,
}

impl<'a> Executor<'a> {
    pub(crate) fn new(
        manifest: &Manifest,
        backend: Box<dyn Compute + 'a>,
        cfg: &ServerConfig,
        shard: usize,
    ) -> Executor<'a> {
        let mut coord = Coordinator::with_backend(
            manifest,
            backend,
            cfg.policy.clone(),
            cfg.max_batch,
            cfg.max_wait,
        );
        coord.batcher.infer_priority = true; // queries are latency-sensitive
        coord.batcher.set_tiers(cfg.tiers);
        coord.sessions.set_eviction(cfg.eviction.build());
        coord.sessions.set_tiers(&cfg.tiers);
        coord.sessions.set_default_strategy(cfg.default_strategy);
        let shards = cfg.shards.max(1);
        // A spill directory that cannot be opened disables hibernation
        // for this shard (logged) rather than killing it — the tier is
        // an optimization; serving without it is the PR 1 lifecycle.
        let spill = cfg.hibernate_dir.as_ref().and_then(|root| {
            match SpillStore::open(root, shard) {
                Ok(store) => Some(store),
                Err(e) => {
                    crate::info!("shard {shard}: hibernation disabled: {e:#}");
                    None
                }
            }
        });
        Executor {
            coord,
            shard,
            max_wait: cfg.max_wait,
            max_pending: cfg.max_pending,
            kv_budget: cfg.kv_budget_bytes.map(|b| partition_budget(b, shard, shards)),
            session_ttl: cfg.session_ttl,
            chunk_max: manifest.scenario.chunk_max,
            input_max: manifest.scenario.input_max,
            waiting: VecDeque::new(),
            draining: false,
            shutdown_replies: Vec::new(),
            spill,
            hibernate_after: cfg.hibernate_after.unwrap_or(Duration::from_secs(60)),
        }
    }

    /// Run until shutdown; returns the repliers to ack once the caller
    /// has released the listener.
    pub(crate) fn run(mut self, rx: Receiver<(Request, Reply)>) -> Result<Vec<Reply>> {
        let idle_wait = self.max_wait.max(Duration::from_millis(1));
        let intake_cap = (self.coord.batcher.max_batch * 4).max(32);
        let mut disconnected = false;
        let mut last_reap = Instant::now();
        loop {
            // 1. Intake: drain queued requests without stalling the pump.
            let mut got = 0usize;
            while got < intake_cap {
                match rx.try_recv() {
                    Ok((req, reply)) => {
                        self.admit(req, reply);
                        got += 1;
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }

            // 2. Execute at most one batch (force while draining so the
            //    tail flushes without waiting for age triggers), then
            //    immediately deliver whatever finished — queries never
            //    wait for an unrelated session's backlog to drain.
            // A batch-execution failure must not kill the shard (it owns
            // every resident session's memory): fail exactly the queries
            // whose batch died, leave unrelated queued work alone, and
            // keep serving.
            let n = match self.coord.pump(self.draining || disconnected) {
                Ok(n) => n,
                Err(e) => {
                    crate::info!("shard {}: batch execution failed: {e:#}", self.shard);
                    let msg = format!(
                        "{{\"ok\":false,\"error\":{}}}",
                        escape(&format!("execution failed: {e:#}"))
                    );
                    let failed = self.coord.take_failed();
                    self.waiting.retain(|w| {
                        if failed.contains(&w.seq) {
                            let _ = w.reply.send(msg.clone());
                            false
                        } else {
                            true
                        }
                    });
                    0
                }
            };
            self.deliver_finished();
            if self.waiting.is_empty() {
                // Any result with no waiting consumer is orphaned (its
                // query was failed on a batch error): free it.
                self.coord.clear_results();
            }
            if n > 0 {
                // KV only grows inside pump, so enforcing right after
                // keeps the shard under its budget slice at every
                // observable point.
                if let Some(budget) = self.kv_budget {
                    let evicted = self.enforce_budget(budget);
                    if evicted > 0 {
                        crate::debug!(
                            "shard {}: kv budget {budget}: evicted {evicted} sessions",
                            self.shard
                        );
                    }
                }
            }

            // 3. Idle-session housekeeping on a coarse timer: spill
            //    cold sessions to the hibernation tier, then reap
            //    expired ones (resident and hibernated alike).
            if (self.session_ttl.is_some() || self.spill.is_some())
                && last_reap.elapsed() >= Duration::from_millis(100)
            {
                last_reap = Instant::now();
                self.spill_idle();
                if let Some(ttl) = self.session_ttl {
                    self.coord.reap_idle(ttl, Instant::now());
                    let reaped = self.coord.sessions.reap_hibernated(ttl, Instant::now());
                    if !reaped.is_empty() {
                        if let Some(store) = &self.spill {
                            for id in &reaped {
                                store.discard(id);
                            }
                        }
                        self.coord.metrics.sessions_reaped += reaped.len() as u64;
                    }
                }
            }

            // 4. Graceful shutdown once in-flight work is drained.
            if (self.draining || disconnected)
                && self.coord.pending() == 0
                && self.waiting.is_empty()
            {
                crate::info!("shard {} shutdown: {}", self.shard, self.coord.metrics.report());
                return Ok(std::mem::take(&mut self.shutdown_replies));
            }

            // 5. Nothing executed and nothing arrived: block for the
            //    next request. With queued-but-unripe work, wake within
            //    max_wait so the age trigger fires; fully idle, park
            //    long (a reap tick if a TTL is set, else effectively
            //    until woken) rather than spinning at millisecond
            //    cadence.
            if n == 0 && got == 0 && !disconnected {
                let fully_idle =
                    self.coord.pending() == 0 && self.waiting.is_empty() && !self.draining;
                let wait = if !fully_idle {
                    idle_wait
                } else if self.session_ttl.is_some() || self.spill.is_some() {
                    Duration::from_millis(100)
                } else {
                    Duration::from_secs(3600)
                };
                match rx.recv_timeout(wait) {
                    Ok((req, reply)) => self.admit(req, reply),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => disconnected = true,
                }
            }
        }
    }

    /// The tier a request for `session` is accounted against: the
    /// resident session's pinned strategy, else the request's explicit
    /// one (first touch), else the server default.
    fn strategy_of(&self, session: &str, requested: Option<StrategyKind>) -> StrategyKind {
        self.coord
            .sessions
            .get(session)
            .ok()
            .map(|s| s.strategy)
            .or(requested)
            .unwrap_or_else(|| self.coord.sessions.default_strategy())
    }

    /// Transparently restore a hibernated session before the request
    /// touches it. Checks the DISK whenever the session is not resident
    /// (not just the hibernated side-table), so a respawned worker
    /// inherits its predecessor's spill directory and Mem(t) survives a
    /// worker restart. The failure contract: a corrupt or missing
    /// snapshot degrades to a fresh session (== eviction) — never a
    /// client-visible error, never a panic.
    fn rehydrate(&mut self, session: &str) {
        let Some(store) = &self.spill else { return };
        if self.coord.sessions.get(session).is_ok() {
            return; // resident wins: its state is newer than any spill
        }
        match store.load(session) {
            Ok(Some(snap)) => {
                store.discard(session);
                self.coord.sessions.insert_restored(Session::from_snapshot(snap));
                self.coord.metrics.rehydrations += 1;
            }
            Ok(None) => {
                // Side-table entry without a file (reaped/corrupt-swept
                // behind our back): forget it and start fresh.
                self.coord.sessions.drop_hibernated(session);
            }
            Err(e) => {
                crate::info!("shard {}: corrupt snapshot for {session:?}: {e:#}", self.shard);
                store.discard(session);
                self.coord.sessions.drop_hibernated(session);
                self.coord.metrics.snapshot_corrupt += 1;
            }
        }
    }

    /// Spill sessions idle past the hibernate threshold: snapshot to
    /// disk first, and only on a successful write move the session to
    /// the hibernated side-table (its KV leaves the budget). A failed
    /// spill keeps the session hot — hibernation may never lose state.
    fn spill_idle(&mut self) {
        let Some(store) = &self.spill else { return };
        let now = Instant::now();
        let protected = self.coord.batcher.pending_sessions();
        let idle = self.coord.sessions.idle_sessions(self.hibernate_after, now, &protected);
        for id in idle {
            let Ok(session) = self.coord.sessions.get(&id) else { continue };
            match store.spill(&session.to_snapshot()) {
                Ok(()) => {
                    self.coord.sessions.hibernate(&id);
                    self.coord.metrics.spills += 1;
                }
                Err(e) => {
                    crate::info!(
                        "shard {}: spill of idle session {id:?} failed (kept hot): {e:#}",
                        self.shard
                    );
                }
            }
        }
    }

    /// Enforce this shard's KV-budget slice. Without a spill store this
    /// is plain eviction; with one, every victim is spilled to disk
    /// before its RAM is dropped (spill-before-drop), so a budget
    /// squeeze demotes sessions to the hibernation tier instead of
    /// erasing them. A victim whose spill fails degrades to the plain
    /// drop. Returns how many sessions left residence.
    fn enforce_budget(&mut self, budget: usize) -> usize {
        if self.coord.sessions.total_kv_bytes() <= budget {
            return 0; // common case: no protected-set allocation
        }
        let Some(store) = &self.spill else {
            return self.coord.enforce_kv_budget(budget).len();
        };
        let protected = self.coord.batcher.pending_sessions();
        let victims = self.coord.sessions.take_victims_to_budget(budget, &protected);
        let n = victims.len();
        self.coord.metrics.sessions_evicted += n as u64;
        for victim in victims {
            match store.spill(&victim.to_snapshot()) {
                Ok(()) => {
                    self.coord.sessions.note_hibernated(&victim);
                    self.coord.metrics.spills += 1;
                }
                Err(e) => {
                    crate::info!(
                        "shard {}: spill of evicted session {:?} failed (dropped): {e:#}",
                        self.shard,
                        victim.id
                    );
                }
            }
        }
        n
    }

    fn admit(&mut self, req: Request, reply: Reply) {
        match req {
            Request::Context { session, tokens, strategy } => {
                self.rehydrate(&session);
                let strat = self.strategy_of(&session, strategy);
                if let Some(refusal) = self.refuse(strat) {
                    let _ = reply.send(refusal);
                    return;
                }
                if tokens.len() > self.chunk_max {
                    let _ = reply.send(too_long("chunk", tokens.len(), self.chunk_max));
                    return;
                }
                self.coord.add_context_strat(&session, tokens, strategy);
                // Ack with the step the chunk will actually land on: t
                // advances once per queued chunk, so two chunks queued
                // in one window ack t+1 and t+2. `kv_bytes` is the
                // tier-aware cost (compressed memory + retained raw).
                let queued = self.coord.batcher.queued_for(&session, WorkKind::Compress);
                let s = self.coord.sessions.get_or_create(&session);
                let msg = format!(
                    "{{\"ok\":true,\"kind\":\"context\",\"t\":{},\"kv_bytes\":{},\
                     \"strategy\":{}}}",
                    s.t + queued,
                    s.kv_bytes(),
                    escape(s.strategy.name())
                );
                let _ = reply.send(msg);
            }
            Request::Query { session, tokens, topk } => {
                self.rehydrate(&session);
                let strat = self.strategy_of(&session, None);
                if let Some(refusal) = self.refuse(strat) {
                    let _ = reply.send(refusal);
                    return;
                }
                if tokens.len() > self.input_max {
                    let _ = reply.send(too_long("input", tokens.len(), self.input_max));
                    return;
                }
                let seq = self.coord.query(&session, tokens);
                self.waiting.push_back(WaitingQuery { seq, reply, topk });
            }
            Request::Stats(q) => {
                let _ = reply.send(self.stats_json(&q));
            }
            Request::Shutdown => {
                // Every shutdown requester is acked only once the drain
                // completes — the ack means "listener closed, port free".
                self.draining = true;
                self.shutdown_replies.push(reply);
            }
        }
    }

    /// Admission control: refuse new work while draining or over the
    /// pending bound. Returns the refusal response, if any; overload
    /// refusals are attributed to the requesting session's tier.
    fn refuse(&mut self, strat: StrategyKind) -> Option<String> {
        if self.draining {
            return Some(format!(
                "{{\"ok\":false,\"error\":\"shutting_down\",\"pending\":{}}}",
                self.coord.pending()
            ));
        }
        if self.coord.pending() >= self.max_pending {
            self.coord.metrics.rejected_overload += 1;
            self.coord.metrics.by_strategy[strat.index()].refusals += 1;
            return Some(format!(
                "{{\"ok\":false,\"error\":\"overloaded\",\"pending\":{}}}",
                self.coord.pending()
            ));
        }
        None
    }

    fn deliver_finished(&mut self) {
        let coord = &mut self.coord;
        self.waiting.retain(|w| {
            if let Some((logits, staged_len)) = coord.take_result_staged(w.seq) {
                let msg = format_query_response(&logits, staged_len, w.topk);
                let _ = w.reply.send(msg);
                false
            } else {
                true
            }
        });
    }

    /// This shard's stats object. Alongside live usage it reports the
    /// configured limits (KV budget slice, idle TTL, pending bound,
    /// eviction policy) so operators can compute headroom without
    /// reading CLI flags. With `detail`, a `sessions_detail` array
    /// carries per-session accounting (id, t, kv_bytes, age/idle),
    /// optionally bounded by the query's `prefix`/`limit`. When the
    /// router injected `per_reactor` rows (single-shard epoll serving),
    /// they are embedded verbatim — the executor itself never sees the
    /// transport layer.
    fn stats_json(&self, q: &StatsQuery) -> String {
        let m = &self.coord.metrics;
        let detail_field = if q.detail {
            format!("\"sessions_detail\":{},", self.sessions_detail_json(q))
        } else {
            String::new()
        };
        let reactor_field = match &q.per_reactor {
            Some(rows) => format!("\"per_reactor\":[{rows}],"),
            None => String::new(),
        };
        format!(
            "{{\"ok\":true,\"kind\":\"stats\",\"shard\":{},\"eviction\":{},\"sessions\":{},\
             \"kv_bytes\":{},\"kv_budget_bytes\":{},\"session_ttl_secs\":{},\"max_pending\":{},\
             \"pending\":{},\"waiting\":{},\"requests\":{},\"compressions\":{},\"inferences\":{},\
             \"batches\":{},\"rejected_overload\":{},\"sessions_evicted\":{},\
             \"sessions_reaped\":{},\"hibernated_sessions\":{},\"hibernated_bytes\":{},\
             \"spills\":{},\"rehydrations\":{},\"snapshot_corrupt\":{},\
             \"priority_overrides\":{},\"peak_kv_bytes\":{},\
             \"strategies\":{},{reactor_field}{detail_field}\"report\":{}}}",
            self.shard,
            escape(self.coord.sessions.eviction_name()),
            self.coord.sessions.len(),
            self.coord.sessions.total_kv_bytes(),
            self.kv_budget.map_or_else(|| "null".to_string(), |b| b.to_string()),
            self.session_ttl.map_or_else(|| "null".to_string(), |t| t.as_secs().to_string()),
            self.max_pending,
            self.coord.pending(),
            self.waiting.len(),
            m.requests,
            m.compressions,
            m.inferences,
            m.batches,
            m.rejected_overload,
            m.sessions_evicted,
            m.sessions_reaped,
            self.coord.sessions.hibernated_census().0,
            self.coord.sessions.hibernated_census().1,
            m.spills,
            m.rehydrations,
            m.snapshot_corrupt,
            self.coord.batcher.total_overrides(),
            m.peak_kv_bytes,
            self.strategies_json(),
            escape(&m.report()),
        )
    }

    /// Per-tier accounting: resident sessions + tier-aware KV bytes
    /// (live gauges from the session census), compress/infer work,
    /// lossy-retention drops, overload refusals, and scheduling
    /// overrides charged to the tier. Every tier is always present
    /// (zeroed when unused) so the router's merge can sum blindly.
    fn strategies_json(&self) -> String {
        let census = self.coord.sessions.census();
        let overrides = self.coord.batcher.overrides_by_strategy();
        let rows: Vec<String> = StrategyKind::ALL
            .iter()
            .map(|k| {
                let i = k.index();
                let by = &self.coord.metrics.by_strategy[i];
                format!(
                    "{}:{{\"sessions\":{},\"kv_bytes\":{},\"compressions\":{},\
                     \"inferences\":{},\"tokens_dropped\":{},\"refusals\":{},\"overrides\":{}}}",
                    escape(k.name()),
                    census[i].0,
                    census[i].1,
                    by.compressions,
                    by.inferences,
                    by.tokens_dropped,
                    by.refusals,
                    overrides[i]
                )
            })
            .collect();
        format!("{{{}}}", rows.join(","))
    }

    /// Per-session accounting rows, sorted by session id: the ROADMAP
    /// open item "surface per-session stats (age, kv_bytes, last_used)"
    /// — ages as integer milliseconds so the stress gate can assert
    /// session accounting after churn without float parsing. The
    /// query's `prefix`/`limit` bound the view for large fleets.
    fn sessions_detail_json(&self, q: &StatsQuery) -> String {
        let now = Instant::now();
        let rows: Vec<String> = self
            .coord
            .sessions
            .snapshot_filtered(now, q.prefix.as_deref(), q.after_id.as_deref(), q.limit)
            .into_iter()
            .map(|s| {
                format!(
                    "{{\"id\":{},\"t\":{},\"kv_bytes\":{},\"age_ms\":{},\"idle_ms\":{},\
                     \"strategy\":{}}}",
                    escape(&s.id),
                    s.t,
                    s.kv_bytes,
                    s.age.as_millis(),
                    s.idle.as_millis(),
                    escape(s.strategy.name())
                )
            })
            .collect();
        format!("[{}]", rows.join(","))
    }
}

/// `{"ok":false,"error":"too_long",...}` for oversized token lists.
fn too_long(what: &str, got: usize, limit: usize) -> String {
    format!(
        "{{\"ok\":false,\"error\":\"too_long\",\"what\":\"{what}\",\"got\":{got},\"limit\":{limit}}}"
    )
}

/// Top-k next-token distribution at the last real input position.
/// Total order via `f32::total_cmp`: a NaN logit (a backend bug) must
/// degrade to a bad ranking, not a panicking comparator in the server.
fn format_query_response(logits: &crate::tensor::Tensor, input_len: usize, topk: usize) -> String {
    let row = logits.row(&[input_len.saturating_sub(1)]);
    // Normalize over the finite logits only: one NaN must not poison
    // the log-sum-exp (and thereby every logprob in the response).
    let finite = || row.iter().copied().filter(|x| x.is_finite());
    let mx = finite().fold(f32::NEG_INFINITY, f32::max);
    let lse: f32 = finite().map(|x| (x - mx).exp()).sum::<f32>().ln() + mx;
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
    let pairs: Vec<String> = idx
        .iter()
        .take(topk)
        .map(|&i| {
            let lp = row[i] - lse;
            // JSON has no NaN/Infinity literal; degrade to null.
            if lp.is_finite() {
                format!("[{},{:.4}]", i, lp)
            } else {
                format!("[{},null]", i)
            }
        })
        .collect();
    format!("{{\"ok\":true,\"kind\":\"query\",\"next\":[{}]}}", pairs.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SimCompute;
    use crate::coordinator::session::{EvictionKind, SessionPolicy};
    use crate::util::json::Json;
    use std::sync::mpsc::channel;

    fn toy_executor(tune: impl FnOnce(&mut ServerConfig)) -> Executor<'static> {
        let m = Manifest::toy();
        let sim = SimCompute::from_manifest(&m);
        let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
        cfg.max_batch = 4;
        cfg.max_wait = Duration::ZERO;
        tune(&mut cfg);
        Executor::new(&m, Box::new(sim), &cfg, 0)
    }

    fn recv_json(rx: &std::sync::mpsc::Receiver<String>) -> Json {
        Json::parse(&rx.recv().expect("reply")).expect("valid JSON reply")
    }

    fn reply_to(tx: &std::sync::mpsc::Sender<String>) -> Reply {
        Reply::channel(tx.clone())
    }

    #[test]
    fn admission_acks_queued_steps_and_refuses_over_bound() {
        let mut ex = toy_executor(|cfg| cfg.max_pending = 2);

        // Two chunks queued in one window ack t=1 and t=2 (seed bug:
        // both acked t=1).
        let (tx, rx) = channel();
        let ctx = |toks: Vec<i32>| Request::Context {
            session: "u".into(),
            tokens: toks,
            strategy: None,
        };
        ex.admit(ctx(vec![4, 5]), reply_to(&tx));
        assert_eq!(recv_json(&rx).get("t").unwrap().i64().unwrap(), 1);
        ex.admit(ctx(vec![6, 7]), reply_to(&tx));
        assert_eq!(recv_json(&rx).get("t").unwrap().i64().unwrap(), 2);

        // The pending bound is hit: the third chunk is refused.
        ex.admit(ctx(vec![8]), reply_to(&tx));
        let refusal = recv_json(&rx);
        assert_eq!(refusal.get("ok").unwrap(), &Json::Bool(false));
        assert_eq!(refusal.get("error").unwrap().str().unwrap(), "overloaded");
        assert_eq!(refusal.get("pending").unwrap().usize().unwrap(), 2);
        assert_eq!(ex.coord.metrics.rejected_overload, 1);

        // After executing, acks continue from the session's real step.
        ex.coord.run_until_idle().unwrap();
        ex.admit(ctx(vec![9]), reply_to(&tx));
        assert_eq!(recv_json(&rx).get("t").unwrap().i64().unwrap(), 3);

        // Oversized requests are refused at admission, not detonated
        // inside a batch (which would take the whole shard down).
        ex.admit(ctx(vec![0; 9]), reply_to(&tx));
        let refusal = recv_json(&rx);
        assert_eq!(refusal.get("error").unwrap().str().unwrap(), "too_long");
        assert_eq!(refusal.get("limit").unwrap().usize().unwrap(), 8);
        let query = Request::Query { session: "u".into(), tokens: vec![0; 9], topk: 1 };
        ex.admit(query, reply_to(&tx));
        assert_eq!(recv_json(&rx).get("error").unwrap().str().unwrap(), "too_long");
        assert!(ex.waiting.is_empty(), "refused query must not wait for results");
        ex.coord.run_until_idle().expect("no oversized item reached the backend");
    }

    #[test]
    fn admission_refuses_new_work_while_draining() {
        let mut ex = toy_executor(|_| {});
        let (tx, rx) = channel();
        ex.admit(Request::Shutdown, reply_to(&tx));
        assert!(ex.draining && ex.shutdown_replies.len() == 1);
        ex.admit(Request::Query { session: "q".into(), tokens: vec![1], topk: 1 }, reply_to(&tx));
        let refusal = recv_json(&rx);
        assert_eq!(refusal.get("error").unwrap().str().unwrap(), "shutting_down");
        assert_eq!(ex.coord.pending(), 0, "refused work must not be queued");
        // Stats are still served during the drain.
        ex.admit(Request::Stats(StatsQuery::default()), reply_to(&tx));
        let stats = recv_json(&rx);
        assert_eq!(stats.get("kind").unwrap().str().unwrap(), "stats");
        // A second shutdown during the drain is deferred too: the ack
        // contract is "drained, listener closed", so nobody is acked
        // until then.
        ex.admit(Request::Shutdown, reply_to(&tx));
        assert_eq!(ex.shutdown_replies.len(), 2);
        assert!(rx.try_recv().is_err(), "no shutdown ack may be sent before the drain completes");
    }

    #[test]
    fn stats_json_reports_configured_limits_alongside_live_usage() {
        // Operators must be able to compute headroom (budget - usage,
        // TTL, pending bound, policy) from the stats response alone,
        // without reading back the CLI flags the server started with.
        let mut ex = toy_executor(|cfg| {
            cfg.kv_budget_bytes = Some(1 << 20);
            cfg.session_ttl = Some(Duration::from_secs(600));
            cfg.max_pending = 64;
            cfg.eviction = EvictionKind::Lru;
        });
        ex.coord.add_context("a", vec![1, 2]);
        ex.coord.run_until_idle().unwrap();
        let s = ex.stats_json(&StatsQuery::default());
        let j = Json::parse(&s).expect("stats must be valid JSON");
        assert_eq!(j.get("shard").unwrap().usize().unwrap(), 0);
        assert_eq!(j.get("sessions").unwrap().usize().unwrap(), 1);
        assert_eq!(j.get("kv_budget_bytes").unwrap().usize().unwrap(), 1 << 20);
        assert_eq!(j.get("session_ttl_secs").unwrap().usize().unwrap(), 600);
        assert_eq!(j.get("max_pending").unwrap().usize().unwrap(), 64);
        assert_eq!(j.get("eviction").unwrap().str().unwrap(), "lru");
        assert!(j.get("kv_bytes").unwrap().usize().unwrap() > 0);
        // The multi-line report embeds as a proper JSON string (the
        // seed used {:?}, which can emit non-JSON escapes).
        assert!(j.get("report").unwrap().str().unwrap().contains("requests="));
    }

    #[test]
    fn stats_json_reports_null_limits_when_unconfigured() {
        let ex = toy_executor(|_| {});
        let j = Json::parse(&ex.stats_json(&StatsQuery::default())).unwrap();
        assert_eq!(j.get("kv_budget_bytes").unwrap(), &Json::Null);
        assert_eq!(j.get("session_ttl_secs").unwrap(), &Json::Null);
        assert_eq!(j.get("eviction").unwrap().str().unwrap(), "oldest");
    }

    #[test]
    fn stats_detail_lists_sessions_sorted_with_live_accounting() {
        let mut ex = toy_executor(|_| {});
        // "b" compresses twice, "a" once, "q" only queries (t stays 0).
        ex.coord.add_context("b", vec![1, 2]);
        ex.coord.add_context("b", vec![3, 4]);
        ex.coord.add_context("a", vec![5, 6]);
        ex.coord.query("q", vec![7]);
        ex.coord.run_until_idle().unwrap();

        // Without detail the array is absent (response stays small).
        let plain = Json::parse(&ex.stats_json(&StatsQuery::default())).unwrap();
        assert!(plain.opt("sessions_detail").is_none());

        let j = Json::parse(&ex.stats_json(&StatsQuery::detailed()))
            .expect("detail stats must be valid JSON");
        let list = j.get("sessions_detail").unwrap().arr().unwrap();
        assert_eq!(list.len(), 3);
        let ids: Vec<&str> = list.iter().map(|s| s.get("id").unwrap().str().unwrap()).collect();
        assert_eq!(ids, vec!["a", "b", "q"], "rows must sort by id");
        assert_eq!(list[0].get("t").unwrap().usize().unwrap(), 1);
        assert_eq!(list[1].get("t").unwrap().usize().unwrap(), 2);
        assert_eq!(list[2].get("t").unwrap().usize().unwrap(), 0);
        // Per-session kv sums to the aggregate the same response reports.
        let kv_sum: usize =
            list.iter().map(|s| s.get("kv_bytes").unwrap().usize().unwrap()).sum();
        assert_eq!(kv_sum, j.get("kv_bytes").unwrap().usize().unwrap());
        assert!(list[1].get("kv_bytes").unwrap().usize().unwrap() > 0);
        for s in list {
            // A session can never have been idle longer than it exists.
            let age = s.get("age_ms").unwrap().usize().unwrap();
            let idle = s.get("idle_ms").unwrap().usize().unwrap();
            assert!(idle <= age, "idle {idle} > age {age}");
        }
    }

    #[test]
    fn stats_detail_respects_prefix_limit_and_embeds_reactor_rows() {
        let mut ex = toy_executor(|_| {});
        for id in ["a1", "a2", "b1"] {
            ex.coord.add_context(id, vec![1, 2]);
        }
        ex.coord.run_until_idle().unwrap();
        // Prefix keeps only matching ids; counters still cover all.
        let q = StatsQuery { detail: true, prefix: Some("a".into()), ..Default::default() };
        let j = Json::parse(&ex.stats_json(&q)).unwrap();
        let ids: Vec<&str> = j
            .get("sessions_detail")
            .unwrap()
            .arr()
            .unwrap()
            .iter()
            .map(|s| s.get("id").unwrap().str().unwrap())
            .collect();
        assert_eq!(ids, vec!["a1", "a2"]);
        assert_eq!(j.get("sessions").unwrap().usize().unwrap(), 3, "counters stay global");
        // Limit truncates to the first N rows by id.
        let q = StatsQuery { detail: true, limit: Some(1), ..Default::default() };
        let j = Json::parse(&ex.stats_json(&q)).unwrap();
        let list = j.get("sessions_detail").unwrap().arr().unwrap();
        assert_eq!(list.len(), 1);
        assert_eq!(list[0].get("id").unwrap().str().unwrap(), "a1");
        // Router-injected per_reactor rows are embedded verbatim.
        let q = StatsQuery {
            per_reactor: Some(
                "{\"reactor\":0,\"conns\":1,\"accepted\":2,\"lines\":3,\"refusals\":0}".into(),
            ),
            ..Default::default()
        };
        let j = Json::parse(&ex.stats_json(&q)).unwrap();
        let rows = j.get("per_reactor").unwrap().arr().unwrap();
        assert_eq!(rows[0].get("accepted").unwrap().usize().unwrap(), 2);
        // Without injection the field is absent.
        let j = Json::parse(&ex.stats_json(&StatsQuery::default())).unwrap();
        assert!(j.opt("per_reactor").is_none());
    }

    #[test]
    fn stats_detail_after_id_cursor_chains_pages() {
        let mut ex = toy_executor(|_| {});
        for id in ["u0", "u1", "u2", "u3", "u4"] {
            ex.coord.add_context(id, vec![1]);
        }
        ex.coord.run_until_idle().unwrap();
        let page = |ex: &Executor, after: Option<&str>| -> Vec<String> {
            let q = StatsQuery {
                detail: true,
                after_id: after.map(str::to_string),
                limit: Some(2),
                ..Default::default()
            };
            Json::parse(&ex.stats_json(&q))
                .unwrap()
                .get("sessions_detail")
                .unwrap()
                .arr()
                .unwrap()
                .iter()
                .map(|s| s.get("id").unwrap().str().unwrap().to_string())
                .collect()
        };
        assert_eq!(page(&ex, None), vec!["u0", "u1"]);
        assert_eq!(page(&ex, Some("u1")), vec!["u2", "u3"]);
        assert_eq!(page(&ex, Some("u3")), vec!["u4"]);
        assert!(page(&ex, Some("u4")).is_empty(), "past the last id the page is empty");
    }

    #[test]
    fn admission_pins_strategy_and_stats_report_per_tier_counters() {
        let mut ex = toy_executor(|_| {});
        let (tx, rx) = channel();
        let ctx = |sess: &str, strat: Option<StrategyKind>| Request::Context {
            session: sess.into(),
            tokens: vec![1, 2],
            strategy: strat,
        };
        ex.admit(ctx("w", Some(StrategyKind::SlidingWindow)), reply_to(&tx));
        assert_eq!(recv_json(&rx).get("strategy").unwrap().str().unwrap(), "sliding-window");
        ex.admit(ctx("c", None), reply_to(&tx));
        assert_eq!(recv_json(&rx).get("strategy").unwrap().str().unwrap(), "ccm");
        // A later chunk cannot re-tier the session: first touch pinned it.
        ex.admit(ctx("w", Some(StrategyKind::NoCompress)), reply_to(&tx));
        assert_eq!(recv_json(&rx).get("strategy").unwrap().str().unwrap(), "sliding-window");
        ex.coord.run_until_idle().unwrap();

        let j = Json::parse(&ex.stats_json(&StatsQuery::detailed())).unwrap();
        let strat = j.get("strategies").unwrap();
        let win = strat.get("sliding-window").unwrap();
        assert_eq!(win.get("sessions").unwrap().usize().unwrap(), 1);
        assert_eq!(win.get("compressions").unwrap().usize().unwrap(), 2);
        let ccm = strat.get("ccm").unwrap();
        assert_eq!(ccm.get("sessions").unwrap().usize().unwrap(), 1);
        assert_eq!(ccm.get("compressions").unwrap().usize().unwrap(), 1);
        let none = strat.get("none").unwrap();
        assert_eq!(none.get("sessions").unwrap().usize().unwrap(), 0, "zeroed tier present");
        // Detail rows carry the pinned tier.
        let rows = j.get("sessions_detail").unwrap().arr().unwrap();
        let by_id = |id: &str| {
            rows.iter()
                .find(|r| r.get("id").unwrap().str().unwrap() == id)
                .unwrap()
                .get("strategy")
                .unwrap()
                .str()
                .unwrap()
                .to_string()
        };
        assert_eq!(by_id("w"), "sliding-window");
        assert_eq!(by_id("c"), "ccm");
    }

    #[test]
    fn overload_refusals_are_attributed_to_the_sessions_tier() {
        let mut ex = toy_executor(|cfg| cfg.max_pending = 1);
        let (tx, rx) = channel();
        ex.admit(
            Request::Context {
                session: "w".into(),
                tokens: vec![1],
                strategy: Some(StrategyKind::SlidingWindow),
            },
            reply_to(&tx),
        );
        let _ = recv_json(&rx);
        // The queue is now full; the same session's next chunk refuses
        // under ITS tier, not the default.
        ex.admit(
            Request::Context { session: "w".into(), tokens: vec![2], strategy: None },
            reply_to(&tx),
        );
        assert_eq!(recv_json(&rx).get("error").unwrap().str().unwrap(), "overloaded");
        let i = StrategyKind::SlidingWindow.index();
        assert_eq!(ex.coord.metrics.by_strategy[i].refusals, 1);
        assert_eq!(ex.coord.metrics.rejected_overload, 1);
        ex.coord.run_until_idle().unwrap();
    }

    #[test]
    fn shard_budget_is_a_partition_of_the_global_budget() {
        let m = Manifest::toy();
        let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
        cfg.shards = 4;
        cfg.kv_budget_bytes = Some(1001);
        let budgets: Vec<usize> = (0..4)
            .map(|i| {
                let sim = SimCompute::from_manifest(&m);
                Executor::new(&m, Box::new(sim), &cfg, i).kv_budget.unwrap()
            })
            .collect();
        assert_eq!(budgets.iter().sum::<usize>(), 1001);
        assert!(budgets.iter().all(|b| *b == 250 || *b == 251), "{budgets:?}");
    }

    fn hib_root(case: &str) -> std::path::PathBuf {
        let root = std::env::temp_dir().join(format!("ccm-exec-hib-{}-{case}", std::process::id()));
        std::fs::remove_dir_all(&root).ok();
        root
    }

    #[test]
    fn idle_session_spills_and_rehydrates_transparently_at_same_t() {
        let root = hib_root("idle");
        let mut ex = toy_executor(|cfg| {
            cfg.hibernate_dir = Some(root.clone());
            cfg.hibernate_after = Some(Duration::ZERO);
        });
        ex.coord.add_context("u", vec![1, 2]);
        ex.coord.run_until_idle().unwrap();
        let kv = ex.coord.sessions.get("u").unwrap().kv_bytes();
        assert!(kv > 0);

        // The housekeeping pass spills the (instantly) idle session.
        ex.spill_idle();
        assert!(ex.coord.sessions.get("u").is_err(), "spilled session leaves residence");
        assert!(ex.coord.sessions.is_hibernated("u"));
        assert!(crate::server::hibernate::snap_path(&root, 0, "u").exists());
        let j = Json::parse(&ex.stats_json(&StatsQuery::default())).unwrap();
        assert_eq!(j.get("sessions").unwrap().usize().unwrap(), 0);
        assert_eq!(j.get("kv_bytes").unwrap().usize().unwrap(), 0, "hibernated KV leaves budget");
        assert_eq!(j.get("hibernated_sessions").unwrap().usize().unwrap(), 1);
        assert_eq!(j.get("hibernated_bytes").unwrap().usize().unwrap(), kv);
        assert_eq!(j.get("spills").unwrap().usize().unwrap(), 1);

        // The next touch rehydrates transparently: the ack continues
        // from the pre-spill t, not from a fresh session.
        let (tx, rx) = channel();
        let req = Request::Context { session: "u".into(), tokens: vec![3, 4], strategy: None };
        ex.admit(req, reply_to(&tx));
        let ack = recv_json(&rx);
        assert_eq!(ack.get("ok").unwrap(), &Json::Bool(true));
        assert_eq!(ack.get("t").unwrap().i64().unwrap(), 2, "resumes at pre-spill t=1, acks t=2");
        assert_eq!(ex.coord.metrics.rehydrations, 1);
        assert!(!ex.coord.sessions.is_hibernated("u"));
        assert!(!crate::server::hibernate::snap_path(&root, 0, "u").exists(), "spill consumed");
        ex.coord.run_until_idle().unwrap();
        assert_eq!(ex.coord.sessions.get("u").unwrap().t, 2);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn corrupt_snapshot_degrades_to_fresh_session_not_an_error() {
        let root = hib_root("corrupt");
        let mut ex = toy_executor(|cfg| cfg.hibernate_dir = Some(root.clone()));
        // Garbage parked where "u"'s snapshot would live — a torn disk,
        // a bad actor, bit rot; the executor must treat it exactly like
        // an eviction.
        let path = crate::server::hibernate::snap_path(&root, 0, "u");
        std::fs::write(&path, b"not a snapshot").unwrap();
        let (tx, rx) = channel();
        let req = Request::Context { session: "u".into(), tokens: vec![1, 2], strategy: None };
        ex.admit(req, reply_to(&tx));
        let ack = recv_json(&rx);
        assert_eq!(ack.get("ok").unwrap(), &Json::Bool(true), "never a client error");
        assert_eq!(ack.get("t").unwrap().i64().unwrap(), 1, "fresh session at t=1");
        assert_eq!(ex.coord.metrics.snapshot_corrupt, 1);
        assert_eq!(ex.coord.metrics.rehydrations, 0);
        assert!(!path.exists(), "corrupt file is deleted, not retried forever");
        ex.coord.run_until_idle().unwrap();
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn budget_eviction_spills_victims_before_dropping_them() {
        let root = hib_root("budget");
        let mut ex = toy_executor(|cfg| cfg.hibernate_dir = Some(root.clone()));
        ex.coord.add_context("a", vec![1, 2]);
        ex.coord.run_until_idle().unwrap();
        assert_eq!(ex.enforce_budget(0), 1);
        assert_eq!(ex.coord.metrics.sessions_evicted, 1);
        assert_eq!(ex.coord.metrics.spills, 1);
        assert!(ex.coord.sessions.is_hibernated("a"), "victim demoted to disk, not erased");
        // The "evicted" session's memory is recoverable: its next touch
        // resumes at the pre-eviction step.
        let (tx, rx) = channel();
        let req = Request::Context { session: "a".into(), tokens: vec![3], strategy: None };
        ex.admit(req, reply_to(&tx));
        assert_eq!(recv_json(&rx).get("t").unwrap().i64().unwrap(), 2);
        assert_eq!(ex.coord.metrics.rehydrations, 1);
        ex.coord.run_until_idle().unwrap();
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn formats_query_response_as_valid_json() {
        let mut logits = crate::tensor::Tensor::zeros(&[4, 6]);
        logits.set(&[1, 3], 5.0);
        let s = format_query_response(&logits, 2, 3);
        let j = Json::parse(&s).unwrap();
        let next = j.get("next").unwrap().arr().unwrap();
        assert_eq!(next.len(), 3);
        assert_eq!(next[0].arr().unwrap()[0].i64().unwrap(), 3);
        // log-probs <= 0
        assert!(next[0].arr().unwrap()[1].f64().unwrap() <= 0.0);
    }

    #[test]
    fn query_response_survives_nan_logits() {
        // Regression: the seed used partial_cmp().unwrap(), which
        // panicked the executor on any NaN logit.
        let mut logits = crate::tensor::Tensor::zeros(&[2, 5]);
        logits.set(&[1, 2], f32::NAN);
        logits.set(&[1, 4], 3.0);
        let s = format_query_response(&logits, 2, 2);
        let j = Json::parse(&s).expect("still valid JSON");
        let next = j.get("next").unwrap().arr().unwrap();
        assert_eq!(next.len(), 2);
        // total_cmp ranks NaN above every real number (descending sort),
        // but the finite top token must still be present.
        let toks: Vec<i64> = next.iter().map(|p| p.arr().unwrap()[0].i64().unwrap()).collect();
        assert!(toks.contains(&4), "finite max must rank in top-2: {toks:?}");
        // The NaN entry degrades to null; finite entries keep real
        // logprobs (lse is computed over finite logits only).
        for p in next {
            let pair = p.arr().unwrap();
            match pair[0].i64().unwrap() {
                2 => assert_eq!(pair[1], Json::Null),
                _ => assert!(pair[1].f64().unwrap() <= 0.0),
            }
        }
    }
}
