//! Session→shard routing and the merged global stats view.
//!
//! The router fans connection requests out to the per-shard executors
//! through [`ShardHandle`]s — an in-process executor's channel, or a
//! worker process's IPC proxy; the routing logic cannot tell the two
//! apart. Routing invariant: a session id ALWAYS maps to the same
//! shard (a stable FNV-1a hash of the id, mod the shard count), so a
//! session's compressed memory Mem(t) never migrates between executors
//! and per-session ordering reduces to per-shard ordering. Stats
//! requests fan out to every shard and come back as one merged object;
//! shutdown fans out so every executor drains.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, SendError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::compress::StrategyKind;
use crate::coordinator::session::EvictionKind;
use crate::server::ipc::{WorkerProxy, WorkerStatsTable};
use crate::server::reactor::ReactorStatsTable;
use crate::server::{ReactorMode, Reply, Request, ServerConfig, StatsQuery, SHARD_UNAVAILABLE};
use crate::util::json::{escape, Json};

/// Stable shard for a session id: FNV-1a (64-bit) of the id bytes, mod
/// the shard count. Deterministic across processes, platforms, and
/// restarts — the routing invariant external load balancers can rely
/// on. With one shard everything maps to shard 0.
pub fn shard_for(session: &str, shards: usize) -> usize {
    debug_assert!(shards > 0);
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in session.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    (h % shards as u64) as usize
}

/// Shard `shard`'s slice of a global byte budget: `total / shards`,
/// with the remainder spread one byte each over the first shards so
/// the slices sum exactly to `total` (never over).
pub(crate) fn partition_budget(total: usize, shard: usize, shards: usize) -> usize {
    total / shards + usize::from(shard < total % shards)
}

/// Every executor stats object starts with exactly this prefix; a
/// worker's failover reply (`shard_unavailable`) does not.
fn is_stats_part(part: &str) -> bool {
    part.starts_with("{\"ok\":true,\"kind\":\"stats\"")
}

/// The per-tier counter keys every stats part carries under
/// `strategies.<tier>`; the merge sums them blindly, so the executor,
/// this placeholder, and the merge must agree on the list.
const STRATEGY_KEYS: [&str; 7] = [
    "sessions",
    "kv_bytes",
    "compressions",
    "inferences",
    "tokens_dropped",
    "refusals",
    "overrides",
];

/// A zeroed `strategies` object (every tier, every counter).
fn zero_strategies() -> String {
    let zeroed: Vec<String> =
        STRATEGY_KEYS.iter().map(|k| format!("\"{k}\":0")).collect();
    let tiers: Vec<String> = StrategyKind::ALL
        .iter()
        .map(|k| format!("{}:{{{}}}", escape(k.name()), zeroed.join(",")))
        .collect();
    format!("{{{}}}", tiers.join(","))
}

/// Placeholder per-shard stats for a worker that is down: zeroed
/// counters (the merged sums then cover the live workers) plus a
/// `"down":true` marker. Keeps the merged view answerable during an
/// outage instead of failing the whole stats request closed.
fn down_part(shard: usize) -> String {
    format!(
        "{{\"ok\":true,\"kind\":\"stats\",\"shard\":{shard},\"down\":true,\"sessions\":0,\
         \"kv_bytes\":0,\"pending\":0,\"waiting\":0,\"requests\":0,\"compressions\":0,\
         \"inferences\":0,\"batches\":0,\"rejected_overload\":0,\"sessions_evicted\":0,\
         \"sessions_reaped\":0,\"hibernated_sessions\":0,\"hibernated_bytes\":0,\"spills\":0,\
         \"rehydrations\":0,\"snapshot_corrupt\":0,\"priority_overrides\":0,\"peak_kv_bytes\":0,\
         \"strategies\":{},\"sessions_detail\":[]}}",
        zero_strategies()
    )
}

const STATS_UNAVAILABLE: &str = "{\"ok\":false,\"error\":\"stats_unavailable\"}";
/// Concurrent merged-stats collectors (each is one short-lived thread
/// that may block up to 30 s on a slow shard). Requests over the cap
/// fail closed with `stats_unavailable` instead of spawning without
/// bound — stats bypass per-shard admission control, so this is the
/// only thing stopping one pipelining client from exhausting threads.
const STATS_FANOUT_LIMIT: usize = 32;

/// One dispatch target of the router: an in-process shard executor's
/// intake channel, or a worker process behind its IPC proxy. The two
/// expose the identical failure contract — `Err` hands the reply back
/// because the shard cannot take the request (executor gone / worker
/// down), and the router answers `shard_unavailable` in its place.
#[derive(Clone)]
pub(crate) enum ShardHandle {
    /// In-process executor (PR 2's channel, unchanged semantics).
    Local(Sender<(Request, Reply)>),
    /// Worker-process executor: pipelined IPC proxy with its own
    /// connection state machine (`ipc::WorkerProxy`).
    Remote(Arc<WorkerProxy>),
}

impl ShardHandle {
    pub(crate) fn send(&self, req: Request, reply: Reply) -> std::result::Result<(), Reply> {
        match self {
            ShardHandle::Local(tx) => tx.send((req, reply)).map_err(|SendError((_, r))| r),
            ShardHandle::Remote(proxy) => proxy.dispatch(req, reply),
        }
    }

    /// Remote shards can come back (the supervisor respawns workers),
    /// so fan-outs degrade per shard instead of failing closed.
    fn is_remote(&self) -> bool {
        matches!(self, ShardHandle::Remote(_))
    }
}

/// Fans requests from connection threads to the per-shard executors
/// and merges fan-out responses. Cheap to clone (one handle per
/// shard); every connection thread holds a clone.
#[derive(Clone)]
pub(crate) struct Router {
    shards: Vec<ShardHandle>,
    /// Global config echoed into the merged stats view.
    kv_budget_bytes: Option<usize>,
    session_ttl: Option<Duration>,
    max_pending: usize,
    eviction: EvictionKind,
    /// Live merged-stats collector threads (shared across clones),
    /// bounded by [`STATS_FANOUT_LIMIT`].
    stats_inflight: Arc<AtomicUsize>,
    /// Per-reactor transport counters (one slot per reactor thread in
    /// the epoll front-end, empty in threads mode): the reactors write
    /// them, stats responses render them as `per_reactor`.
    reactor_stats: Arc<ReactorStatsTable>,
    /// Per-worker supervision counters (worker topology only): rendered
    /// into merged stats as `per_worker` + `shard_restarts`.
    workers: Option<Arc<WorkerStatsTable>>,
}

impl Router {
    /// Router over in-process shard executors (one intake channel each).
    pub(crate) fn new(shards: Vec<Sender<(Request, Reply)>>, cfg: &ServerConfig) -> Router {
        Router::build(shards.into_iter().map(ShardHandle::Local).collect(), cfg, None)
    }

    /// Router over worker-process shards: same dispatch logic, plus the
    /// per-worker stats table rendered into the merged view (stats
    /// always take the merged path so worker rows are present even with
    /// one worker).
    pub(crate) fn with_workers(
        shards: Vec<ShardHandle>,
        cfg: &ServerConfig,
        workers: Arc<WorkerStatsTable>,
    ) -> Router {
        debug_assert_eq!(shards.len(), workers.count());
        Router::build(shards, cfg, Some(workers))
    }

    fn build(
        shards: Vec<ShardHandle>,
        cfg: &ServerConfig,
        workers: Option<Arc<WorkerStatsTable>>,
    ) -> Router {
        assert!(!shards.is_empty());
        // One counter slot per reactor thread; threads mode has none.
        let reactors = match cfg.reactor {
            ReactorMode::Epoll => cfg.reactors.max(1),
            ReactorMode::Threads => 0,
        };
        Router {
            shards,
            kv_budget_bytes: cfg.kv_budget_bytes,
            session_ttl: cfg.session_ttl,
            max_pending: cfg.max_pending,
            eviction: cfg.eviction,
            stats_inflight: Arc::new(AtomicUsize::new(0)),
            reactor_stats: Arc::new(ReactorStatsTable::new(reactors)),
            workers,
        }
    }

    /// The shared per-reactor counter table (the serve shell hands each
    /// reactor thread its slot).
    pub(crate) fn reactor_stats(&self) -> Arc<ReactorStatsTable> {
        self.reactor_stats.clone()
    }

    /// Pre-rendered `per_reactor` rows, or `None` in threads mode.
    fn per_reactor_rows(&self) -> Option<String> {
        if self.reactor_stats.is_empty() {
            None
        } else {
            Some(self.reactor_stats.render_rows())
        }
    }

    /// Route one request; the executor (or the router, for merged
    /// stats) answers on `reply`. Returns false when the target
    /// executor is gone and the connection should close. Never blocks:
    /// shard sends are unbounded channel pushes and the merged-stats
    /// collection runs on its own short-lived thread, so the reactor's
    /// event loop (which dispatches inline) is never stalled behind a
    /// slow shard.
    pub(crate) fn dispatch(&self, req: Request, reply: Reply) -> bool {
        let n = self.shards.len();
        if let Some(session) = req.session() {
            let target = shard_for(session, n);
            // An unreachable shard (in process: executor gone for good;
            // worker topology: process down, perhaps respawning) yields
            // the documented refusal instead of silently dropping the
            // connection — and never a hang.
            return match self.shards[target].send(req, reply) {
                Ok(()) => true,
                Err(reply) => reply.send(SHARD_UNAVAILABLE.into()).is_ok(),
            };
        }
        match req {
            Request::Stats(mut q) => {
                if n == 1 && self.workers.is_none() {
                    // The executor cannot see the transport layer, so
                    // the router injects the pre-rendered per-reactor
                    // rows for it to embed. (Worker topologies always
                    // take the merged path: per-reactor AND per-worker
                    // rows are rendered front-end side.)
                    q.per_reactor = self.per_reactor_rows();
                    match self.shards[0].send(Request::Stats(q), reply) {
                        Ok(()) => true,
                        Err(reply) => reply.send(STATS_UNAVAILABLE.into()).is_ok(),
                    }
                } else {
                    if self.stats_inflight.fetch_add(1, Ordering::SeqCst) >= STATS_FANOUT_LIMIT {
                        self.stats_inflight.fetch_sub(1, Ordering::SeqCst);
                        return reply.send(STATS_UNAVAILABLE.into()).is_ok();
                    }
                    // The merged view renders per_reactor itself; the
                    // per-shard objects stay transport-free.
                    q.per_reactor = None;
                    let router = self.clone();
                    std::thread::spawn(move || {
                        let ok = router.merged_stats(q, reply);
                        router.stats_inflight.fetch_sub(1, Ordering::SeqCst);
                        ok
                    });
                    true
                }
            }
            Request::Shutdown => {
                // Every executor must drain; the serve loop acks each
                // requester once ALL shards have drained and the
                // listener is closed, so extra clones of `reply` held
                // by other shards are simply never read. (A down worker
                // accepts the shutdown too — recorded as trivially
                // drained, acked at port release like the rest.)
                let mut any = false;
                for handle in &self.shards {
                    any |= handle.send(Request::Shutdown, reply.clone()).is_ok();
                }
                any
            }
            Request::Context { .. } | Request::Query { .. } => unreachable!("routed above"),
        }
    }

    /// Fan a stats request to every shard and reply with the merged
    /// view. In-process shards fail closed: a missing or unparsable
    /// shard yields `stats_unavailable` rather than a silently partial
    /// answer (a local executor cannot come back). A DOWN WORKER shard
    /// instead contributes a zeroed placeholder part (`"down":true`) —
    /// operators need stats most during a worker outage, and the
    /// `per_worker` rows carry the outage itself.
    fn merged_stats(&self, q: StatsQuery, reply: Reply) -> bool {
        // Fan out to every shard BEFORE collecting, under one shared
        // deadline: total latency is the slowest shard (bounded at
        // 30 s, inside the connection's 60 s reply timeout), not the
        // sum of per-shard waits.
        let mut pending: Vec<(usize, Option<Receiver<String>>)> =
            Vec::with_capacity(self.shards.len());
        for (shard, handle) in self.shards.iter().enumerate() {
            // Shards see the prefix/limit bounds too (each shard's
            // snapshot is sorted by id, so per-shard truncation keeps
            // a superset of the global first-N rows).
            let part = StatsQuery {
                detail: q.detail,
                prefix: q.prefix.clone(),
                after_id: q.after_id.clone(),
                limit: q.limit,
                per_reactor: None,
            };
            let (part_tx, part_rx) = channel();
            match handle.send(Request::Stats(part), Reply::channel(part_tx)) {
                Ok(()) => pending.push((shard, Some(part_rx))),
                Err(_) if handle.is_remote() => pending.push((shard, None)),
                Err(_) => return reply.send(STATS_UNAVAILABLE.into()).is_ok(),
            }
        }
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut parts = Vec::with_capacity(pending.len());
        for (shard, part_rx) in pending {
            let Some(part_rx) = part_rx else {
                parts.push(down_part(shard));
                continue;
            };
            let left = deadline.saturating_duration_since(Instant::now());
            // A worker that dies mid-collection answers its pending
            // stats with `shard_unavailable` (not a stats object) or
            // nothing at all: both degrade to the placeholder.
            match part_rx.recv_timeout(left) {
                Ok(part) if is_stats_part(&part) => parts.push(part),
                Ok(part) if !self.shards[shard].is_remote() => parts.push(part),
                Ok(_) => parts.push(down_part(shard)),
                Err(_) if self.shards[shard].is_remote() => parts.push(down_part(shard)),
                Err(_) => return reply.send(STATS_UNAVAILABLE.into()).is_ok(),
            }
        }
        let merged = match self.merge_stats(&parts, &q) {
            Ok(m) => m,
            Err(_) => STATS_UNAVAILABLE.into(),
        };
        reply.send(merged).is_ok()
    }

    /// Sum per-shard counters into the global stats object; `per_shard`
    /// embeds each shard's own stats verbatim so operators get both
    /// views from one request. `peak_kv_bytes` sums per-shard peaks (an
    /// upper bound on the true global peak, since shards peak at
    /// different times). With `detail`, the shards' `sessions_detail`
    /// arrays are concatenated (routing keeps a session on one shard,
    /// so the concatenation has no duplicates), re-sorted by id, and
    /// truncated to `limit` — the global bound, applied after the
    /// merge. In the epoll front-end a `per_reactor` array carries the
    /// transport counters.
    fn merge_stats(&self, parts: &[String], q: &StatsQuery) -> Result<String> {
        let parsed: Vec<Json> = parts.iter().map(|p| Json::parse(p)).collect::<Result<_>>()?;
        let sum = |key: &str| -> Result<usize> {
            let mut total = 0usize;
            for p in &parsed {
                total += p.get(key)?.usize()?;
            }
            Ok(total)
        };
        let detail_field = if q.detail {
            let mut rows: Vec<(String, String)> = Vec::new();
            for p in &parsed {
                for s in p.get("sessions_detail")?.arr()? {
                    rows.push((s.get("id")?.str()?.to_string(), s.to_string()));
                }
            }
            rows.sort_unstable_by(|a, b| a.0.cmp(&b.0));
            if let Some(limit) = q.limit {
                rows.truncate(limit);
            }
            let joined: Vec<String> = rows.into_iter().map(|(_, row)| row).collect();
            format!("\"sessions_detail\":[{}],", joined.join(","))
        } else {
            String::new()
        };
        // Nested per-tier sums: every part always carries all tiers
        // (executors and the down-worker placeholder agree), so a
        // missing key is a malformed part and fails closed like any
        // other counter.
        let strategies_field = {
            let mut tiers = Vec::with_capacity(StrategyKind::ALL.len());
            for k in StrategyKind::ALL.iter() {
                let mut fields = Vec::with_capacity(STRATEGY_KEYS.len());
                for key in STRATEGY_KEYS {
                    let mut total = 0usize;
                    for p in &parsed {
                        total += p.get("strategies")?.get(k.name())?.get(key)?.usize()?;
                    }
                    fields.push(format!("\"{key}\":{total}"));
                }
                tiers.push(format!("{}:{{{}}}", escape(k.name()), fields.join(",")));
            }
            format!("\"strategies\":{{{}}},", tiers.join(","))
        };
        let reactor_field = match self.per_reactor_rows() {
            Some(rows) => format!("\"per_reactor\":[{rows}],"),
            None => String::new(),
        };
        // Worker topology: supervision counters alongside the merged
        // executor counters (note: a restarted worker's own counters
        // restart with its process; the merged sums cover the LIVE
        // worker processes, while `restarts` persists front-end side).
        let worker_field = match &self.workers {
            Some(table) => format!(
                "\"shard_restarts\":{},\"per_worker\":[{}],",
                table.total_restarts(),
                table.render_rows()
            ),
            None => String::new(),
        };
        Ok(format!(
            "{{\"ok\":true,\"kind\":\"stats\",\"shards\":{},\"eviction\":{},\"sessions\":{},\
             \"kv_bytes\":{},\"kv_budget_bytes\":{},\"session_ttl_secs\":{},\"max_pending\":{},\
             \"pending\":{},\"waiting\":{},\"requests\":{},\"compressions\":{},\"inferences\":{},\
             \"batches\":{},\"rejected_overload\":{},\"sessions_evicted\":{},\
             \"sessions_reaped\":{},\"hibernated_sessions\":{},\"hibernated_bytes\":{},\
             \"spills\":{},\"rehydrations\":{},\"snapshot_corrupt\":{},\
             \"priority_overrides\":{},\"peak_kv_bytes\":{},\
             {strategies_field}{worker_field}{reactor_field}{detail_field}\"per_shard\":[{}]}}",
            self.shards.len(),
            escape(self.eviction.name()),
            sum("sessions")?,
            sum("kv_bytes")?,
            self.kv_budget_bytes.map_or_else(|| "null".to_string(), |b| b.to_string()),
            self.session_ttl.map_or_else(|| "null".to_string(), |t| t.as_secs().to_string()),
            self.max_pending,
            sum("pending")?,
            sum("waiting")?,
            sum("requests")?,
            sum("compressions")?,
            sum("inferences")?,
            sum("batches")?,
            sum("rejected_overload")?,
            sum("sessions_evicted")?,
            sum("sessions_reaped")?,
            sum("hibernated_sessions")?,
            sum("hibernated_bytes")?,
            sum("spills")?,
            sum("rehydrations")?,
            sum("snapshot_corrupt")?,
            sum("priority_overrides")?,
            sum("peak_kv_bytes")?,
            parts.join(","),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::IpcCodec;

    #[test]
    fn shard_routing_is_stable_and_total() {
        // Same id, same shard — every time, for any shard count.
        for shards in [1usize, 2, 4, 7] {
            for i in 0..64 {
                let id = format!("session-{i}");
                let a = shard_for(&id, shards);
                assert_eq!(a, shard_for(&id, shards), "routing must be deterministic");
                assert!(a < shards);
            }
        }
        assert_eq!(shard_for("anything", 1), 0);
        // A reasonable id population reaches every shard (the hash is
        // not degenerate).
        let shards = 4;
        let mut hit = vec![false; shards];
        for i in 0..64 {
            hit[shard_for(&format!("user{i}"), shards)] = true;
        }
        assert!(hit.iter().all(|h| *h), "64 ids must cover all {shards} shards: {hit:?}");
    }

    #[test]
    fn budget_partition_sums_exactly_and_never_overshoots() {
        for (total, shards) in [(1usize << 20, 4usize), (7, 3), (5, 8), (0, 2), (100, 1)] {
            let slices: Vec<usize> =
                (0..shards).map(|i| partition_budget(total, i, shards)).collect();
            let sum: usize = slices.iter().sum();
            assert_eq!(sum, total, "slices {slices:?} must sum to {total}");
            let (min, max) = (slices.iter().min().unwrap(), slices.iter().max().unwrap());
            assert!(max - min <= 1, "slices must be near-even: {slices:?}");
        }
    }

    #[test]
    fn routing_to_a_dead_shard_replies_shard_unavailable() {
        // A shard whose executor is gone (drained mid-shutdown, or its
        // factory failed at startup) must yield the documented
        // non-retryable refusal — the connection stays open — not a
        // silent drop.
        use crate::coordinator::session::SessionPolicy;
        let cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
        let (tx0, rx0) = channel();
        let (tx1, _rx1) = channel();
        let router = Router::new(vec![tx0, tx1], &cfg);
        drop(rx0); // shard 0's executor exited
        let mut id = 0usize;
        let dead = loop {
            let candidate = format!("s{id}");
            if shard_for(&candidate, 2) == 0 {
                break candidate;
            }
            id += 1;
        };
        let (reply_tx, reply_rx) = channel();
        let req = Request::Context { session: dead, tokens: vec![1], strategy: None };
        assert!(router.dispatch(req, Reply::channel(reply_tx)), "connection must stay open");
        let resp = Json::parse(&reply_rx.recv().unwrap()).unwrap();
        assert_eq!(resp.get("error").unwrap().str().unwrap(), "shard_unavailable");
        // A live shard still routes normally.
        let alive = {
            let mut i = 0usize;
            loop {
                let candidate = format!("s{i}");
                if shard_for(&candidate, 2) == 1 {
                    break candidate;
                }
                i += 1;
            }
        };
        let (reply_tx, _reply_rx) = channel();
        let q = Request::Query { session: alive, tokens: vec![2], topk: 1 };
        assert!(router.dispatch(q, Reply::channel(reply_tx)));
    }

    #[test]
    fn merged_stats_sums_counters_and_embeds_shards() {
        use crate::coordinator::session::SessionPolicy;
        let cfg = {
            let mut c = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
            c.kv_budget_bytes = Some(1 << 20);
            c.session_ttl = Some(Duration::from_secs(600));
            c.shards = 2;
            c
        };
        let (tx0, _rx0) = channel();
        let (tx1, _rx1) = channel();
        let router = Router::new(vec![tx0, tx1], &cfg);
        let shard = |i: usize, sessions: usize, kv: usize| {
            // Per-tier rows: `sessions` of them under ccm plus one
            // sliding-window override count, so the nested sum is
            // observable in the merged view.
            let strategies = format!(
                "{{\"ccm\":{{\"sessions\":{sessions},\"kv_bytes\":{kv},\"compressions\":4,\
                 \"inferences\":5,\"tokens_dropped\":0,\"refusals\":0,\"overrides\":3}},\
                 \"sliding-window\":{{\"sessions\":0,\"kv_bytes\":0,\"compressions\":0,\
                 \"inferences\":0,\"tokens_dropped\":7,\"refusals\":1,\"overrides\":0}},\
                 \"none\":{{\"sessions\":0,\"kv_bytes\":0,\"compressions\":0,\"inferences\":0,\
                 \"tokens_dropped\":0,\"refusals\":0,\"overrides\":0}}}}"
            );
            format!(
                "{{\"ok\":true,\"kind\":\"stats\",\"shard\":{i},\"sessions\":{sessions},\
                 \"kv_bytes\":{kv},\"pending\":1,\"waiting\":0,\"requests\":10,\
                 \"compressions\":4,\"inferences\":5,\"batches\":6,\"rejected_overload\":0,\
                 \"sessions_evicted\":2,\"sessions_reaped\":0,\"hibernated_sessions\":1,\
                 \"hibernated_bytes\":64,\"spills\":2,\"rehydrations\":1,\"snapshot_corrupt\":0,\
                 \"priority_overrides\":3,\"peak_kv_bytes\":{kv},\"strategies\":{strategies}}}"
            )
        };
        let merged = router
            .merge_stats(&[shard(0, 3, 100), shard(1, 5, 200)], &StatsQuery::default())
            .unwrap();
        let j = Json::parse(&merged).expect("merged stats must be valid JSON");
        assert_eq!(j.get("shards").unwrap().usize().unwrap(), 2);
        assert_eq!(j.get("sessions").unwrap().usize().unwrap(), 8);
        // Nested per-tier counters sum across shards.
        let strat = j.get("strategies").unwrap();
        assert_eq!(strat.get("ccm").unwrap().get("sessions").unwrap().usize().unwrap(), 8);
        assert_eq!(strat.get("ccm").unwrap().get("kv_bytes").unwrap().usize().unwrap(), 300);
        assert_eq!(strat.get("ccm").unwrap().get("overrides").unwrap().usize().unwrap(), 6);
        let win = strat.get("sliding-window").unwrap();
        assert_eq!(win.get("tokens_dropped").unwrap().usize().unwrap(), 14);
        assert_eq!(win.get("refusals").unwrap().usize().unwrap(), 2);
        assert_eq!(strat.get("none").unwrap().get("sessions").unwrap().usize().unwrap(), 0);
        assert_eq!(j.get("kv_bytes").unwrap().usize().unwrap(), 300);
        assert_eq!(j.get("kv_budget_bytes").unwrap().usize().unwrap(), 1 << 20);
        assert_eq!(j.get("session_ttl_secs").unwrap().usize().unwrap(), 600);
        assert_eq!(j.get("sessions_evicted").unwrap().usize().unwrap(), 4);
        // Hibernation gauges/counters sum like every other field.
        assert_eq!(j.get("hibernated_sessions").unwrap().usize().unwrap(), 2);
        assert_eq!(j.get("hibernated_bytes").unwrap().usize().unwrap(), 128);
        assert_eq!(j.get("spills").unwrap().usize().unwrap(), 4);
        assert_eq!(j.get("rehydrations").unwrap().usize().unwrap(), 2);
        assert_eq!(j.get("snapshot_corrupt").unwrap().usize().unwrap(), 0);
        assert_eq!(j.get("priority_overrides").unwrap().usize().unwrap(), 6);
        assert_eq!(j.get("eviction").unwrap().str().unwrap(), "oldest");
        assert!(j.opt("sessions_detail").is_none(), "detail must be opt-in");
        let per = j.get("per_shard").unwrap().arr().unwrap();
        assert_eq!(per.len(), 2);
        assert_eq!(per[1].get("shard").unwrap().usize().unwrap(), 1);
        assert_eq!(per[1].get("sessions").unwrap().usize().unwrap(), 5);
        // A malformed shard part fails closed instead of mis-summing.
        let q = StatsQuery::default();
        assert!(router.merge_stats(&[shard(0, 1, 1), "garbage".into()], &q).is_err());
    }

    #[test]
    fn merged_stats_fanout_is_bounded() {
        // One client pipelining stats must not spawn collector threads
        // without bound: over the cap the router fails closed, and a
        // refusal does not leak a slot.
        use crate::coordinator::session::SessionPolicy;
        let cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
        let (tx0, _rx0) = channel();
        let (tx1, _rx1) = channel();
        let router = Router::new(vec![tx0, tx1], &cfg);
        router.stats_inflight.store(STATS_FANOUT_LIMIT, Ordering::SeqCst);
        let (reply_tx, reply_rx) = channel();
        let req = Request::Stats(StatsQuery::default());
        assert!(router.dispatch(req, Reply::channel(reply_tx)));
        let resp = Json::parse(&reply_rx.recv().unwrap()).unwrap();
        assert_eq!(resp.get("error").unwrap().str().unwrap(), "stats_unavailable");
        assert_eq!(
            router.stats_inflight.load(Ordering::SeqCst),
            STATS_FANOUT_LIMIT,
            "a refused request must not leak an in-flight slot"
        );
    }

    #[test]
    fn merged_stats_concatenates_and_sorts_session_detail() {
        use crate::coordinator::session::SessionPolicy;
        let cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
        let (tx0, _rx0) = channel();
        let (tx1, _rx1) = channel();
        let router = Router::new(vec![tx0, tx1], &cfg);
        let shard = |i: usize, detail: &str| {
            format!(
                "{{\"ok\":true,\"kind\":\"stats\",\"shard\":{i},\"sessions\":1,\"kv_bytes\":8,\
                 \"pending\":0,\"waiting\":0,\"requests\":1,\"compressions\":1,\"inferences\":0,\
                 \"batches\":1,\"rejected_overload\":0,\"sessions_evicted\":0,\
                 \"sessions_reaped\":0,\"hibernated_sessions\":0,\"hibernated_bytes\":0,\
                 \"spills\":0,\"rehydrations\":0,\"snapshot_corrupt\":0,\
                 \"priority_overrides\":0,\"peak_kv_bytes\":8,\
                 \"strategies\":{},\"sessions_detail\":[{detail}]}}",
                zero_strategies()
            )
        };
        let row = |id: &str, t: usize| {
            format!("{{\"id\":\"{id}\",\"t\":{t},\"kv_bytes\":8,\"age_ms\":10,\"idle_ms\":5}}")
        };
        // Shard order does not determine output order: rows re-sort by id.
        let shard1_detail = format!("{},{}", row("beta", 1), row("mu", 2));
        let parts = [shard(0, &row("zeta", 3)), shard(1, &shard1_detail)];
        let merged = router.merge_stats(&parts, &StatsQuery::detailed()).unwrap();
        let j = Json::parse(&merged).expect("valid JSON");
        let list = j.get("sessions_detail").unwrap().arr().unwrap();
        let ids: Vec<&str> = list.iter().map(|s| s.get("id").unwrap().str().unwrap()).collect();
        assert_eq!(ids, vec!["beta", "mu", "zeta"]);
        assert_eq!(list[0].get("t").unwrap().usize().unwrap(), 1);
        assert_eq!(list[2].get("t").unwrap().usize().unwrap(), 3);
        // A limit bounds the merged view globally, after the id sort:
        // the first N rows across shards, not N per shard.
        let q = StatsQuery { detail: true, limit: Some(2), ..Default::default() };
        let merged = router.merge_stats(&parts, &q).unwrap();
        let j = Json::parse(&merged).expect("valid JSON");
        let list = j.get("sessions_detail").unwrap().arr().unwrap();
        let ids: Vec<&str> = list.iter().map(|s| s.get("id").unwrap().str().unwrap()).collect();
        assert_eq!(ids, vec!["beta", "mu"], "global first-2 by id");
        // Without the per-shard detail arrays, a detail merge fails
        // closed (stats_unavailable upstream) instead of fabricating.
        let bare = "{\"ok\":true,\"sessions\":1,\"kv_bytes\":8,\"pending\":0,\"waiting\":0,\
                    \"requests\":1,\"compressions\":1,\"inferences\":0,\"batches\":1,\
                    \"rejected_overload\":0,\"sessions_evicted\":0,\"sessions_reaped\":0,\
                    \"priority_overrides\":0,\"peak_kv_bytes\":8}";
        assert!(router.merge_stats(&[bare.to_string()], &StatsQuery::detailed()).is_err());
    }

    #[test]
    fn down_workers_degrade_merged_stats_instead_of_failing_closed() {
        // Worker topology with every worker down: stats must still
        // answer (operators need them mid-outage) with zeroed
        // placeholder shards, per_worker rows, and shard_restarts —
        // never stats_unavailable, never a hang.
        use crate::coordinator::session::SessionPolicy;
        let cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
        let table = Arc::new(WorkerStatsTable::new(2));
        table.slot(1).restarts.store(3, Ordering::SeqCst);
        let handles: Vec<ShardHandle> = (0..2)
            .map(|i| {
                ShardHandle::Remote(Arc::new(WorkerProxy::new(i, table.clone(), IpcCodec::Json)))
            })
            .collect();
        let router = Router::with_workers(handles, &cfg, table);
        let (reply_tx, reply_rx) = channel();
        assert!(router.dispatch(Request::Stats(StatsQuery::detailed()), Reply::channel(reply_tx)));
        let merged = reply_rx.recv_timeout(Duration::from_secs(10)).expect("merged stats");
        let j = Json::parse(&merged).expect("valid merged JSON");
        assert_eq!(j.get("ok").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("shards").unwrap().usize().unwrap(), 2);
        assert_eq!(j.get("sessions").unwrap().usize().unwrap(), 0);
        assert_eq!(j.get("shard_restarts").unwrap().usize().unwrap(), 3);
        assert!(j.get("sessions_detail").unwrap().arr().unwrap().is_empty());
        let workers = j.get("per_worker").unwrap().arr().unwrap();
        assert_eq!(workers.len(), 2);
        assert_eq!(workers[0].get("up").unwrap(), &Json::Bool(false));
        assert_eq!(workers[0].get("pid").unwrap(), &Json::Null);
        assert_eq!(workers[1].get("restarts").unwrap().usize().unwrap(), 3);
        for p in j.get("per_shard").unwrap().arr().unwrap() {
            assert_eq!(p.get("down").unwrap(), &Json::Bool(true));
        }
    }

    #[test]
    fn down_worker_routing_and_shutdown_semantics() {
        use crate::coordinator::session::SessionPolicy;
        let cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
        let table = Arc::new(WorkerStatsTable::new(1));
        let proxy = Arc::new(WorkerProxy::new(0, table.clone(), IpcCodec::Json));
        let router = Router::with_workers(vec![ShardHandle::Remote(proxy.clone())], &cfg, table);
        // Session-routed work against the down worker: an immediate
        // shard_unavailable reply; the connection stays open.
        let (reply_tx, reply_rx) = channel();
        let req = Request::Context { session: "s".into(), tokens: vec![1], strategy: None };
        assert!(router.dispatch(req, Reply::channel(reply_tx)), "connection must stay open");
        let resp = Json::parse(&reply_rx.recv().unwrap()).unwrap();
        assert_eq!(resp.get("error").unwrap().str().unwrap(), "shard_unavailable");
        // Shutdown against the down worker: accepted and recorded as
        // trivially drained; the ack waits for port release.
        let (reply_tx, reply_rx) = channel();
        assert!(router.dispatch(Request::Shutdown, Reply::channel(reply_tx)));
        assert!(proxy.drain_done(), "a dead worker has nothing left to drain");
        assert!(reply_rx.try_recv().is_err(), "no ack before the listener is released");
        assert_eq!(proxy.take_drained().len(), 1);
    }

    #[test]
    fn per_reactor_rows_follow_the_transport_mode() {
        use crate::coordinator::session::SessionPolicy;
        // Epoll front-end with 2 reactors: the merged stats embed one
        // per_reactor row per reactor thread.
        let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
        cfg.reactor = ReactorMode::Epoll;
        cfg.reactors = 2;
        let (tx0, _rx0) = channel();
        let (tx1, _rx1) = channel();
        let router = Router::new(vec![tx0, tx1], &cfg);
        let table = router.reactor_stats();
        assert_eq!(table.len(), 2);
        table.slot(1).accepted.fetch_add(5, Ordering::Relaxed);
        let shard = |i: usize| {
            format!(
                "{{\"ok\":true,\"kind\":\"stats\",\"shard\":{i},\"sessions\":0,\"kv_bytes\":0,\
                 \"pending\":0,\"waiting\":0,\"requests\":0,\"compressions\":0,\"inferences\":0,\
                 \"batches\":0,\"rejected_overload\":0,\"sessions_evicted\":0,\
                 \"sessions_reaped\":0,\"hibernated_sessions\":0,\"hibernated_bytes\":0,\
                 \"spills\":0,\"rehydrations\":0,\"snapshot_corrupt\":0,\
                 \"priority_overrides\":0,\"peak_kv_bytes\":0,\
                 \"strategies\":{}}}",
                zero_strategies()
            )
        };
        let merged = router.merge_stats(&[shard(0), shard(1)], &StatsQuery::default()).unwrap();
        let j = Json::parse(&merged).expect("valid JSON");
        let rows = j.get("per_reactor").unwrap().arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("reactor").unwrap().usize().unwrap(), 1);
        assert_eq!(rows[1].get("accepted").unwrap().usize().unwrap(), 5);
        // Threads mode has no reactors: the field is absent entirely.
        let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
        cfg.reactor = ReactorMode::Threads;
        let (tx0, _rx0) = channel();
        let (tx1, _rx1) = channel();
        let router = Router::new(vec![tx0, tx1], &cfg);
        assert!(router.reactor_stats().is_empty());
        let merged = router.merge_stats(&[shard(0), shard(1)], &StatsQuery::default()).unwrap();
        let j = Json::parse(&merged).expect("valid JSON");
        assert!(j.opt("per_reactor").is_none(), "threads mode must not fabricate reactors");
    }
}
