//! Thin readiness-polling wrapper for the serving reactor.
//!
//! The vendored offline tree has no `mio`/`libc`, so on Linux the
//! default backend is a zero-dependency epoll wrapper: raw `extern "C"`
//! declarations for `epoll_create1` / `epoll_ctl` / `epoll_wait` (the
//! symbols live in the C library std already links) plus an `eventfd`
//! used as a waker — executor shards signal completion delivery and the
//! serve shell signals shutdown by writing to it, which pops the
//! reactor out of `epoll_wait`. Readiness is level-triggered, matching
//! the reactor's "read/write until `WouldBlock`" discipline.
//!
//! A portable fallback keeps the same API everywhere: a bounded scan
//! loop that reports every registered source as maybe-ready each tick
//! (the reactor treats spurious readiness as a no-op `WouldBlock`) and
//! a condvar-backed waker. Slower, but dependency-free and correct. It
//! is the only backend off-Linux, and `CCM_FORCE_FALLBACK_POLL=1`
//! selects it on Linux too so CI can compile AND run the scan loop
//! instead of shipping it untested to other platforms.
//!
//! This module also owns [`bind_reuseport`], the raw `SO_REUSEPORT`
//! socket builder behind multi-reactor accept sharding: N listeners on
//! one address, kernel-balanced. Off-Linux (or on kernels without the
//! option) it fails cleanly and the serve shell falls back to a
//! single-listener round-robin handoff.

use std::net::{SocketAddr, TcpListener};
use std::time::Duration;

use anyhow::Result;

/// Identifies a registered source in [`Event`]s (the reactor uses the
/// connection id). [`WAKER_TOKEN`] is reserved for the built-in waker.
pub(crate) type Token = u64;

pub(crate) const WAKER_TOKEN: Token = u64::MAX;

/// One readiness report from [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub(crate) struct Event {
    pub token: Token,
    pub readable: bool,
    pub writable: bool,
}

/// OS-level source handle, wide enough for unix fds and winsock
/// sockets. The epoll backend narrows it to the fd it came from; the
/// fallback backend only uses it as a registration key.
pub(crate) type SysFd = i64;

#[cfg(unix)]
pub(crate) fn source_fd<T: std::os::unix::io::AsRawFd>(s: &T) -> SysFd {
    s.as_raw_fd() as SysFd
}

#[cfg(windows)]
pub(crate) fn source_fd<T: std::os::windows::io::AsRawSocket>(s: &T) -> SysFd {
    s.as_raw_socket() as SysFd
}

/// `CCM_FORCE_FALLBACK_POLL=1`: run the portable scan-loop backend on
/// Linux (the CI escape hatch exercising the off-Linux code path).
#[cfg(target_os = "linux")]
fn force_fallback() -> bool {
    std::env::var("CCM_FORCE_FALLBACK_POLL").ok().as_deref() == Some("1")
}

/// Readiness poller: epoll on Linux (unless forced into the fallback),
/// the portable scan loop everywhere else. Both backends stay compiled
/// on Linux so the fallback cannot rot unbuilt.
pub(crate) enum Poller {
    #[cfg(target_os = "linux")]
    Epoll(epoll::Poller),
    Fallback(fallback::Poller),
}

/// Wakes a [`Poller`] blocked in `wait` from any thread.
#[derive(Clone)]
pub(crate) enum Waker {
    #[cfg(target_os = "linux")]
    Epoll(epoll::Waker),
    Fallback(fallback::Waker),
}

impl Waker {
    pub(crate) fn wake(&self) {
        match self {
            #[cfg(target_os = "linux")]
            Waker::Epoll(w) => w.wake(),
            Waker::Fallback(w) => w.wake(),
        }
    }
}

impl Poller {
    pub(crate) fn new() -> Result<Poller> {
        #[cfg(target_os = "linux")]
        if !force_fallback() {
            return Ok(Poller::Epoll(epoll::Poller::new()?));
        }
        Ok(Poller::Fallback(fallback::Poller::new()?))
    }

    pub(crate) fn waker(&self) -> Waker {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => Waker::Epoll(p.waker()),
            Poller::Fallback(p) => Waker::Fallback(p.waker()),
        }
    }

    pub(crate) fn add(
        &mut self,
        fd: SysFd,
        token: Token,
        readable: bool,
        writable: bool,
    ) -> Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.add(fd, token, readable, writable),
            Poller::Fallback(p) => p.add(fd, token, readable, writable),
        }
    }

    pub(crate) fn modify(
        &mut self,
        fd: SysFd,
        token: Token,
        readable: bool,
        writable: bool,
    ) -> Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.modify(fd, token, readable, writable),
            Poller::Fallback(p) => p.modify(fd, token, readable, writable),
        }
    }

    pub(crate) fn delete(&mut self, fd: SysFd) -> Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.delete(fd),
            Poller::Fallback(p) => p.delete(fd),
        }
    }

    /// Block until readiness, a wake, or `timeout`; fills `out`.
    pub(crate) fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.wait(out, timeout),
            Poller::Fallback(p) => p.wait(out, timeout),
        }
    }
}

/// Bind a listener with `SO_REUSEPORT` set before `bind`, so several
/// listeners (one per reactor) can share one address and the kernel
/// hash-balances incoming connections across them. Linux-only raw
/// syscalls (no libc crate offline); every other platform — and any
/// kernel that refuses the option — gets a clean error and the serve
/// shell degrades to single-listener handoff.
#[cfg(target_os = "linux")]
pub(crate) fn bind_reuseport(addr: SocketAddr) -> Result<TcpListener> {
    use anyhow::Context;
    use std::os::unix::io::FromRawFd;

    const AF_INET: i32 = 2;
    const AF_INET6: i32 = 10;
    const SOCK_STREAM: i32 = 1;
    const SOCK_CLOEXEC: i32 = 0o200_0000;
    const SOL_SOCKET: i32 = 1;
    const SO_REUSEPORT: i32 = 15;

    extern "C" {
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        fn setsockopt(fd: i32, level: i32, optname: i32, optval: *const u8, optlen: u32) -> i32;
        fn bind(fd: i32, addr: *const u8, len: u32) -> i32;
        fn listen(fd: i32, backlog: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    // sockaddr_in / sockaddr_in6 laid out by hand: family in native
    // byte order, port and address in network byte order.
    let (family, buf, len): (i32, [u8; 28], u32) = match addr {
        SocketAddr::V4(a) => {
            let mut b = [0u8; 28];
            b[0..2].copy_from_slice(&(AF_INET as u16).to_ne_bytes());
            b[2..4].copy_from_slice(&a.port().to_be_bytes());
            b[4..8].copy_from_slice(&a.ip().octets());
            (AF_INET, b, 16)
        }
        SocketAddr::V6(a) => {
            let mut b = [0u8; 28];
            b[0..2].copy_from_slice(&(AF_INET6 as u16).to_ne_bytes());
            b[2..4].copy_from_slice(&a.port().to_be_bytes());
            b[4..8].copy_from_slice(&a.flowinfo().to_be_bytes());
            b[8..24].copy_from_slice(&a.ip().octets());
            b[24..28].copy_from_slice(&a.scope_id().to_ne_bytes());
            (AF_INET6, b, 28)
        }
    };
    // SAFETY: plain FFI call; no pointers involved.
    let fd = unsafe { socket(family, SOCK_STREAM | SOCK_CLOEXEC, 0) };
    if fd < 0 {
        return Err(std::io::Error::last_os_error()).context("socket");
    }
    let fail = |fd: i32, what: &'static str| -> anyhow::Error {
        let e = std::io::Error::last_os_error();
        // SAFETY: fd is a live socket still owned by this function (it
        // is only wrapped in a TcpListener on the success path), and
        // every error path closes it exactly once, here.
        unsafe { close(fd) };
        anyhow::Error::from(e).context(what)
    };
    let one: i32 = 1;
    // SAFETY: optval points at a live i32 and optlen is its exact size.
    if unsafe { setsockopt(fd, SOL_SOCKET, SO_REUSEPORT, &one as *const i32 as *const u8, 4) } < 0
    {
        return Err(fail(fd, "setsockopt(SO_REUSEPORT)"));
    }
    // SAFETY: buf is a live 28-byte sockaddr buffer and len (16 or 28)
    // is the initialized prefix for the chosen address family.
    if unsafe { bind(fd, buf.as_ptr(), len) } < 0 {
        return Err(fail(fd, "bind"));
    }
    // SAFETY: plain FFI call on a socket fd owned by this function.
    if unsafe { listen(fd, 1024) } < 0 {
        return Err(fail(fd, "listen"));
    }
    // SAFETY: fd is a valid listening socket whose ownership transfers
    // here exactly once; the TcpListener closes it on drop.
    Ok(unsafe { TcpListener::from_raw_fd(fd) })
}

#[cfg(not(target_os = "linux"))]
pub(crate) fn bind_reuseport(_addr: SocketAddr) -> Result<TcpListener> {
    anyhow::bail!("SO_REUSEPORT accept sharding is only wired up on Linux")
}

/// Largest number of buffers one gathered write submits at once
/// (Linux `IOV_MAX`); longer batches loop in chunks of this size.
pub(crate) const WRITE_GATHER_MAX: usize = 1024;

/// Write a batch of frames to a blocking stream with as few syscalls
/// as the platform allows: one gathered `writev` per
/// [`WRITE_GATHER_MAX`]-sized burst on Linux, sequential `write_all`
/// everywhere else. Lives here because the Linux path talks to the
/// raw fd directly (the `raw-fd-outside-poll` lint rule: poll.rs owns
/// every raw-descriptor syscall). Empty buffers are skipped; partial
/// writes and `EINTR` are retried until the whole batch is on the
/// wire.
#[cfg(target_os = "linux")]
pub(crate) fn write_gathered(
    stream: &std::net::TcpStream,
    bufs: &[Vec<u8>],
) -> std::io::Result<()> {
    use std::os::unix::io::AsRawFd;

    // struct iovec laid out by hand (no libc crate offline).
    #[repr(C)]
    struct IoVec {
        base: *const u8,
        len: usize,
    }

    extern "C" {
        fn writev(fd: i32, iov: *const IoVec, iovcnt: i32) -> isize;
    }

    let fd = stream.as_raw_fd();
    let mut iov: Vec<IoVec> = Vec::with_capacity(bufs.len().min(WRITE_GATHER_MAX));
    // Cursor over the flattened byte stream: next buffer index and the
    // offset inside it that has not reached the wire yet.
    let (mut idx, mut off) = (0usize, 0usize);
    while idx < bufs.len() {
        if off >= bufs[idx].len() {
            idx += 1;
            off = 0;
            continue;
        }
        iov.clear();
        let mut j = idx;
        let mut skip = off;
        while j < bufs.len() && iov.len() < WRITE_GATHER_MAX {
            let b = &bufs[j];
            if skip < b.len() {
                iov.push(IoVec { base: b[skip..].as_ptr(), len: b.len() - skip });
            }
            skip = 0;
            j += 1;
        }
        let wrote = loop {
            // SAFETY: iov holds iov.len() entries, each pointing into a
            // live buffer borrowed from `bufs` for the duration of the
            // call; the kernel only reads through them.
            let rc = unsafe { writev(fd, iov.as_ptr(), iov.len() as i32) };
            if rc > 0 {
                break rc as usize;
            }
            if rc == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "writev wrote zero bytes",
                ));
            }
            let e = std::io::Error::last_os_error();
            if e.kind() != std::io::ErrorKind::Interrupted {
                return Err(e);
            }
        };
        // Advance the cursor past the bytes the kernel took; a partial
        // write leaves (idx, off) mid-buffer and the loop resubmits
        // from there.
        let mut left = wrote;
        while left > 0 {
            let avail = bufs[idx].len() - off;
            let take = left.min(avail);
            off += take;
            left -= take;
            if off == bufs[idx].len() {
                idx += 1;
                off = 0;
            }
        }
    }
    Ok(())
}

/// Portable fallback: the same contract, one `write_all` per buffer.
#[cfg(not(target_os = "linux"))]
pub(crate) fn write_gathered(
    stream: &std::net::TcpStream,
    bufs: &[Vec<u8>],
) -> std::io::Result<()> {
    use std::io::Write;
    let mut w = stream;
    for b in bufs {
        w.write_all(b)?;
    }
    Ok(())
}

#[cfg(target_os = "linux")]
mod epoll {
    use super::{Event, SysFd, Token, WAKER_TOKEN};
    use anyhow::{Context, Result};
    use std::sync::Arc;
    use std::time::Duration;

    // epoll_event is packed on x86-64 (a kernel ABI quirk); everywhere
    // else it has natural C layout.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x1;
    const EPOLLOUT: u32 = 0x4;
    const EPOLLERR: u32 = 0x8;
    const EPOLLHUP: u32 = 0x10;
    const EPOLL_CLOEXEC: i32 = 0o200_0000;
    const EFD_NONBLOCK: i32 = 0o4000;
    const EFD_CLOEXEC: i32 = 0o200_0000;

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
        fn eventfd(initval: u32, flags: i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        fn close(fd: i32) -> i32;
    }

    /// Owned fd, closed on drop.
    struct Fd(i32);

    impl Drop for Fd {
        fn drop(&mut self) {
            // SAFETY: self.0 is the fd this wrapper owns, and drop runs
            // at most once, so this is the single close.
            unsafe { close(self.0) };
        }
    }

    /// Wakes a [`Poller`] blocked in `wait` from any thread (eventfd
    /// write; wakes coalesce in the eventfd counter).
    #[derive(Clone)]
    pub(crate) struct Waker {
        fd: Arc<Fd>,
    }

    impl Waker {
        pub(crate) fn wake(&self) {
            let one: u64 = 1;
            // SAFETY: writes 8 bytes from a live u64. EAGAIN (counter
            // saturated) means a wake is already pending — exactly what
            // we want; ignore the result.
            unsafe { write(self.fd.0, &one as *const u64 as *const u8, 8) };
        }
    }

    pub(crate) struct Poller {
        epfd: Fd,
        wake_fd: Arc<Fd>,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub(crate) fn new() -> Result<Poller> {
            // SAFETY: plain FFI call; no pointers involved.
            let ep = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if ep < 0 {
                return Err(std::io::Error::last_os_error()).context("epoll_create1");
            }
            let epfd = Fd(ep);
            // SAFETY: plain FFI call; no pointers involved.
            let efd = unsafe { eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC) };
            if efd < 0 {
                return Err(std::io::Error::last_os_error()).context("eventfd");
            }
            let wake_fd = Arc::new(Fd(efd));
            let poller =
                Poller { epfd, wake_fd, buf: vec![EpollEvent { events: 0, data: 0 }; 1024] };
            poller.ctl(EPOLL_CTL_ADD, efd, EPOLLIN, WAKER_TOKEN).context("register waker")?;
            Ok(poller)
        }

        pub(crate) fn waker(&self) -> Waker {
            Waker { fd: self.wake_fd.clone() }
        }

        fn ctl(&self, op: i32, fd: i32, events: u32, token: Token) -> Result<()> {
            let mut ev = EpollEvent { events, data: token };
            // SAFETY: ev is a live, correctly laid out epoll_event; the
            // kernel is done with the pointer when the call returns.
            let rc = unsafe { epoll_ctl(self.epfd.0, op, fd, &mut ev) };
            if rc < 0 {
                return Err(std::io::Error::last_os_error()).context("epoll_ctl");
            }
            Ok(())
        }

        fn interest_bits(readable: bool, writable: bool) -> u32 {
            let mut bits = 0;
            if readable {
                bits |= EPOLLIN;
            }
            if writable {
                bits |= EPOLLOUT;
            }
            bits
        }

        pub(crate) fn add(
            &mut self,
            fd: SysFd,
            token: Token,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd as i32, Self::interest_bits(readable, writable), token)
        }

        pub(crate) fn modify(
            &mut self,
            fd: SysFd,
            token: Token,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd as i32, Self::interest_bits(readable, writable), token)
        }

        pub(crate) fn delete(&mut self, fd: SysFd) -> Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd as i32, 0, 0)
        }

        /// Block until readiness, a wake, or `timeout`; fills `out`.
        /// Error/hangup conditions are reported as readable (and, when
        /// write interest was registered, writable) so the caller's
        /// next non-blocking I/O observes the failure directly.
        pub(crate) fn wait(
            &mut self,
            out: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> Result<()> {
            out.clear();
            let ms: i32 = match timeout {
                None => -1,
                Some(d) => {
                    let mut ms = d.as_millis();
                    if Duration::from_millis(ms as u64) < d {
                        ms += 1; // round up: never spin below the asked wait
                    }
                    ms.min(i32::MAX as u128) as i32
                }
            };
            loop {
                // SAFETY: buf is a live array of buf.len() epoll_event
                // slots and the kernel writes at most that many.
                let n = unsafe {
                    epoll_wait(self.epfd.0, self.buf.as_mut_ptr(), self.buf.len() as i32, ms)
                };
                if n < 0 {
                    let e = std::io::Error::last_os_error();
                    if e.kind() == std::io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(e).context("epoll_wait");
                }
                for i in 0..n as usize {
                    let ev = self.buf[i];
                    let (bits, token) = (ev.events, ev.data);
                    if token == WAKER_TOKEN {
                        let mut b = [0u8; 8];
                        // SAFETY: b is a live 8-byte buffer, exactly
                        // the size an eventfd read writes.
                        unsafe { read(self.wake_fd.0, b.as_mut_ptr(), 8) };
                        out.push(Event { token, readable: true, writable: false });
                    } else {
                        out.push(Event {
                            token,
                            readable: bits & (EPOLLIN | EPOLLERR | EPOLLHUP) != 0,
                            writable: bits & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                        });
                    }
                }
                return Ok(());
            }
        }
    }
}

mod fallback {
    use super::{Event, SysFd, Token, WAKER_TOKEN};
    use anyhow::Result;
    use std::sync::{Arc, Condvar, Mutex};
    use std::time::Duration;

    #[derive(Default)]
    struct Signal {
        flag: Mutex<bool>,
        cv: Condvar,
    }

    #[derive(Clone)]
    pub(crate) struct Waker {
        signal: Arc<Signal>,
    }

    impl Waker {
        pub(crate) fn wake(&self) {
            *self.signal.flag.lock().unwrap() = true;
            self.signal.cv.notify_all();
        }
    }

    /// Portable fallback: no readiness syscall, so every registered
    /// source is reported as maybe-ready (per its interest) each tick,
    /// at a bounded cadence. The reactor's non-blocking reads/writes
    /// turn a spurious report into `WouldBlock`, so this is merely a
    /// scan loop, not a correctness change.
    pub(crate) struct Poller {
        registered: Vec<(SysFd, Token, bool, bool)>,
        signal: Arc<Signal>,
    }

    impl Poller {
        pub(crate) fn new() -> Result<Poller> {
            Ok(Poller { registered: Vec::new(), signal: Arc::new(Signal::default()) })
        }

        pub(crate) fn waker(&self) -> Waker {
            Waker { signal: self.signal.clone() }
        }

        pub(crate) fn add(
            &mut self,
            fd: SysFd,
            token: Token,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            self.registered.retain(|(f, _, _, _)| *f != fd);
            self.registered.push((fd, token, readable, writable));
            Ok(())
        }

        pub(crate) fn modify(
            &mut self,
            fd: SysFd,
            token: Token,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            self.add(fd, token, readable, writable)
        }

        pub(crate) fn delete(&mut self, fd: SysFd) -> Result<()> {
            self.registered.retain(|(f, _, _, _)| *f != fd);
            Ok(())
        }

        pub(crate) fn wait(
            &mut self,
            out: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> Result<()> {
            out.clear();
            let tick = Duration::from_millis(2);
            let wait_for = timeout.map_or(tick, |t| t.min(tick));
            let woken = {
                let mut flag = self.signal.flag.lock().unwrap();
                if !*flag {
                    // lint: allow(unwrap) — condvar poisoning means a
                    // waker panicked mid-notify; propagate the crash.
                    let (guard, _) = self.signal.cv.wait_timeout(flag, wait_for).unwrap();
                    flag = guard;
                }
                std::mem::take(&mut *flag)
            };
            if woken {
                out.push(Event { token: WAKER_TOKEN, readable: true, writable: false });
            }
            for &(_, token, readable, writable) in &self.registered {
                if readable || writable {
                    out.push(Event { token, readable, writable });
                }
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn waker_pops_wait_and_timeout_expires() {
        let mut poller = Poller::new().unwrap();
        let waker = poller.waker();
        let mut events = Vec::new();

        // A pre-issued wake is observed by the next wait.
        waker.wake();
        poller.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert!(events.iter().any(|e| e.token == WAKER_TOKEN), "{events:?}");

        // Without a wake, a short timeout expires with no events.
        let t0 = Instant::now();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.iter().all(|e| e.token != WAKER_TOKEN), "{events:?}");
        assert!(t0.elapsed() < Duration::from_secs(2), "timeout must bound the wait");
    }

    #[test]
    fn wake_from_another_thread_unblocks_wait() {
        let mut poller = Poller::new().unwrap();
        let waker = poller.waker();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            waker.wake();
        });
        let mut events = Vec::new();
        let t0 = Instant::now();
        // Generous backstop timeout: the wake must fire long before it.
        poller.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
        handle.join().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    // Pinned to the epoll backend: the fallback scan loop reports
    // registered sources as maybe-ready unconditionally, so "no event
    // before a connection arrives" is an epoll-only guarantee.
    #[cfg(target_os = "linux")]
    #[test]
    fn listener_readability_is_reported() {
        use std::net::{TcpListener, TcpStream};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut poller = epoll::Poller::new().unwrap();
        poller.add(source_fd(&listener), 7, true, false).unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(events.is_empty(), "no connection yet: {events:?}");
        let _client = TcpStream::connect(addr).unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(
            events.iter().any(|e| e.token == 7 && e.readable),
            "pending accept must be readable: {events:?}"
        );
        poller.delete(source_fd(&listener)).unwrap();
    }

    // The portable scan loop, exercised explicitly on every platform
    // (the host-suite CI matrix additionally drives the whole serve
    // suite through it via CCM_FORCE_FALLBACK_POLL=1).
    #[test]
    fn fallback_poller_scans_registered_sources_and_wakes() {
        let mut poller = fallback::Poller::new().unwrap();
        let waker = poller.waker();
        let mut events = Vec::new();

        // Registered sources are reported as maybe-ready per interest.
        poller.add(11, 1, true, false).unwrap();
        poller.add(12, 2, false, true).unwrap();
        poller.add(13, 3, false, false).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.iter().any(|e| e.token == 1 && e.readable && !e.writable), "{events:?}");
        assert!(events.iter().any(|e| e.token == 2 && e.writable && !e.readable), "{events:?}");
        assert!(events.iter().all(|e| e.token != 3), "no-interest source must stay silent");

        // modify re-registers under the same key; delete removes it.
        poller.modify(11, 1, false, false).unwrap();
        poller.delete(12).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.iter().all(|e| e.token != 1 && e.token != 2), "{events:?}");

        // A wake from another thread pops the wait promptly.
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            waker.wake();
        });
        let t0 = Instant::now();
        loop {
            poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
            if events.iter().any(|e| e.token == WAKER_TOKEN) {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(5), "wake never observed");
        }
        handle.join().unwrap();
    }

    // Exceeds WRITE_GATHER_MAX so the chunked-batch path runs, and
    // mixes empty buffers in so the skip logic is exercised; the byte
    // stream must arrive exactly once and in order on every platform.
    #[test]
    fn write_gathered_delivers_every_byte_in_order() {
        use std::io::Read;
        use std::net::{TcpListener, TcpStream};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = TcpStream::connect(addr).unwrap();
        let (mut reader, _) = listener.accept().unwrap();

        let mut bufs: Vec<Vec<u8>> = Vec::new();
        let mut expect: Vec<u8> = Vec::new();
        for i in 0..(WRITE_GATHER_MAX + 300) {
            if i % 7 == 3 {
                bufs.push(Vec::new()); // empty frames must be skipped
                continue;
            }
            let frame: Vec<u8> = (0..(i % 23 + 1)).map(|j| ((i * 31 + j) % 251) as u8).collect();
            expect.extend_from_slice(&frame);
            bufs.push(frame);
        }
        let total = expect.len();
        let sender = std::thread::spawn(move || write_gathered(&writer, &bufs));
        let mut got = vec![0u8; total];
        reader.read_exact(&mut got).unwrap();
        sender.join().unwrap().unwrap();
        assert_eq!(got, expect);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn reuseport_listeners_share_one_port_and_both_accept() {
        use std::net::TcpStream;
        let first = bind_reuseport("127.0.0.1:0".parse().unwrap()).unwrap();
        let addr = first.local_addr().unwrap();
        // Second listener on the SAME resolved port: only possible with
        // SO_REUSEPORT set on both.
        let second = bind_reuseport(addr).unwrap();
        assert_eq!(second.local_addr().unwrap().port(), addr.port());
        first.set_nonblocking(true).unwrap();
        second.set_nonblocking(true).unwrap();

        // 64 connections from distinct source ports: the kernel hash
        // must route some to each listener (P(one starves) ~ 2^-64).
        let clients: Vec<TcpStream> = (0..64).map(|_| TcpStream::connect(addr).unwrap()).collect();
        let (mut got_first, mut got_second) = (0usize, 0usize);
        let deadline = Instant::now() + Duration::from_secs(10);
        while got_first + got_second < clients.len() {
            let mut progressed = false;
            while first.accept().is_ok() {
                got_first += 1;
                progressed = true;
            }
            while second.accept().is_ok() {
                got_second += 1;
                progressed = true;
            }
            if !progressed {
                assert!(Instant::now() < deadline, "accepts stalled: {got_first}+{got_second}");
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        assert!(got_first > 0 && got_second > 0, "kernel must balance: {got_first}/{got_second}");
        drop(clients);
    }
}
