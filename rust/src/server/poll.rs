//! Thin readiness-polling wrapper for the serving reactor.
//!
//! The vendored offline tree has no `mio`/`libc`, so on Linux this is a
//! zero-dependency epoll wrapper: raw `extern "C"` declarations for
//! `epoll_create1` / `epoll_ctl` / `epoll_wait` (the symbols live in
//! the C library std already links) plus an `eventfd` used as a waker —
//! executor shards signal completion delivery and the serve shell
//! signals shutdown by writing to it, which pops the reactor out of
//! `epoll_wait`. Readiness is level-triggered, matching the reactor's
//! "read/write until `WouldBlock`" discipline.
//!
//! On every other OS a portable fallback keeps the same API: a bounded
//! scan loop that reports every registered source as maybe-ready each
//! tick (the reactor treats spurious readiness as a no-op `WouldBlock`)
//! and a condvar-backed waker. Slower, but dependency-free and correct.

/// Identifies a registered source in [`Event`]s (the reactor uses the
/// connection id). [`WAKER_TOKEN`] is reserved for the built-in waker.
pub(crate) type Token = u64;

pub(crate) const WAKER_TOKEN: Token = u64::MAX;

/// One readiness report from [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub(crate) struct Event {
    pub token: Token,
    pub readable: bool,
    pub writable: bool,
}

/// OS-level source handle, wide enough for unix fds and winsock
/// sockets. The epoll backend narrows it to the fd it came from; the
/// fallback backend only uses it as a registration key.
pub(crate) type SysFd = i64;

#[cfg(unix)]
pub(crate) fn source_fd<T: std::os::unix::io::AsRawFd>(s: &T) -> SysFd {
    s.as_raw_fd() as SysFd
}

#[cfg(windows)]
pub(crate) fn source_fd<T: std::os::windows::io::AsRawSocket>(s: &T) -> SysFd {
    s.as_raw_socket() as SysFd
}

pub(crate) use imp::{Poller, Waker};

#[cfg(target_os = "linux")]
mod imp {
    use super::{Event, SysFd, Token, WAKER_TOKEN};
    use anyhow::{Context, Result};
    use std::sync::Arc;
    use std::time::Duration;

    // epoll_event is packed on x86-64 (a kernel ABI quirk); everywhere
    // else it has natural C layout.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x1;
    const EPOLLOUT: u32 = 0x4;
    const EPOLLERR: u32 = 0x8;
    const EPOLLHUP: u32 = 0x10;
    const EPOLL_CLOEXEC: i32 = 0o200_0000;
    const EFD_NONBLOCK: i32 = 0o4000;
    const EFD_CLOEXEC: i32 = 0o200_0000;

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
        fn eventfd(initval: u32, flags: i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        fn close(fd: i32) -> i32;
    }

    /// Owned fd, closed on drop.
    struct Fd(i32);

    impl Drop for Fd {
        fn drop(&mut self) {
            unsafe { close(self.0) };
        }
    }

    /// Wakes a [`Poller`] blocked in `wait` from any thread (eventfd
    /// write; wakes coalesce in the eventfd counter).
    #[derive(Clone)]
    pub(crate) struct Waker {
        fd: Arc<Fd>,
    }

    impl Waker {
        pub(crate) fn wake(&self) {
            let one: u64 = 1;
            // EAGAIN (counter saturated) means a wake is already
            // pending — exactly what we want; ignore the result.
            unsafe { write(self.fd.0, &one as *const u64 as *const u8, 8) };
        }
    }

    pub(crate) struct Poller {
        epfd: Fd,
        wake_fd: Arc<Fd>,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub(crate) fn new() -> Result<Poller> {
            let ep = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if ep < 0 {
                return Err(std::io::Error::last_os_error()).context("epoll_create1");
            }
            let epfd = Fd(ep);
            let efd = unsafe { eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC) };
            if efd < 0 {
                return Err(std::io::Error::last_os_error()).context("eventfd");
            }
            let wake_fd = Arc::new(Fd(efd));
            let poller =
                Poller { epfd, wake_fd, buf: vec![EpollEvent { events: 0, data: 0 }; 1024] };
            poller.ctl(EPOLL_CTL_ADD, efd, EPOLLIN, WAKER_TOKEN).context("register waker")?;
            Ok(poller)
        }

        pub(crate) fn waker(&self) -> Waker {
            Waker { fd: self.wake_fd.clone() }
        }

        fn ctl(&self, op: i32, fd: i32, events: u32, token: Token) -> Result<()> {
            let mut ev = EpollEvent { events, data: token };
            let rc = unsafe { epoll_ctl(self.epfd.0, op, fd, &mut ev) };
            if rc < 0 {
                return Err(std::io::Error::last_os_error()).context("epoll_ctl");
            }
            Ok(())
        }

        fn interest_bits(readable: bool, writable: bool) -> u32 {
            let mut bits = 0;
            if readable {
                bits |= EPOLLIN;
            }
            if writable {
                bits |= EPOLLOUT;
            }
            bits
        }

        pub(crate) fn add(
            &mut self,
            fd: SysFd,
            token: Token,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd as i32, Self::interest_bits(readable, writable), token)
        }

        pub(crate) fn modify(
            &mut self,
            fd: SysFd,
            token: Token,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd as i32, Self::interest_bits(readable, writable), token)
        }

        pub(crate) fn delete(&mut self, fd: SysFd) -> Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd as i32, 0, 0)
        }

        /// Block until readiness, a wake, or `timeout`; fills `out`.
        /// Error/hangup conditions are reported as readable (and, when
        /// write interest was registered, writable) so the caller's
        /// next non-blocking I/O observes the failure directly.
        pub(crate) fn wait(
            &mut self,
            out: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> Result<()> {
            out.clear();
            let ms: i32 = match timeout {
                None => -1,
                Some(d) => {
                    let mut ms = d.as_millis();
                    if Duration::from_millis(ms as u64) < d {
                        ms += 1; // round up: never spin below the asked wait
                    }
                    ms.min(i32::MAX as u128) as i32
                }
            };
            loop {
                let n = unsafe {
                    epoll_wait(self.epfd.0, self.buf.as_mut_ptr(), self.buf.len() as i32, ms)
                };
                if n < 0 {
                    let e = std::io::Error::last_os_error();
                    if e.kind() == std::io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(e).context("epoll_wait");
                }
                for i in 0..n as usize {
                    let ev = self.buf[i];
                    let (bits, token) = (ev.events, ev.data);
                    if token == WAKER_TOKEN {
                        let mut b = [0u8; 8];
                        unsafe { read(self.wake_fd.0, b.as_mut_ptr(), 8) };
                        out.push(Event { token, readable: true, writable: false });
                    } else {
                        out.push(Event {
                            token,
                            readable: bits & (EPOLLIN | EPOLLERR | EPOLLHUP) != 0,
                            writable: bits & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                        });
                    }
                }
                return Ok(());
            }
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod imp {
    use super::{Event, SysFd, Token, WAKER_TOKEN};
    use anyhow::Result;
    use std::sync::{Arc, Condvar, Mutex};
    use std::time::Duration;

    #[derive(Default)]
    struct Signal {
        flag: Mutex<bool>,
        cv: Condvar,
    }

    #[derive(Clone)]
    pub(crate) struct Waker {
        signal: Arc<Signal>,
    }

    impl Waker {
        pub(crate) fn wake(&self) {
            *self.signal.flag.lock().unwrap() = true;
            self.signal.cv.notify_all();
        }
    }

    /// Portable fallback: no readiness syscall, so every registered
    /// source is reported as maybe-ready (per its interest) each tick,
    /// at a bounded cadence. The reactor's non-blocking reads/writes
    /// turn a spurious report into `WouldBlock`, so this is merely a
    /// scan loop, not a correctness change.
    pub(crate) struct Poller {
        registered: Vec<(SysFd, Token, bool, bool)>,
        signal: Arc<Signal>,
    }

    impl Poller {
        pub(crate) fn new() -> Result<Poller> {
            Ok(Poller { registered: Vec::new(), signal: Arc::new(Signal::default()) })
        }

        pub(crate) fn waker(&self) -> Waker {
            Waker { signal: self.signal.clone() }
        }

        pub(crate) fn add(
            &mut self,
            fd: SysFd,
            token: Token,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            self.registered.retain(|(f, _, _, _)| *f != fd);
            self.registered.push((fd, token, readable, writable));
            Ok(())
        }

        pub(crate) fn modify(
            &mut self,
            fd: SysFd,
            token: Token,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            self.add(fd, token, readable, writable)
        }

        pub(crate) fn delete(&mut self, fd: SysFd) -> Result<()> {
            self.registered.retain(|(f, _, _, _)| *f != fd);
            Ok(())
        }

        pub(crate) fn wait(
            &mut self,
            out: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> Result<()> {
            out.clear();
            let tick = Duration::from_millis(2);
            let wait_for = timeout.map_or(tick, |t| t.min(tick));
            let woken = {
                let mut flag = self.signal.flag.lock().unwrap();
                if !*flag {
                    let (guard, _) = self.signal.cv.wait_timeout(flag, wait_for).unwrap();
                    flag = guard;
                }
                std::mem::take(&mut *flag)
            };
            if woken {
                out.push(Event { token: WAKER_TOKEN, readable: true, writable: false });
            }
            for &(_, token, readable, writable) in &self.registered {
                if readable || writable {
                    out.push(Event { token, readable, writable });
                }
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn waker_pops_wait_and_timeout_expires() {
        let mut poller = Poller::new().unwrap();
        let waker = poller.waker();
        let mut events = Vec::new();

        // A pre-issued wake is observed by the next wait.
        waker.wake();
        poller.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert!(events.iter().any(|e| e.token == WAKER_TOKEN), "{events:?}");

        // Without a wake, a short timeout expires with no events.
        let t0 = Instant::now();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.iter().all(|e| e.token != WAKER_TOKEN), "{events:?}");
        assert!(t0.elapsed() < Duration::from_secs(2), "timeout must bound the wait");
    }

    #[test]
    fn wake_from_another_thread_unblocks_wait() {
        let mut poller = Poller::new().unwrap();
        let waker = poller.waker();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            waker.wake();
        });
        let mut events = Vec::new();
        let t0 = Instant::now();
        // Generous backstop timeout: the wake must fire long before it.
        poller.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
        handle.join().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn listener_readability_is_reported() {
        use std::net::{TcpListener, TcpStream};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut poller = Poller::new().unwrap();
        poller.add(source_fd(&listener), 7, true, false).unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(events.is_empty(), "no connection yet: {events:?}");
        let _client = TcpStream::connect(addr).unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(
            events.iter().any(|e| e.token == 7 && e.readable),
            "pending accept must be readable: {events:?}"
        );
        poller.delete(source_fd(&listener)).unwrap();
    }
}
