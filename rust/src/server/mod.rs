//! JSON-lines TCP serving front-end.
//!
//! Connection threads parse newline-delimited JSON requests and hand
//! them to the router (see [`router`]), which fans them out to N shard
//! executors. Each shard (see `executor.rs`) owns its own [`Compute`]
//! backend, dynamic batcher, and session manager — the standard
//! one-executor-per-device topology (XLA executables are not Sync) —
//! and runs the continuously-pumped pipeline from PR 1: each turn it
//! (1) drains whatever requests are queued, (2) executes at most one
//! batch through its coordinator, and (3) delivers any finished query
//! results — so a fast query is never stuck behind another session's
//! full queue drain, and intake keeps flowing while batches execute.
//!
//! ## Sharding (`--shards N`)
//!
//! Sessions are routed with a stable hash of the session id
//! ([`shard_for`]): one session id ALWAYS maps to the same shard, so a
//! session's compressed memory Mem(t) never migrates and per-session
//! ordering is preserved across any number of connections. Per-shard
//! KV budgets partition the global `--kv-budget-mb` (slices sum
//! exactly to the global budget), admission control (`--max-pending`)
//! bounds each shard's queue independently — one flooded shard refuses
//! work while the others keep serving — and each shard evicts by the
//! selected `--eviction` policy (`oldest` | `lru` | `largest-bytes`).
//! With `--shards 1` (the default) the engine behaves exactly like the
//! PR 1 single-executor pipeline.
//!
//! ## Protocol (one JSON object per line)
//!
//! Requests:
//!   {"op":"context","session":"u1","tokens":[5,6,7]}
//!   {"op":"query","session":"u1","tokens":[9,2],"topk":5}
//!   {"op":"stats"}            {"op":"shutdown"}
//!
//! Responses:
//!   {"ok":true,"kind":"context","t":3,"kv_bytes":12288}
//!       `t` is the time step the chunk will land on: two chunks queued
//!       back-to-back for one session ack t+1 and t+2. `kv_bytes` is the
//!       session's compressed-KV size at ack time (pre-compression).
//!   {"ok":true,"kind":"query","next":[[tok,logprob],...]}
//!   {"ok":true,"kind":"stats",...}
//!       Live usage (sessions, kv_bytes, pending queued work, waiting
//!       queries in flight, requests/compressions/inferences/batches,
//!       rejected_overload, sessions_evicted, sessions_reaped,
//!       priority_overrides, peak_kv_bytes) PLUS the configured limits
//!       (kv_budget_bytes, session_ttl_secs, max_pending, eviction) so
//!       operators can compute headroom from the response alone. With
//!       one shard the object carries its `shard` id and the
//!       human-readable `report`; with N shards the response is the
//!       merged global view (counters summed, `shards`:N) and
//!       `per_shard` embeds each shard's own stats object.
//!   {"ok":true,"kind":"shutdown"}
//!       Sent after in-flight work has drained on EVERY shard; the
//!       listener is closed and the acceptor thread joined before
//!       `serve` returns.
//!
//! Error responses (admission control and lifecycle):
//!   {"ok":false,"error":"overloaded","pending":N}
//!       The target shard's bounded pending queue (`max_pending`) is
//!       full. Back off and retry; the connection stays open. Other
//!       shards are unaffected.
//!   {"ok":false,"error":"shutting_down","pending":N}
//!       A shutdown is draining; no new work is admitted.
//!   {"ok":false,"error":"too_long","what":"chunk"|"input","got":N,"limit":N}
//!       Token list exceeds the artifact shape (chunk_max / input_max);
//!       validated at admission so it never fails a batch.
//!   {"ok":false,"error":"timeout"}
//!       The executor did not answer within the per-request deadline.
//!   {"ok":false,"error":"stats_unavailable"}
//!       A shard could not answer a fanned-out stats request (e.g. it
//!       is mid-shutdown); merged stats fail closed over partial data.
//!   {"ok":false,"error":"shard_unavailable"}
//!       The session's shard executor is gone for good in this process
//!       (it drained during a shutdown, or its backend failed to
//!       initialize). Not retryable here; the connection stays open
//!       for sessions on other shards.
//!   {"ok":false,"error":"..."} for malformed requests.
//!
//! ## Memory governance
//!
//! With `kv_budget_bytes` set, each shard enforces its slice of the
//! global compressed-KV budget after every executed batch: idle
//! sessions are evicted in [`EvictionPolicy`] order until under
//! budget. Sessions with queued work are never evicted. With
//! `session_ttl` set, sessions idle longer than the TTL are reaped
//! periodically. Both are counted in `stats` (`sessions_evicted`,
//! `sessions_reaped`). A later request for an evicted session
//! transparently starts a fresh session (its compressed memory is
//! gone — that is the cost of the budget).
//!
//! [`EvictionPolicy`]: crate::coordinator::session::EvictionPolicy

mod executor;
pub mod router;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::compress::{Compute, Engine};
use crate::coordinator::session::{EvictionKind, SessionPolicy};
use crate::model::manifest::Manifest;
use crate::model::Checkpoint;
use crate::runtime::Runtime;
use crate::util::json::{escape, Json};

use executor::Executor;
use router::Router;

pub use router::shard_for;

#[derive(Debug)]
pub enum Request {
    Context { session: String, tokens: Vec<i32> },
    Query { session: String, tokens: Vec<i32>, topk: usize },
    Stats,
    Shutdown,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line)?;
        let op = j.get("op")?.str()?.to_string();
        let tokens = || -> Result<Vec<i32>> {
            j.get("tokens")?.arr()?.iter().map(|t| Ok(t.i64()? as i32)).collect()
        };
        let session = || -> Result<String> { Ok(j.get("session")?.str()?.to_string()) };
        Ok(match op.as_str() {
            "context" => Request::Context { session: session()?, tokens: tokens()? },
            "query" => Request::Query {
                session: session()?,
                tokens: tokens()?,
                topk: j.opt("topk").and_then(|v| v.usize().ok()).unwrap_or(5),
            },
            "stats" => Request::Stats,
            "shutdown" => Request::Shutdown,
            _ => bail!("unknown op {op:?}"),
        })
    }

    /// Session id for session-routed ops (the routing key of
    /// [`shard_for`]); `None` for fan-out ops (stats, shutdown).
    pub fn session(&self) -> Option<&str> {
        match self {
            Request::Context { session, .. } | Request::Query { session, .. } => Some(session),
            Request::Stats | Request::Shutdown => None,
        }
    }
}

/// Serving configuration. `new` fills production-shaped defaults; set
/// the public fields to tune.
pub struct ServerConfig {
    pub addr: String,
    pub policy: SessionPolicy,
    /// Artifact batch width each shard's coordinator packs towards.
    pub max_batch: usize,
    /// Dynamic-batching age trigger (how long a lone item waits).
    pub max_wait: Duration,
    /// Admission control, per shard: queued work items beyond this are
    /// refused with an `overloaded` reply instead of buffered without
    /// bound.
    pub max_pending: usize,
    /// Global compressed-KV budget across all sessions (bytes);
    /// partitioned into per-shard slices that sum exactly to it.
    pub kv_budget_bytes: Option<usize>,
    /// Idle-session TTL; idle sessions beyond it are reaped.
    pub session_ttl: Option<Duration>,
    /// Executor shard count. Informational for [`serve_with_backend`]
    /// (which drives exactly one executor); [`serve_sharded`] overrides
    /// it with the number of backends supplied.
    pub shards: usize,
    /// Session-eviction policy under KV-budget pressure.
    pub eviction: EvictionKind,
}

impl ServerConfig {
    pub fn new(addr: impl Into<String>, policy: SessionPolicy) -> ServerConfig {
        ServerConfig {
            addr: addr.into(),
            policy,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            max_pending: 256,
            kv_budget_bytes: None,
            session_ttl: None,
            shards: 1,
            eviction: EvictionKind::OldestCreated,
        }
    }
}

pub(crate) type Reply = Sender<String>;

/// Builds one shard's [`Compute`] backend INSIDE that shard's executor
/// thread, so a backend may own thread-bound state (e.g. a PJRT
/// runtime, which must never cross threads).
pub type BackendFactory<'a> = Box<dyn FnOnce() -> Result<Box<dyn Compute + 'a>> + Send + 'a>;

/// Run the server until a shutdown request arrives, over the XLA engine
/// borrowed from `rt`. Single-executor only: a PJRT runtime is
/// thread-bound, so multi-shard serving needs one owned runtime per
/// shard — build [`crate::compress::OwnedEngine`] factories and call
/// [`serve_sharded`] instead (see `cli_serve` for the wiring).
/// `ready` receives the bound local address (tests bind port 0).
pub fn serve(
    rt: &Runtime,
    ck: &Checkpoint,
    cfg: ServerConfig,
    ready: Option<Sender<String>>,
) -> Result<()> {
    if cfg.shards > 1 {
        bail!(
            "serve() drives one borrowed runtime; for --shards {} use serve_sharded \
             with one OwnedEngine per shard",
            cfg.shards
        );
    }
    let engine = Engine::new(rt, ck, cfg.policy.comp_len)?;
    serve_with_backend(&rt.manifest, Box::new(engine), cfg, ready)
}

/// Run a single-executor server over any [`Compute`] backend (protocol
/// tests and host-only benches inject [`crate::compress::SimCompute`]).
/// The executor runs on the calling thread, so the backend need not be
/// `Send`. For multi-shard serving use [`serve_sharded`].
pub fn serve_with_backend<'a>(
    manifest: &Manifest,
    backend: Box<dyn Compute + 'a>,
    cfg: ServerConfig,
    ready: Option<Sender<String>>,
) -> Result<()> {
    if cfg.shards > 1 {
        bail!(
            "serve_with_backend drives one executor; use serve_sharded with {} backends",
            cfg.shards
        );
    }
    let (req_tx, req_rx) = channel::<(Request, Reply)>();
    let router = Router::new(vec![req_tx], &cfg);
    let cfg = &cfg;
    run_server(cfg, router, ready, move || {
        match Executor::new(manifest, backend, cfg, 0).run(req_rx) {
            Ok(replies) => (replies, Ok(())),
            Err(e) => (Vec::new(), Err(e)),
        }
    })
}

/// Run an N-shard server: one executor thread per backend factory,
/// each owning the backend its factory builds. `cfg.shards` is set to
/// the factory count. The listener binds (and `ready` fires) before
/// the factories run, so shard backends build/warm up concurrently
/// while the port is already open: requests arriving early queue on
/// their shard until it is ready (they are answered, not refused —
/// but a warmup longer than the connection's 60 s reply deadline
/// surfaces as per-request timeouts, unlike the single-shard path
/// which binds only after warmup). Sessions route by [`shard_for`]; the
/// global KV budget is partitioned across shards. If a factory fails,
/// its shard is dead (requests routed there get `shard_unavailable`)
/// but the other shards keep serving until shutdown, when the error is
/// returned (after acking the healthy shards' shutdown requesters).
pub fn serve_sharded<'a>(
    manifest: &Manifest,
    factories: Vec<BackendFactory<'a>>,
    mut cfg: ServerConfig,
    ready: Option<Sender<String>>,
) -> Result<()> {
    if factories.is_empty() {
        bail!("serve_sharded needs at least one backend factory");
    }
    cfg.shards = factories.len();
    let mut senders = Vec::with_capacity(cfg.shards);
    let mut work = Vec::with_capacity(cfg.shards);
    for (shard, factory) in factories.into_iter().enumerate() {
        let (tx, rx) = channel::<(Request, Reply)>();
        senders.push(tx);
        work.push((shard, factory, rx));
    }
    let router = Router::new(senders, &cfg);
    let cfg = &cfg;
    run_server(cfg, router, ready, move || {
        std::thread::scope(|s| {
            let handles: Vec<_> = work
                .into_iter()
                .map(|(shard, factory, rx)| {
                    s.spawn(move || -> Result<Vec<Reply>> {
                        let backend = factory()?;
                        Executor::new(manifest, backend, cfg, shard).run(rx)
                    })
                })
                .collect();
            let mut replies = Vec::new();
            let mut first_err = None;
            for h in handles {
                match h.join().expect("executor thread") {
                    Ok(mut r) => replies.append(&mut r),
                    Err(e) => first_err = first_err.or(Some(e)),
                }
            }
            // Replies from healthy shards are returned even when a
            // shard errored: their requesters still get the shutdown
            // ack once the port is released.
            (replies, first_err.map_or(Ok(()), Err))
        })
    })
}

/// Shared serving shell: bind the listener, run the acceptor thread
/// (connection threads dispatch through `router`), drive the executors
/// via `run_executors` (which blocks until every shard has drained and
/// returns the drained shards' shutdown repliers alongside the first
/// shard error, if any), then release the port, ack the shutdown
/// requesters — even on a partial failure — and propagate the error.
fn run_server(
    cfg: &ServerConfig,
    router: Router,
    ready: Option<Sender<String>>,
    run_executors: impl FnOnce() -> (Vec<Reply>, Result<()>),
) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let local = listener.local_addr()?.to_string();
    crate::info!("serving on {local} ({} shard(s), eviction {})", cfg.shards, cfg.eviction.name());
    if let Some(tx) = ready {
        let _ = tx.send(local.clone());
    }

    let stop = Arc::new(AtomicBool::new(false));

    // Acceptor thread: polls the nonblocking listener so it can observe
    // the stop flag; one reader thread per connection. The listener is
    // dropped when this thread exits, releasing the port.
    let acceptor = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let _ = stream.set_nonblocking(false);
                        let router = router.clone();
                        std::thread::spawn(move || {
                            let _ = handle_connection(stream, router);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => {
                        crate::debug!("accept error: {e}");
                        std::thread::sleep(Duration::from_millis(2));
                    }
                }
            }
        })
    };

    let (shutdown_replies, result) = run_executors();
    // Signal the acceptor and join it so the port is actually released
    // before `serve` returns (the seed leaked both thread and port).
    stop.store(true, Ordering::SeqCst);
    let _ = acceptor.join();
    // Only now — listener dropped, port free — ack the shutdown
    // requesters: the ack's documented meaning is "port released".
    for reply in shutdown_replies {
        let _ = reply.send("{\"ok\":true,\"kind\":\"shutdown\"}".into());
    }
    result
}

fn handle_connection(stream: TcpStream, router: Router) -> Result<()> {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    crate::debug!("connection from {peer}");
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (resp_tx, resp_rx) = channel::<String>();
        match Request::parse(&line) {
            Ok(req) => {
                let shutdown = matches!(req, Request::Shutdown);
                if !router.dispatch(req, resp_tx) {
                    break; // executor gone
                }
                match resp_rx.recv_timeout(Duration::from_secs(60)) {
                    Ok(resp) => {
                        writer.write_all(resp.as_bytes())?;
                        writer.write_all(b"\n")?;
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        // Answer instead of silently dropping the client.
                        writer.write_all(b"{\"ok\":false,\"error\":\"timeout\"}\n")?;
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
                if shutdown {
                    break;
                }
            }
            Err(e) => {
                let msg = format!("{{\"ok\":false,\"error\":{}}}\n", escape(&e.to_string()));
                writer.write_all(msg.as_bytes())?;
            }
        }
    }
    Ok(())
}

/// Minimal blocking client for examples and tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn call(&mut self, request: &str) -> Result<Json> {
        self.writer.write_all(request.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            bail!("server closed connection");
        }
        Json::parse(line.trim())
    }

    pub fn add_context(&mut self, session: &str, tokens: &[i32]) -> Result<Json> {
        self.call(&format!(
            "{{\"op\":\"context\",\"session\":{},\"tokens\":{}}}",
            escape(session),
            fmt_tokens(tokens)
        ))
    }

    pub fn query(&mut self, session: &str, tokens: &[i32], topk: usize) -> Result<Vec<(i32, f32)>> {
        let resp = self.call(&format!(
            "{{\"op\":\"query\",\"session\":{},\"tokens\":{},\"topk\":{topk}}}",
            escape(session),
            fmt_tokens(tokens)
        ))?;
        let next = resp.get("next")?.arr()?;
        next.iter()
            .map(|p| {
                let pair = p.arr()?;
                // A null logprob means the logit was non-finite.
                let lp = match &pair[1] {
                    Json::Null => f32::NEG_INFINITY,
                    v => v.f64()? as f32,
                };
                Ok((pair[0].i64()? as i32, lp))
            })
            .collect()
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.call("{\"op\":\"stats\"}")
    }

    pub fn shutdown(&mut self) -> Result<()> {
        match self.call("{\"op\":\"shutdown\"}") {
            // The ack means "drained, listener closed"; an ok:false
            // reply (e.g. a connection-level timeout) is not success.
            Ok(resp) => {
                if resp.get("ok")? == &Json::Bool(true) {
                    Ok(())
                } else {
                    bail!("shutdown not confirmed: {resp}")
                }
            }
            Err(e) if e.to_string().contains("closed") => Ok(()),
            Err(e) => Err(e),
        }
    }
}

fn fmt_tokens(tokens: &[i32]) -> String {
    let inner: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
    format!("[{}]", inner.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_requests() {
        let r = Request::parse(r#"{"op":"context","session":"u1","tokens":[1,2,3]}"#).unwrap();
        match r {
            Request::Context { session, tokens } => {
                assert_eq!(session, "u1");
                assert_eq!(tokens, vec![1, 2, 3]);
            }
            _ => panic!("wrong kind"),
        }
        let r = Request::parse(r#"{"op":"query","session":"u","tokens":[9],"topk":2}"#).unwrap();
        matches!(r, Request::Query { topk: 2, .. }).then_some(()).unwrap();
        assert!(Request::parse(r#"{"op":"nope"}"#).is_err());
        assert!(Request::parse("garbage").is_err());
    }

    #[test]
    fn request_session_is_the_routing_key() {
        let ctx = Request::Context { session: "u1".into(), tokens: vec![1] };
        let q = Request::Query { session: "u2".into(), tokens: vec![2], topk: 1 };
        assert_eq!(ctx.session(), Some("u1"));
        assert_eq!(q.session(), Some("u2"));
        assert_eq!(Request::Stats.session(), None);
        assert_eq!(Request::Shutdown.session(), None);
    }

    #[test]
    fn fmt_tokens_roundtrip() {
        let j = Json::parse(&fmt_tokens(&[1, -2, 30])).unwrap();
        assert_eq!(
            j.arr().unwrap().iter().map(|v| v.i64().unwrap()).collect::<Vec<_>>(),
            vec![1, -2, 30]
        );
    }
}
