//! JSON-lines TCP serving front-end.
//!
//! Connection threads parse newline-delimited JSON requests and forward
//! them over a channel to the single executor thread that owns the PJRT
//! runtime (XLA executables are not Sync; one executor per device is the
//! standard topology). The executor batches across connections via the
//! coordinator's dynamic batcher and replies through per-request channels.
//!
//! Protocol (one JSON object per line):
//!   {"op":"context","session":"u1","tokens":[5,6,7]}
//!   {"op":"query","session":"u1","tokens":[9,2],"topk":5}
//!   {"op":"stats"}            {"op":"shutdown"}
//! Responses:
//!   {"ok":true,"kind":"context","t":3,"kv_bytes":12288}
//!   {"ok":true,"kind":"query","next":[[tok,logprob],...]}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::session::SessionPolicy;
use crate::coordinator::Coordinator;
use crate::model::Checkpoint;
use crate::runtime::Runtime;
use crate::util::json::Json;

#[derive(Debug)]
pub enum Request {
    Context { session: String, tokens: Vec<i32> },
    Query { session: String, tokens: Vec<i32>, topk: usize },
    Stats,
    Shutdown,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line)?;
        let op = j.get("op")?.str()?.to_string();
        let tokens = || -> Result<Vec<i32>> {
            j.get("tokens")?.arr()?.iter().map(|t| Ok(t.i64()? as i32)).collect()
        };
        let session = || -> Result<String> { Ok(j.get("session")?.str()?.to_string()) };
        Ok(match op.as_str() {
            "context" => Request::Context { session: session()?, tokens: tokens()? },
            "query" => Request::Query {
                session: session()?,
                tokens: tokens()?,
                topk: j.opt("topk").and_then(|v| v.usize().ok()).unwrap_or(5),
            },
            "stats" => Request::Stats,
            "shutdown" => Request::Shutdown,
            _ => bail!("unknown op {op:?}"),
        })
    }
}

/// Executor-side handling of one request batch window.
pub struct ServerConfig {
    pub addr: String,
    pub policy: SessionPolicy,
    pub max_batch: usize,
    pub max_wait: Duration,
}

type Reply = Sender<String>;

/// Run the server until a shutdown request arrives. `ready` receives the
/// bound local address (tests bind port 0).
pub fn serve(
    rt: &Runtime,
    ck: &Checkpoint,
    cfg: ServerConfig,
    ready: Option<Sender<String>>,
) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
    let local = listener.local_addr()?.to_string();
    crate::info!("serving on {local}");
    if let Some(tx) = ready {
        let _ = tx.send(local.clone());
    }

    let (req_tx, req_rx) = channel::<(Request, Reply)>();

    // Acceptor thread: one reader thread per connection.
    let acceptor = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = req_tx.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(stream, tx);
            });
        }
    });

    let result = executor_loop(rt, ck, &cfg, req_rx);
    drop(acceptor); // acceptor exits when the process does
    result
}

fn handle_connection(stream: TcpStream, tx: Sender<(Request, Reply)>) -> Result<()> {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    crate::debug!("connection from {peer}");
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (resp_tx, resp_rx) = channel::<String>();
        match Request::parse(&line) {
            Ok(req) => {
                let shutdown = matches!(req, Request::Shutdown);
                if tx.send((req, resp_tx)).is_err() {
                    break; // executor gone
                }
                match resp_rx.recv_timeout(Duration::from_secs(60)) {
                    Ok(resp) => {
                        writer.write_all(resp.as_bytes())?;
                        writer.write_all(b"\n")?;
                    }
                    Err(_) => break,
                }
                if shutdown {
                    break;
                }
            }
            Err(e) => {
                let msg = format!("{{\"ok\":false,\"error\":{:?}}}\n", e.to_string());
                writer.write_all(msg.as_bytes())?;
            }
        }
    }
    Ok(())
}

fn executor_loop(
    rt: &Runtime,
    ck: &Checkpoint,
    cfg: &ServerConfig,
    rx: Receiver<(Request, Reply)>,
) -> Result<()> {
    let mut coord = Coordinator::new(rt, ck, cfg.policy.clone(), cfg.max_batch, cfg.max_wait)?;
    // seq -> (reply channel, input_len, topk) for queries in flight.
    let mut waiting: Vec<(u64, Reply, usize, usize)> = Vec::new();
    loop {
        // Collect a batching window of requests.
        let first = rx.recv_timeout(cfg.max_wait);
        let mut incoming = Vec::new();
        if let Ok(r) = first {
            incoming.push(r);
            while let Ok(r) = rx.try_recv() {
                incoming.push(r);
                if incoming.len() >= cfg.max_batch * 2 {
                    break;
                }
            }
        }
        let mut shutdown = false;
        for (req, reply) in incoming {
            match req {
                Request::Context { session, tokens } => {
                    coord.add_context(&session, tokens);
                    // Context ingestion acks after the batch executes; we
                    // ack immediately with the queued time step.
                    let s = coord.sessions.get_or_create(&session);
                    let msg = format!(
                        "{{\"ok\":true,\"kind\":\"context\",\"t\":{},\"kv_bytes\":{}}}",
                        s.t + 1,
                        s.mem.kv_bytes()
                    );
                    let _ = reply.send(msg);
                }
                Request::Query { session, tokens, topk } => {
                    let n = tokens.len();
                    let seq = coord.query(&session, tokens);
                    waiting.push((seq, reply, n, topk));
                }
                Request::Stats => {
                    let msg = format!(
                        "{{\"ok\":true,\"kind\":\"stats\",\"sessions\":{},\"kv_bytes\":{},\"report\":{:?}}}",
                        coord.sessions.len(),
                        coord.sessions.total_kv_bytes(),
                        coord.metrics.report()
                    );
                    let _ = reply.send(msg);
                }
                Request::Shutdown => {
                    let _ = reply.send("{\"ok\":true,\"kind\":\"shutdown\"}".into());
                    shutdown = true;
                }
            }
        }
        coord.run_until_idle()?;
        // Deliver finished queries.
        waiting.retain(|(seq, reply, input_len, topk)| {
            if let Some(logits) = coord.take_result(*seq) {
                let msg = format_query_response(&logits, *input_len, *topk);
                let _ = reply.send(msg);
                false
            } else {
                true
            }
        });
        if shutdown {
            crate::info!("shutdown: {}", coord.metrics.report());
            return Ok(());
        }
    }
}

/// Top-k next-token distribution at the last real input position.
fn format_query_response(logits: &crate::tensor::Tensor, input_len: usize, topk: usize) -> String {
    let row = logits.row(&[input_len.saturating_sub(1)]);
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f32 = row.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln() + mx;
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
    let pairs: Vec<String> = idx
        .iter()
        .take(topk)
        .map(|&i| format!("[{},{:.4}]", i, row[i] - lse))
        .collect();
    format!("{{\"ok\":true,\"kind\":\"query\",\"next\":[{}]}}", pairs.join(","))
}

/// Minimal blocking client for examples and tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn call(&mut self, request: &str) -> Result<Json> {
        self.writer.write_all(request.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            bail!("server closed connection");
        }
        Json::parse(line.trim())
    }

    pub fn add_context(&mut self, session: &str, tokens: &[i32]) -> Result<Json> {
        self.call(&format!(
            "{{\"op\":\"context\",\"session\":{session:?},\"tokens\":{}}}",
            fmt_tokens(tokens)
        ))
    }

    pub fn query(&mut self, session: &str, tokens: &[i32], topk: usize) -> Result<Vec<(i32, f32)>> {
        let resp = self.call(&format!(
            "{{\"op\":\"query\",\"session\":{session:?},\"tokens\":{},\"topk\":{topk}}}",
            fmt_tokens(tokens)
        ))?;
        let next = resp.get("next")?.arr()?;
        next.iter()
            .map(|p| {
                let pair = p.arr()?;
                Ok((pair[0].i64()? as i32, pair[1].f64()? as f32))
            })
            .collect()
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.call("{\"op\":\"stats\"}")
    }

    pub fn shutdown(&mut self) -> Result<()> {
        self.call("{\"op\":\"shutdown\"}")
            .map(|_| ())
            .or_else(|e| if e.to_string().contains("closed") { Ok(()) } else { Err(e) })
    }
}

fn fmt_tokens(tokens: &[i32]) -> String {
    let inner: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
    format!("[{}]", inner.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_requests() {
        let r = Request::parse(r#"{"op":"context","session":"u1","tokens":[1,2,3]}"#).unwrap();
        match r {
            Request::Context { session, tokens } => {
                assert_eq!(session, "u1");
                assert_eq!(tokens, vec![1, 2, 3]);
            }
            _ => panic!("wrong kind"),
        }
        let r = Request::parse(r#"{"op":"query","session":"u","tokens":[9],"topk":2}"#).unwrap();
        matches!(r, Request::Query { topk: 2, .. }).then_some(()).unwrap();
        assert!(Request::parse(r#"{"op":"nope"}"#).is_err());
        assert!(Request::parse("garbage").is_err());
    }

    #[test]
    fn formats_query_response_as_valid_json() {
        let mut logits = crate::tensor::Tensor::zeros(&[4, 6]);
        logits.set(&[1, 3], 5.0);
        let s = format_query_response(&logits, 2, 3);
        let j = Json::parse(&s).unwrap();
        let next = j.get("next").unwrap().arr().unwrap();
        assert_eq!(next.len(), 3);
        assert_eq!(next[0].arr().unwrap()[0].i64().unwrap(), 3);
        // log-probs <= 0
        assert!(next[0].arr().unwrap()[1].f64().unwrap() <= 0.0);
    }

    #[test]
    fn fmt_tokens_roundtrip() {
        let j = Json::parse(&fmt_tokens(&[1, -2, 30])).unwrap();
        assert_eq!(
            j.arr().unwrap().iter().map(|v| v.i64().unwrap()).collect::<Vec<_>>(),
            vec![1, -2, 30]
        );
    }
}
