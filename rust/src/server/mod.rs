//! JSON-lines TCP serving front-end.
//!
//! Connections parse newline-delimited JSON requests and hand them to
//! the router (see [`router`]), which fans them out to N shard
//! executors. Each shard (see `executor.rs`) owns its own [`Compute`]
//! backend, dynamic batcher, and session manager — the standard
//! one-executor-per-device topology (XLA executables are not Sync) —
//! and runs the continuously-pumped pipeline from PR 1: each turn it
//! (1) drains whatever requests are queued, (2) executes at most one
//! batch through its coordinator, and (3) delivers any finished query
//! results — so a fast query is never stuck behind another session's
//! full queue drain, and intake keeps flowing while batches execute.
//!
//! ## I/O front-ends (`--reactor threads|epoll`)
//!
//! Two interchangeable transport front-ends feed the router; the wire
//! protocol and reply semantics are identical under both
//! (`CCM_SERVE_REACTOR=threads|epoll` selects one for the whole test
//! suite; the default is `epoll` on Linux, `threads` elsewhere):
//!
//! * **`epoll` (default on Linux)** — N reactor threads (`--reactors`,
//!   default 1 for the library, `auto` = min(4, cores) for `ccm
//!   serve`) own every accepted connection in non-blocking mode,
//!   multiplexing readiness through a zero-dependency epoll wrapper
//!   (`poll.rs`: raw `epoll_create1`/`epoll_ctl`/`epoll_wait` plus an
//!   `eventfd` waker; a portable fallback scan loop keeps the mode
//!   working off-Linux, and `CCM_FORCE_FALLBACK_POLL=1` runs that scan
//!   loop on Linux so CI exercises it). **Accept sharding:** with
//!   `--reactors N > 1` each reactor binds its own `SO_REUSEPORT`
//!   listener on the shared address and the kernel hash-balances
//!   incoming connections across them; where the option is unavailable
//!   (non-Linux, pre-3.9 kernels, or `CCM_FORCE_ACCEPT_HANDOFF=1`)
//!   reactor 0 owns a single listener and hands accepted sockets
//!   round-robin to its peers through waker-signalled inboxes. A
//!   connection lives its whole life on one reactor. Per connection
//!   the reactor keeps an explicit state struct: a capped read buffer
//!   with incremental line framing, a write buffer with partial-write
//!   continuation (reads pause while a slow client's reply backlog
//!   exceeds 1 MiB — backpressure, not unbounded growth), and a
//!   pending-reply queue that delivers replies strictly in request
//!   order even when shards finish out of order. Executor shards push
//!   replies into the owning reactor's eventfd-signalled completion
//!   queue (the reply handle pins that reactor's queue, so delivery
//!   needs no cross-reactor routing) instead of blocking a
//!   per-connection thread. Per-request deadlines drive each reactor's
//!   poll timeout, so `timeout` replies fire when due. Shutdown is a
//!   staged per-reactor handshake fanned out by the serve shell: every
//!   reactor closes its listener and confirms before ANY shutdown ack
//!   is written — the multi-reactor form of "ack means port released".
//!   Scales to 10k+ concurrent sessions (one `Conn` struct each, no
//!   thread stacks) — stress-gated in CI at 1024 connections under
//!   both `--reactors 1` and `--reactors 4`.
//! * **`threads`** — one blocking reader thread per connection (the
//!   PR 1/PR 2 front-end), kept as a fallback and as the portable
//!   reference implementation.
//!
//! `--max-conns` bounds accepted connections in both modes (excess
//! connections get a `too_many_connections` reply and are closed);
//! oversized request lines are refused with `line_too_long` in both
//! modes and the connection stays usable (framing resynchronises at
//! the next newline), so a slow-loris peer cannot pin buffer memory.
//!
//! ## Sharding (`--shards N`)
//!
//! Sessions are routed with a stable hash of the session id
//! ([`shard_for`]): one session id ALWAYS maps to the same shard, so a
//! session's compressed memory Mem(t) never migrates and per-session
//! ordering is preserved across any number of connections. Per-shard
//! KV budgets partition the global `--kv-budget-mb` (slices sum
//! exactly to the global budget), admission control (`--max-pending`)
//! bounds each shard's queue independently — one flooded shard refuses
//! work while the others keep serving — and each shard evicts by the
//! selected `--eviction` policy (`oldest` | `lru` | `largest-bytes`).
//! With `--shards 1` (the default) the engine behaves exactly like the
//! PR 1 single-executor pipeline.
//!
//! ## Cross-process workers (`--workers N` | `--worker-addr a,b,...`)
//!
//! [`serve_workers`] promotes shards to worker PROCESSES: the front-end
//! keeps the transport above, but each shard executor runs inside its
//! own `ccm worker --shard K` process (one XLA device per OS process —
//! PJRT runtimes are thread-bound and device-per-process is the
//! deployment shape), connected over a newline-framed JSON IPC protocol
//! on a loopback socket (request frames carry a pipelining `id`; reply
//! frames return `{"id":N,"resp":<the executor's reply, verbatim>}`;
//! framing is newline-delimited with JSON-escaped payloads, so a torn
//! read can never desync the stream — see `ipc.rs`). The SAME
//! [`shard_for`] hash routes sessions, so Mem(t) stays pinned to one
//! worker as the fleet grows past a single process.
//!
//! **IPC codec negotiation** (`--ipc-codec json|binary`, default
//! binary): on every (re)attach the proxy's first frame is a JSON
//! hello — `{"id":N,"op":"hello","codec":"binary","version":1}` — and
//! only after the worker acks it
//! (`{"ok":true,"kind":"hello","codec":"binary","version":1}`) does
//! the proxy switch its request encoding to length-prefixed binary
//! frames (magic byte `0xCC`, so a receiver distinguishes them from
//! JSON lines by the first byte; layout in `ipc.rs`). The worker
//! mirrors per frame: a binary request gets a binary reply, a JSON
//! line gets a JSON line. A peer that answers the hello with an error
//! — any pre-codec build, or an external `--worker-addr` worker that
//! only speaks JSON — is **negotiated down**: the connection simply
//! stays on the JSON codec and every PR 5 failure/drain/stats
//! guarantee holds unchanged. The client-facing wire protocol is
//! byte-identical JSON under both codecs. Both IPC writers batch
//! bursts of queued frames into gathered `writev` writes (poll.rs), so
//! a pipelined burst costs one syscall instead of one `write_all` per
//! frame.
//!
//! A supervisor thread per worker spawns it, reads its
//! `CCM_WORKER_READY <addr>` stdout handshake, connects with backoff,
//! and respawns it (exponential backoff, `shard_restarts` counter in
//! stats) when it dies. **Failure semantics:** while a worker is down,
//! requests routed to its shard are answered immediately with the
//! documented `{"ok":false,"error":"shard_unavailable"}` — in-flight
//! requests fail over to the same reply the moment the connection
//! drops; nothing hangs and the client connection stays open. A
//! respawned worker starts with FRESH sessions: the compressed memory
//! Mem(t) died with its owner, so a session's next request
//! transparently restarts it at t=0 (the same contract as KV-budget
//! eviction, at process granularity). Merged stats gain a `per_worker`
//! breakdown (`worker`, `pid`, `up`, `restarts`, `rtt_ms`) plus the
//! summed `shard_restarts`; a down worker's per-shard row reports
//! zeroed counters with `"down":true` instead of failing the whole
//! stats request closed. Shutdown fans out across the IPC boundary:
//! every worker drains its executor, acks, and exits before any client
//! shutdown ack is written (still after the front-end's listener is
//! released); a worker that dies mid-drain counts as drained, and one
//! that stalls past a kill deadline is killed so shutdown always
//! completes.
//!
//! ## Protocol (one JSON object per line)
//!
//! Requests:
//!   {"op":"context","session":"u1","tokens":[5,6,7]}
//!   {"op":"context","session":"u1","tokens":[5,6,7],"strategy":"ccm"}
//!       `strategy` (`ccm` | `sliding-window` | `none`) selects the
//!       session's compression tier AT ADMISSION — the first context
//!       chunk that creates the session pins it; later values are
//!       ignored (a session's memory shape cannot change mid-stream).
//!       Absent → the server's `--strategy` default (ccm).
//!   {"op":"query","session":"u1","tokens":[9,2],"topk":5}
//!   {"op":"stats"}            {"op":"stats","detail":true}
//!   {"op":"stats","detail":true,"prefix":"user-","limit":100}
//!   {"op":"stats","detail":true,"after_id":"user-1041","limit":100}
//!   {"op":"shutdown"}
//!
//! Responses:
//!   {"ok":true,"kind":"context","t":3,"kv_bytes":12288}
//!       `t` is the time step the chunk will land on: two chunks queued
//!       back-to-back for one session ack t+1 and t+2. `kv_bytes` is the
//!       session's compressed-KV size at ack time (pre-compression).
//!   {"ok":true,"kind":"query","next":[[tok,logprob],...]}
//!   {"ok":true,"kind":"stats",...}
//!       Live usage (sessions, kv_bytes, pending queued work, waiting
//!       queries in flight, requests/compressions/inferences/batches,
//!       rejected_overload, sessions_evicted, sessions_reaped,
//!       priority_overrides, peak_kv_bytes) PLUS the configured limits
//!       (kv_budget_bytes, session_ttl_secs, max_pending, eviction) so
//!       operators can compute headroom from the response alone. With
//!       one shard the object carries its `shard` id and the
//!       human-readable `report`; with N shards the response is the
//!       merged global view (counters summed, `shards`:N) and
//!       `per_shard` embeds each shard's own stats object. With
//!       `"detail":true` the response additionally carries a
//!       `sessions_detail` array — one object per resident session
//!       (`id`, `t`, `kv_bytes`, `age_ms`, `idle_ms`), sorted by id;
//!       merged across shards in the sharded view — so operators and
//!       the CI stress gate can audit per-session accounting. For
//!       fleets with large resident-session counts the detail view can
//!       be bounded: `"prefix"` keeps only ids starting with it, and
//!       `"limit"` truncates to the first N rows by id (applied after
//!       the cross-shard merge, so it is a global bound). `"after_id"`
//!       is a cursor token: only ids strictly greater than it are
//!       returned, so `limit`-sized pages chain (`after_id` = last id
//!       of the previous page) without re-scanning or re-sending
//!       earlier rows. The stats object also carries a `strategies`
//!       map — per compression tier (`ccm`, `sliding-window`, `none`):
//!       resident `sessions`, `kv_bytes`, `compressions`, `inferences`,
//!       `tokens_dropped` (lossy-retention drops), and scheduling
//!       `overrides` charged to that tier — summed across shards in
//!       the merged view. Under the
//!       epoll front-end the response also carries `per_reactor` — one
//!       object per reactor thread (`reactor`, `conns` currently open,
//!       `accepted` total, `lines` framed, `refusals`) — so operators
//!       can verify the accept sharding actually balances.
//!   {"ok":true,"kind":"shutdown"}
//!       Sent after in-flight work has drained on EVERY shard; the
//!       listener is closed and the acceptor thread joined before
//!       `serve` returns.
//!
//! Error responses (admission control and lifecycle):
//!   {"ok":false,"error":"overloaded","pending":N}
//!       The target shard's bounded pending queue (`max_pending`) is
//!       full. Back off and retry; the connection stays open. Other
//!       shards are unaffected.
//!   {"ok":false,"error":"shutting_down","pending":N}
//!       A shutdown is draining; no new work is admitted.
//!   {"ok":false,"error":"too_long","what":"chunk"|"input","got":N,"limit":N}
//!       Token list exceeds the artifact shape (chunk_max / input_max);
//!       validated at admission so it never fails a batch.
//!   {"ok":false,"error":"timeout"}
//!       The executor did not answer within the per-request deadline.
//!   {"ok":false,"error":"line_too_long"}
//!       The request line exceeded `max_line_bytes`. The buffered
//!       bytes are dropped and framing resumes at the next newline —
//!       the connection stays open (slow-loris hardening).
//!   {"ok":false,"error":"too_many_connections"}
//!       Sent once on accept when `--max-conns` is reached, then the
//!       connection is closed.
//!   {"ok":false,"error":"stats_unavailable"}
//!       A shard could not answer a fanned-out stats request (e.g. it
//!       is mid-shutdown); merged stats fail closed over partial data.
//!   {"ok":false,"error":"shard_unavailable"}
//!       The session's shard executor is gone: in process, for good
//!       (it drained during a shutdown, or its backend failed to
//!       initialize — not retryable); with worker shards, the worker
//!       process is down (a retry can succeed once the supervisor
//!       respawns it, but the shard's sessions restart fresh — their
//!       compressed memory died with the process). The connection
//!       stays open for sessions on other shards.
//!   {"ok":false,"error":"..."} for malformed requests.
//!
//! ## Memory governance
//!
//! With `kv_budget_bytes` set, each shard enforces its slice of the
//! global compressed-KV budget after every executed batch: idle
//! sessions are evicted in [`EvictionPolicy`] order until under
//! budget. Sessions with queued work are never evicted. With
//! `session_ttl` set, sessions idle longer than the TTL are reaped
//! periodically. Both are counted in `stats` (`sessions_evicted`,
//! `sessions_reaped`). A later request for an evicted session
//! transparently starts a fresh session (its compressed memory is
//! gone — that is the cost of the budget).
//!
//! ## Hibernation (`--hibernate-dir` + `--hibernate-after-secs`)
//!
//! With a hibernation directory configured, the session lifecycle
//! gains a middle level: hot RAM → disk → gone. Each shard's executor
//! spills sessions idle past the threshold into per-shard snapshot
//! files (versioned + CRC'd `Mem(t)` codec, written tmp-then-rename so
//! a crash never leaves a torn snapshot — see `hibernate.rs` and
//! `crate::model::snapshot`), excluding their bytes from the hot KV
//! budget; budget eviction likewise spills victims before dropping
//! them. The next request for a hibernated session transparently
//! rehydrates it, resuming at its pre-spill `t` (the rehydrate cost is
//! folded into that request's normal latency). Failure contract: a
//! corrupt or missing snapshot degrades to a FRESH session — exactly
//! eviction semantics, never an error to the client. Stats grow
//! `hibernated_sessions` / `hibernated_bytes` gauges and `spills` /
//! `rehydrations` / `snapshot_corrupt` counters (summed in the merged
//! multi-shard view).
//!
//! ## Invariants
//!
//! This module tree is the serving core, and `docs/INVARIANTS.md`
//! lists the mechanical rules it is held to by the `ccm-lint` CI gate
//! (`cargo run -p ccm-lint -- rust/src rust/tests examples`): every
//! `unsafe` carries a `// SAFETY:` comment, no `.unwrap()` without a
//! `// lint: allow(unwrap)` justification (mutex poisoning
//! propagation excepted), no mutex guard held across blocking I/O,
//! raw fd syscalls confined to `poll.rs`, `Ordering::Relaxed` off
//! counters justified with `// ordering:`, and no `env::set_var` in
//! tests.
//!
//! ## Operator docs
//!
//! `docs/ARCHITECTURE.md` distills the load-bearing invariants of this
//! module tree (routing invariant, topology, failure contract, CI
//! gates) with the request-lifecycle diagram; `docs/SCENARIOS.md` is
//! the operator handbook for driving this server with the paper's
//! workloads via `ccm loadgen` (`crate::bench::loadgen`) and reading
//! the latency/refusal/compression-quality output.
//!
//! [`EvictionPolicy`]: crate::coordinator::session::EvictionPolicy

mod executor;
pub mod hibernate;
mod ipc;
mod poll;
mod reactor;
pub mod router;
mod worker;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::compress::{Compute, Engine, StrategyKind, Tiers};
use crate::coordinator::session::{EvictionKind, SessionPolicy};
use crate::model::manifest::Manifest;
use crate::model::Checkpoint;
use crate::runtime::Runtime;
use crate::util::json::{escape, Json};

use executor::Executor;
use router::Router;

pub use router::shard_for;
pub use worker::{run_worker, serve_workers, WorkerLauncher, WorkerMode, WORKER_READY_PREFIX};

/// A `stats` request's knobs. `detail` opts into `sessions_detail`;
/// `prefix`/`limit` bound that view for fleets with large
/// resident-session counts (prefix filter, then first-N-by-id).
/// `per_reactor` is internal plumbing: the router fills it with the
/// pre-rendered per-reactor transport rows before forwarding to a
/// single shard (the merged multi-shard view renders its own), so the
/// executor can embed transport stats it has no other way to see.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StatsQuery {
    pub detail: bool,
    pub prefix: Option<String>,
    /// Cursor token: only session ids strictly greater than this are
    /// returned, so pages chain without re-scanning earlier rows.
    pub after_id: Option<String>,
    pub limit: Option<usize>,
    pub per_reactor: Option<String>,
}

impl StatsQuery {
    /// Shorthand for `{"op":"stats","detail":true}`.
    pub fn detailed() -> StatsQuery {
        StatsQuery { detail: true, ..Default::default() }
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum Request {
    /// `strategy` applies only when this admission creates the session
    /// (first touch pins the tier); `None` means the server default.
    Context { session: String, tokens: Vec<i32>, strategy: Option<StrategyKind> },
    Query { session: String, tokens: Vec<i32>, topk: usize },
    Stats(StatsQuery),
    Shutdown,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        Request::from_json(&Json::parse(line)?)
    }

    /// Build a request from already-parsed JSON (unknown keys are
    /// ignored, which is what lets the IPC layer decode its `id`-tagged
    /// request frames with the same grammar as the client protocol).
    pub fn from_json(j: &Json) -> Result<Request> {
        let op = j.get("op")?.str()?.to_string();
        let tokens = || -> Result<Vec<i32>> {
            j.get("tokens")?.arr()?.iter().map(|t| Ok(t.i64()? as i32)).collect()
        };
        let session = || -> Result<String> { Ok(j.get("session")?.str()?.to_string()) };
        Ok(match op.as_str() {
            "context" => Request::Context {
                session: session()?,
                tokens: tokens()?,
                // A present-but-unknown strategy is a client error and
                // refused (silently defaulting would mis-tier quietly).
                strategy: match j.opt("strategy").and_then(|v| v.str().ok()) {
                    Some(name) => Some(StrategyKind::parse(name)?),
                    None => None,
                },
            },
            "query" => Request::Query {
                session: session()?,
                tokens: tokens()?,
                topk: j.opt("topk").and_then(|v| v.usize().ok()).unwrap_or(5),
            },
            "stats" => Request::Stats(StatsQuery {
                detail: matches!(j.opt("detail"), Some(Json::Bool(true))),
                prefix: j.opt("prefix").and_then(|v| v.str().ok()).map(str::to_string),
                after_id: j.opt("after_id").and_then(|v| v.str().ok()).map(str::to_string),
                limit: j.opt("limit").and_then(|v| v.usize().ok()),
                per_reactor: None,
            }),
            "shutdown" => Request::Shutdown,
            _ => bail!("unknown op {op:?}"),
        })
    }

    /// Session id for session-routed ops (the routing key of
    /// [`shard_for`]); `None` for fan-out ops (stats, shutdown).
    pub fn session(&self) -> Option<&str> {
        match self {
            Request::Context { session, .. } | Request::Query { session, .. } => Some(session),
            Request::Stats(_) | Request::Shutdown => None,
        }
    }
}

/// Transport front-end for the serve loop: blocking reader threads
/// (one per connection) or the event-driven polling reactor. See the
/// module docs; the wire protocol is identical under both.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReactorMode {
    /// One blocking reader thread per connection.
    Threads,
    /// Non-blocking readiness reactor (epoll on Linux; a portable
    /// fallback scan loop elsewhere keeps the mode available).
    Epoll,
}

impl ReactorMode {
    pub fn parse(name: &str) -> Result<ReactorMode> {
        Ok(match name {
            "threads" => ReactorMode::Threads,
            "epoll" => ReactorMode::Epoll,
            other => bail!("unknown reactor mode {other:?} (threads|epoll)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ReactorMode::Threads => "threads",
            ReactorMode::Epoll => "epoll",
        }
    }

    /// `CCM_SERVE_REACTOR` if set to a valid mode (the CI matrix runs
    /// the whole suite under each), else the platform default: epoll
    /// on Linux, threads elsewhere.
    pub fn from_env() -> ReactorMode {
        match std::env::var("CCM_SERVE_REACTOR").ok().as_deref().map(ReactorMode::parse) {
            Some(Ok(mode)) => mode,
            _ => {
                if cfg!(target_os = "linux") {
                    ReactorMode::Epoll
                } else {
                    ReactorMode::Threads
                }
            }
        }
    }
}

/// `auto` reactor count for the epoll front-end: min(4, cores). Four
/// event loops saturate a NIC long before four cores do; past that the
/// bottleneck is the executors, not accept/readiness dispatch.
pub fn auto_reactors() -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get()).clamp(1, 4)
}

/// Reactor-thread count from `CCM_SERVE_REACTORS` (a positive integer,
/// or `auto` = [`auto_reactors`]); 1 when unset — the library default
/// stays the PR 3 single-reactor baseline, while `ccm serve` defaults
/// its `--reactors` flag to `auto` (and rejects garbage outright via
/// `Args::usize_env_auto`). An unparsable value here degrades to 1
/// WITH a logged warning, never silently — the CI stress matrix
/// drives this through 1 and 4 and must not quietly lose coverage.
pub fn reactors_from_env() -> usize {
    match std::env::var("CCM_SERVE_REACTORS").ok().as_deref() {
        Some("auto") => auto_reactors(),
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n > 0 => n,
            _ => {
                crate::info!(
                    "ignoring invalid CCM_SERVE_REACTORS={v:?} (want a positive integer or \
                     `auto`); using 1 reactor"
                );
                1
            }
        },
        None => 1,
    }
}

/// Shard-IPC wire codec (`--ipc-codec json|binary`).
///
/// `Binary` is the default: the proxy opens every worker connection
/// with a JSON hello (`{"op":"hello","codec":"binary","version":1}`)
/// and switches to length-prefixed binary frames only after the worker
/// acks it — a peer that answers with an error (any pre-codec or
/// external `--worker-addr` worker) is negotiated down and the
/// connection simply stays on newline-framed JSON. `Json` pins the
/// legacy codec on both sides. The client-facing protocol is JSON
/// either way; this only selects the front-end ↔ worker hop's
/// encoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IpcCodec {
    Json,
    Binary,
}

impl IpcCodec {
    pub fn parse(name: &str) -> Result<IpcCodec> {
        match name {
            "json" => Ok(IpcCodec::Json),
            "binary" => Ok(IpcCodec::Binary),
            other => anyhow::bail!("unknown IPC codec {other:?} (want `json` or `binary`)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            IpcCodec::Json => "json",
            IpcCodec::Binary => "binary",
        }
    }

    /// `CCM_IPC_CODEC` if valid (lets CI steer a whole test run across
    /// the codec matrix without touching any call site), else binary.
    pub fn from_env() -> IpcCodec {
        match std::env::var("CCM_IPC_CODEC").ok().as_deref() {
            Some(v) => match IpcCodec::parse(v) {
                Ok(codec) => codec,
                Err(_) => {
                    crate::info!(
                        "ignoring invalid CCM_IPC_CODEC={v:?} (want `json` or `binary`); \
                         using binary"
                    );
                    IpcCodec::Binary
                }
            },
            None => IpcCodec::Binary,
        }
    }
}

/// Serving configuration. `new` fills production-shaped defaults; set
/// the public fields to tune.
pub struct ServerConfig {
    pub addr: String,
    pub policy: SessionPolicy,
    /// Artifact batch width each shard's coordinator packs towards.
    pub max_batch: usize,
    /// Dynamic-batching age trigger (how long a lone item waits).
    pub max_wait: Duration,
    /// Admission control, per shard: queued work items beyond this are
    /// refused with an `overloaded` reply instead of buffered without
    /// bound.
    pub max_pending: usize,
    /// Global compressed-KV budget across all sessions (bytes);
    /// partitioned into per-shard slices that sum exactly to it.
    pub kv_budget_bytes: Option<usize>,
    /// Idle-session TTL; idle sessions beyond it are reaped.
    pub session_ttl: Option<Duration>,
    /// Executor shard count. Informational for [`serve_with_backend`]
    /// (which drives exactly one executor); [`serve_sharded`] overrides
    /// it with the number of backends supplied.
    pub shards: usize,
    /// Session-eviction policy under KV-budget pressure.
    pub eviction: EvictionKind,
    /// Transport front-end (`--reactor threads|epoll`). Defaults to
    /// [`ReactorMode::from_env`]: `CCM_SERVE_REACTOR` if valid, else
    /// epoll on Linux / threads elsewhere.
    pub reactor: ReactorMode,
    /// Reactor-thread count for the epoll front-end (`--reactors`):
    /// each reactor owns its own poller, waker, connection table, and
    /// completion queue, with `SO_REUSEPORT` accept sharding where
    /// available. Defaults to [`reactors_from_env`] (1 unless
    /// `CCM_SERVE_REACTORS` says otherwise). Ignored in threads mode.
    pub reactors: usize,
    /// Force the single-listener round-robin accept handoff even where
    /// `SO_REUSEPORT` is available (test/CI escape hatch; also set by
    /// `CCM_FORCE_ACCEPT_HANDOFF=1`).
    pub force_accept_handoff: bool,
    /// Per-request reply deadline: past it the front-end answers
    /// `{"ok":false,"error":"timeout"}` instead of silently dropping
    /// the client. The reactor wakes for the earliest pending deadline,
    /// so expiry latency is one poll wakeup.
    pub reply_timeout: Duration,
    /// Accepted-connection bound (both front-ends): connections beyond
    /// it get one `too_many_connections` line and are closed.
    pub max_conns: usize,
    /// Per-connection request-line cap (both front-ends): longer lines
    /// are refused with `line_too_long` and discarded through the next
    /// newline, so a slow-loris peer cannot pin buffer memory.
    pub max_line_bytes: usize,
    /// Shard-IPC codec preference (`--ipc-codec`). On the front-end it
    /// decides whether worker connections attempt the binary hello; on
    /// a worker it decides whether such a hello is granted. Defaults
    /// to [`IpcCodec::from_env`] (`CCM_IPC_CODEC` if valid, else
    /// binary).
    pub ipc_codec: IpcCodec,
    /// Compression tier for sessions admitted without an explicit
    /// `"strategy"` field (`--strategy`, default `ccm`).
    pub default_strategy: StrategyKind,
    /// Per-tier retention + QoS shapes (`--tiers`): token-bucket
    /// refill/burst for priority overrides and the sliding-window
    /// tier's raw-KV budget.
    pub tiers: Tiers,
    /// Worker-supervisor respawn backoff floor (`--respawn-backoff-min`;
    /// the schedule doubles from here after each failed spawn/attach).
    pub respawn_backoff_min: Duration,
    /// Worker-supervisor respawn backoff ceiling (`--respawn-backoff-max`).
    pub respawn_backoff_max: Duration,
    /// How long shutdown waits for a worker to drain before killing it
    /// (`--shutdown-kill-after`) so shutdown always completes.
    pub shutdown_kill_after: Duration,
    /// How long a refused (over `--max-conns`) connection is kept open
    /// to flush its refusal line under the epoll front-end
    /// (`--refusal-linger`).
    pub refusal_linger: Duration,
    /// Listener pause after a failed accept under the epoll front-end
    /// (`--accept-backoff`) — EMFILE etc. resolve by waiting, and
    /// re-polling instantly would spin.
    pub accept_backoff: Duration,
    /// On-disk hibernation root (`--hibernate-dir`). Each shard spills
    /// idle sessions into `<dir>/shard-<K>/` as CRC'd snapshot files
    /// and rehydrates them transparently on the next touch. `None`
    /// disables the tier (the two-level PR 1 lifecycle).
    pub hibernate_dir: Option<std::path::PathBuf>,
    /// Idle threshold before a resident session is spilled
    /// (`--hibernate-after-secs`). Ignored without `hibernate_dir`;
    /// with a directory but no threshold the executor uses 60 s.
    pub hibernate_after: Option<Duration>,
    /// Orphan-watchdog grace a worker allows for its FIRST front-end
    /// connection before exiting (`ccm worker --orphan-grace-secs`,
    /// default 120 s); also bounds the startup sweep of stale spill
    /// tmp files.
    pub orphan_grace: Duration,
}

impl ServerConfig {
    pub fn new(addr: impl Into<String>, policy: SessionPolicy) -> ServerConfig {
        ServerConfig {
            addr: addr.into(),
            policy,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            max_pending: 256,
            kv_budget_bytes: None,
            session_ttl: None,
            shards: 1,
            eviction: EvictionKind::OldestCreated,
            reactor: ReactorMode::from_env(),
            reactors: reactors_from_env(),
            force_accept_handoff: std::env::var("CCM_FORCE_ACCEPT_HANDOFF").ok().as_deref()
                == Some("1"),
            reply_timeout: REPLY_TIMEOUT,
            max_conns: 16_384,
            max_line_bytes: 256 * 1024,
            ipc_codec: IpcCodec::from_env(),
            default_strategy: StrategyKind::Ccm,
            tiers: Tiers::default(),
            respawn_backoff_min: Duration::from_millis(50),
            respawn_backoff_max: Duration::from_secs(2),
            shutdown_kill_after: Duration::from_secs(30),
            refusal_linger: Duration::from_secs(5),
            accept_backoff: Duration::from_millis(50),
            hibernate_dir: None,
            hibernate_after: None,
            orphan_grace: ORPHAN_GRACE_DEFAULT,
        }
    }
}

/// Default orphan-watchdog grace for a worker's first front-end
/// connection ([`ServerConfig::orphan_grace`]; `--orphan-grace-secs`).
pub const ORPHAN_GRACE_DEFAULT: Duration = Duration::from_secs(120);

/// Default per-request reply deadline ([`ServerConfig::reply_timeout`];
/// both front-ends answer `timeout` past it rather than silently
/// dropping the client).
pub(crate) const REPLY_TIMEOUT: Duration = Duration::from_secs(60);
pub(crate) const TIMEOUT_REPLY: &str = "{\"ok\":false,\"error\":\"timeout\"}";
pub(crate) const LINE_TOO_LONG_REPLY: &str = "{\"ok\":false,\"error\":\"line_too_long\"}";
pub(crate) const TOO_MANY_CONNS_REPLY: &str = "{\"ok\":false,\"error\":\"too_many_connections\"}";
pub(crate) const SHUTDOWN_ACK: &str = "{\"ok\":true,\"kind\":\"shutdown\"}";
/// Reply for a request routed to a shard whose executor is gone — in
/// process: its channel closed (it drained during a shutdown, or its
/// backend factory failed at startup; not retryable). With worker
/// shards: the worker process is down or unreachable; the supervisor
/// may respawn it with FRESH sessions, so a later retry can succeed but
/// the session's compressed memory is gone either way. Distinct from
/// the retryable `shutting_down` refusal a live, draining shard sends.
/// The client keeps its connection (other shards still serve it).
pub(crate) const SHARD_UNAVAILABLE: &str = "{\"ok\":false,\"error\":\"shard_unavailable\"}";

/// Where an executor's reply for one request goes: a blocking channel
/// (threads mode: the connection thread waits on the receiver) or the
/// owning reactor's completion queue (the handle pins that reactor's
/// queue and tags connection + request id, so the reply lands on the
/// right event loop in per-connection request order without any
/// cross-reactor routing).
#[derive(Clone)]
pub(crate) enum Reply {
    Channel(Sender<String>),
    Completion(reactor::CompletionHandle),
    /// Worker-process side of the IPC boundary: the reply is tagged
    /// with its request id and framed back to the front-end.
    Ipc(ipc::IpcReplyHandle),
}

impl Reply {
    pub(crate) fn channel(tx: Sender<String>) -> Reply {
        Reply::Channel(tx)
    }

    /// Deliver a reply. `Err` means the requester is gone (its channel
    /// hung up, or the IPC connection's writer exited); completion-
    /// queue delivery cannot fail — the reactor drops replies for
    /// connections that have since closed.
    pub(crate) fn send(&self, msg: String) -> std::result::Result<(), ()> {
        match self {
            Reply::Channel(tx) => tx.send(msg).map_err(|_| ()),
            Reply::Completion(handle) => {
                handle.send(msg);
                Ok(())
            }
            Reply::Ipc(handle) => handle.send(msg),
        }
    }
}

/// Builds one shard's [`Compute`] backend INSIDE that shard's executor
/// thread, so a backend may own thread-bound state (e.g. a PJRT
/// runtime, which must never cross threads).
pub type BackendFactory<'a> = Box<dyn FnOnce() -> Result<Box<dyn Compute + 'a>> + Send + 'a>;

/// Run the server until a shutdown request arrives, over the XLA engine
/// borrowed from `rt`. Single-executor only: a PJRT runtime is
/// thread-bound, so multi-shard serving needs one owned runtime per
/// shard — build [`crate::compress::OwnedEngine`] factories and call
/// [`serve_sharded`] instead (see `cli_serve` for the wiring).
/// `ready` receives the bound local address (tests bind port 0).
pub fn serve(
    rt: &Runtime,
    ck: &Checkpoint,
    cfg: ServerConfig,
    ready: Option<Sender<String>>,
) -> Result<()> {
    if cfg.shards > 1 {
        bail!(
            "serve() drives one borrowed runtime; for --shards {} use serve_sharded \
             with one OwnedEngine per shard",
            cfg.shards
        );
    }
    let engine = Engine::new(rt, ck, cfg.policy.comp_len)?;
    serve_with_backend(&rt.manifest, Box::new(engine), cfg, ready)
}

/// Run a single-executor server over any [`Compute`] backend (protocol
/// tests and host-only benches inject [`crate::compress::SimCompute`]).
/// The executor runs on the calling thread, so the backend need not be
/// `Send`. For multi-shard serving use [`serve_sharded`].
pub fn serve_with_backend<'a>(
    manifest: &Manifest,
    backend: Box<dyn Compute + 'a>,
    cfg: ServerConfig,
    ready: Option<Sender<String>>,
) -> Result<()> {
    if cfg.shards > 1 {
        bail!(
            "serve_with_backend drives one executor; use serve_sharded with {} backends",
            cfg.shards
        );
    }
    let (req_tx, req_rx) = channel::<(Request, Reply)>();
    let router = Router::new(vec![req_tx], &cfg);
    let cfg = &cfg;
    run_server(cfg, router, ready, move || {
        match Executor::new(manifest, backend, cfg, 0).run(req_rx) {
            Ok(replies) => (replies, Ok(())),
            Err(e) => (Vec::new(), Err(e)),
        }
    })
}

/// Run an N-shard server: one executor thread per backend factory,
/// each owning the backend its factory builds. `cfg.shards` is set to
/// the factory count. The listener binds (and `ready` fires) before
/// the factories run, so shard backends build/warm up concurrently
/// while the port is already open: requests arriving early queue on
/// their shard until it is ready (they are answered, not refused —
/// but a warmup longer than the connection's 60 s reply deadline
/// surfaces as per-request timeouts, unlike the single-shard path
/// which binds only after warmup). Sessions route by [`shard_for`]; the
/// global KV budget is partitioned across shards. If a factory fails,
/// its shard is dead (requests routed there get `shard_unavailable`)
/// but the other shards keep serving until shutdown, when the error is
/// returned (after acking the healthy shards' shutdown requesters).
pub fn serve_sharded<'a>(
    manifest: &Manifest,
    factories: Vec<BackendFactory<'a>>,
    mut cfg: ServerConfig,
    ready: Option<Sender<String>>,
) -> Result<()> {
    if factories.is_empty() {
        bail!("serve_sharded needs at least one backend factory");
    }
    cfg.shards = factories.len();
    let mut senders = Vec::with_capacity(cfg.shards);
    let mut work = Vec::with_capacity(cfg.shards);
    for (shard, factory) in factories.into_iter().enumerate() {
        let (tx, rx) = channel::<(Request, Reply)>();
        senders.push(tx);
        work.push((shard, factory, rx));
    }
    let router = Router::new(senders, &cfg);
    let cfg = &cfg;
    run_server(cfg, router, ready, move || {
        std::thread::scope(|s| {
            let handles: Vec<_> = work
                .into_iter()
                .map(|(shard, factory, rx)| {
                    s.spawn(move || -> Result<Vec<Reply>> {
                        let backend = factory()?;
                        Executor::new(manifest, backend, cfg, shard).run(rx)
                    })
                })
                .collect();
            let mut replies = Vec::new();
            let mut first_err = None;
            for h in handles {
                // lint: allow(unwrap) — a panicked executor shard is
                // unrecoverable; re-raise the panic on the shell.
                match h.join().expect("executor thread") {
                    Ok(mut r) => replies.append(&mut r),
                    Err(e) => first_err = first_err.or(Some(e)),
                }
            }
            // Replies from healthy shards are returned even when a
            // shard errored: their requesters still get the shutdown
            // ack once the port is released.
            (replies, first_err.map_or(Ok(()), Err))
        })
    })
}

/// Shared serving shell: bind the listener, start the selected
/// transport front-end (blocking reader threads or the polling
/// reactor), drive the executors via `run_executors` (which blocks
/// until every shard has drained and returns the drained shards'
/// shutdown repliers alongside the first shard error, if any), then
/// release the port, ack the shutdown requesters — even on a partial
/// failure — and propagate the error.
fn run_server(
    cfg: &ServerConfig,
    router: Router,
    ready: Option<Sender<String>>,
    run_executors: impl FnOnce() -> (Vec<Reply>, Result<()>),
) -> Result<()> {
    let (listeners, reactors) = bind_listeners(cfg)?;
    let local = listeners[0].local_addr()?.to_string();
    crate::info!(
        "serving on {local} ({} shard(s), eviction {}, reactor {}, {} reactor thread(s), {})",
        cfg.shards,
        cfg.eviction.name(),
        cfg.reactor.name(),
        reactors,
        if listeners.len() > 1 { "reuseport accept sharding" } else { "single listener" }
    );
    if let Some(tx) = ready {
        let _ = tx.send(local.clone());
    }
    match cfg.reactor {
        ReactorMode::Threads => {
            // lint: allow(unwrap) — bind_listeners returned Ok, which
            // guarantees at least one listener.
            let listener = listeners.into_iter().next().expect("one listener");
            run_server_threads(cfg, listener, router, run_executors)
        }
        ReactorMode::Epoll => run_server_reactor(cfg, listeners, reactors, router, run_executors),
    }
}

/// Bind the accept socket(s) for the selected front-end. Threads mode
/// and a single-reactor epoll front-end get one ordinary listener.
/// With `--reactors N > 1` each reactor gets its own `SO_REUSEPORT`
/// listener on the same address (the kernel hash-balances accepts
/// across them); where that fails — non-Linux, kernels without the
/// option, a non-literal address, or `force_accept_handoff` — the
/// shell degrades to ONE listener and reactor 0 hands accepted sockets
/// round-robin to its peers. Returns the nonblocking listeners (1 or
/// N) and the reactor count.
fn bind_listeners(cfg: &ServerConfig) -> Result<(Vec<TcpListener>, usize)> {
    let single = |why: Option<&str>| -> Result<Vec<TcpListener>> {
        if let Some(why) = why {
            crate::info!("serve: accept sharding disabled ({why}); single-listener handoff");
        }
        let l = TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
        l.set_nonblocking(true).context("listener nonblocking")?;
        Ok(vec![l])
    };
    let reactors = match cfg.reactor {
        ReactorMode::Epoll => cfg.reactors.max(1),
        ReactorMode::Threads => 1,
    };
    if reactors == 1 {
        return Ok((single(None)?, reactors));
    }
    if cfg.force_accept_handoff {
        return Ok((single(Some("accept handoff forced"))?, reactors));
    }
    let addr: std::net::SocketAddr = match cfg.addr.parse() {
        Ok(a) => a,
        Err(_) => return Ok((single(Some("address is not a literal socket address"))?, reactors)),
    };
    let first = match poll::bind_reuseport(addr) {
        Ok(l) => l,
        Err(e) => {
            return Ok((single(Some(&format!("SO_REUSEPORT unavailable: {e:#}")))?, reactors));
        }
    };
    // Re-bind the RESOLVED address so `:0` requests land every reactor
    // on the same ephemeral port.
    let bound = first.local_addr()?;
    let mut listeners = vec![first];
    for _ in 1..reactors {
        match poll::bind_reuseport(bound) {
            Ok(l) => listeners.push(l),
            Err(e) => {
                // Release the already-bound group before the plain
                // re-bind (a fixed port would otherwise collide).
                drop(listeners);
                return Ok((single(Some(&format!("SO_REUSEPORT re-bind: {e:#}")))?, reactors));
            }
        }
    }
    for l in &listeners {
        l.set_nonblocking(true).context("listener nonblocking")?;
    }
    Ok((listeners, reactors))
}

/// Threads front-end: an acceptor thread polling the nonblocking
/// listener (so it can observe the stop flag), one blocking reader
/// thread per connection. The listener is dropped when the acceptor
/// exits, releasing the port before the shutdown acks go out.
fn run_server_threads(
    cfg: &ServerConfig,
    listener: TcpListener,
    router: Router,
    run_executors: impl FnOnce() -> (Vec<Reply>, Result<()>),
) -> Result<()> {
    let stop = Arc::new(AtomicBool::new(false));
    let max_conns = cfg.max_conns;
    let max_line_bytes = cfg.max_line_bytes;
    let reply_timeout = cfg.reply_timeout;

    let acceptor = {
        let stop = stop.clone();
        let live = Arc::new(AtomicUsize::new(0));
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if live.load(Ordering::SeqCst) >= max_conns {
                            let mut stream = stream;
                            let refusal = format!("{TOO_MANY_CONNS_REPLY}\n");
                            let _ = stream.write_all(refusal.as_bytes());
                            continue; // dropped => closed
                        }
                        let _ = stream.set_nonblocking(false);
                        let router = router.clone();
                        live.fetch_add(1, Ordering::SeqCst);
                        let live = live.clone();
                        std::thread::spawn(move || {
                            let _ =
                                handle_connection(stream, router, max_line_bytes, reply_timeout);
                            live.fetch_sub(1, Ordering::SeqCst);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => {
                        crate::debug!("accept error: {e}");
                        std::thread::sleep(Duration::from_millis(2));
                    }
                }
            }
        })
    };

    let (shutdown_replies, result) = run_executors();
    // Signal the acceptor and join it so the port is actually released
    // before `serve` returns (the seed leaked both thread and port).
    stop.store(true, Ordering::SeqCst);
    let _ = acceptor.join();
    // Only now — listener dropped, port free — ack the shutdown
    // requesters: the ack's documented meaning is "port released".
    for reply in shutdown_replies {
        let _ = reply.send(SHUTDOWN_ACK.into());
    }
    result
}

/// Reactor front-end: every connection lives on exactly one of N
/// reactor threads; executors deliver replies through the owning
/// reactor's eventfd-signalled completion queue. With multiple
/// listeners (SO_REUSEPORT) each reactor accepts for itself; with one
/// listener reactor 0 hands accepted sockets round-robin to peer
/// inboxes. Shutdown is a staged per-reactor handshake so the ack
/// keeps its documented meaning across reactors: EVERY reactor closes
/// its listener first (port fully released), then the acks are pushed,
/// then all reactors flush-and-exit.
fn run_server_reactor(
    cfg: &ServerConfig,
    listeners: Vec<TcpListener>,
    reactors: usize,
    router: Router,
    run_executors: impl FnOnce() -> (Vec<Reply>, Result<()>),
) -> Result<()> {
    let sharded_accept = listeners.len() > 1;
    let stats = router.reactor_stats();
    debug_assert_eq!(stats.len(), reactors, "router and shell must agree on reactor count");
    let conn_count = Arc::new(AtomicUsize::new(0));
    let mut pollers = Vec::with_capacity(reactors);
    for _ in 0..reactors {
        pollers.push(poll::Poller::new().context("reactor poller")?);
    }
    let wakers: Vec<poll::Waker> = pollers.iter().map(|p| p.waker()).collect();
    let completions: Vec<Arc<reactor::CompletionQueue>> =
        wakers.iter().map(|w| Arc::new(reactor::CompletionQueue::new(w.clone()))).collect();
    let ctls: Vec<Arc<reactor::Ctl>> =
        (0..reactors).map(|_| Arc::new(reactor::Ctl::default())).collect();
    let inboxes: Vec<Arc<Mutex<Vec<TcpStream>>>> =
        (0..reactors).map(|_| Arc::new(Mutex::new(Vec::new()))).collect();

    let mut listener_iter = listeners.into_iter();
    let mut threads = Vec::with_capacity(reactors);
    for (id, poller) in pollers.into_iter().enumerate() {
        let listener = if sharded_accept || id == 0 { listener_iter.next() } else { None };
        // In handoff mode reactor 0 round-robins accepts over every
        // reactor (itself included); peers are indexed by reactor id.
        let peers = if !sharded_accept && id == 0 && reactors > 1 {
            inboxes
                .iter()
                .zip(&wakers)
                .map(|(inbox, waker)| reactor::HandoffPeer {
                    inbox: inbox.clone(),
                    waker: waker.clone(),
                })
                .collect()
        } else {
            Vec::new()
        };
        let inbox =
            if !sharded_accept && reactors > 1 { Some(inboxes[id].clone()) } else { None };
        let setup = reactor::ReactorSetup {
            id,
            listener,
            inbox,
            peers,
            poller,
            completions: completions[id].clone(),
            ctl: ctls[id].clone(),
            conn_count: conn_count.clone(),
            stats: stats.clone(),
        };
        match reactor::Reactor::new(setup, router.clone(), cfg) {
            Ok(r) => threads.push(std::thread::spawn(move || r.run())),
            Err(e) => {
                // Tear down the reactors already spawned before
                // propagating: left alone they would park in
                // `poller.wait` forever, holding their listeners (and
                // the port) after serve() has returned the error.
                for (ctl, waker) in ctls.iter().zip(&wakers) {
                    ctl.advance(reactor::CTL_FINISH);
                    waker.wake();
                }
                for t in threads {
                    let _ = t.join();
                }
                return Err(e);
            }
        }
    }

    let (shutdown_replies, result) = run_executors();
    // Stage 1: every reactor drops its listener and confirms — ALL of
    // the port's listeners must be closed before ANY shutdown ack is
    // written, preserving the single-reactor ack contract (a dead
    // reactor times its wait out; the shell degrades instead of
    // hanging).
    for (ctl, waker) in ctls.iter().zip(&wakers) {
        ctl.advance(reactor::CTL_CLOSE_LISTENER);
        waker.wake();
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    for ctl in &ctls {
        let left = deadline.saturating_duration_since(Instant::now());
        ctl.wait_at_least(reactor::CTL_LISTENER_CLOSED, left);
    }
    // Stage 2: acks travel the normal completion path — each handle
    // pins the queue of the reactor owning its connection, so they
    // land on the right event loop without any routing step.
    for reply in shutdown_replies {
        let _ = reply.send(SHUTDOWN_ACK.into());
    }
    // Stage 3: flush buffered replies and exit, closing every conn.
    for (ctl, waker) in ctls.iter().zip(&wakers) {
        ctl.advance(reactor::CTL_FINISH);
        waker.wake();
    }
    for t in threads {
        let _ = t.join();
    }
    result
}

/// Outcome of reading one framed request line in threads mode.
enum ReadLine {
    Eof,
    /// Line exceeded the cap; it was consumed through its newline (or
    /// EOF) with memory bounded by the reader's internal buffer.
    Overlong,
    Line(String),
}

/// Read one newline-terminated line of at most `cap` bytes — the
/// threads-mode slow-loris guard (`BufRead::read_line` would buffer an
/// endless partial line without bound).
fn read_line_capped(reader: &mut impl BufRead, cap: usize) -> std::io::Result<ReadLine> {
    let mut buf = Vec::new();
    let mut overlong = false;
    loop {
        let (consumed, terminated) = {
            let chunk = reader.fill_buf()?;
            if chunk.is_empty() {
                // EOF; a partial trailing line cannot be answered.
                return Ok(if overlong { ReadLine::Overlong } else { ReadLine::Eof });
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if !overlong {
                        buf.extend_from_slice(&chunk[..pos]);
                    }
                    (pos + 1, true)
                }
                None => {
                    if !overlong {
                        buf.extend_from_slice(chunk);
                    }
                    (chunk.len(), false)
                }
            }
        };
        reader.consume(consumed);
        if !overlong && buf.len() > cap {
            overlong = true;
            buf = Vec::new();
        }
        if terminated {
            return Ok(if overlong {
                ReadLine::Overlong
            } else {
                ReadLine::Line(String::from_utf8_lossy(&buf).into_owned())
            });
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    router: Router,
    max_line_bytes: usize,
    reply_timeout: Duration,
) -> Result<()> {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    crate::debug!("connection from {peer}");
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    loop {
        let line = match read_line_capped(&mut reader, max_line_bytes)? {
            ReadLine::Eof => break,
            ReadLine::Overlong => {
                writer.write_all(format!("{LINE_TOO_LONG_REPLY}\n").as_bytes())?;
                continue;
            }
            ReadLine::Line(line) => line,
        };
        if line.trim().is_empty() {
            continue;
        }
        let (resp_tx, resp_rx) = channel::<String>();
        match Request::parse(line.trim()) {
            Ok(req) => {
                let shutdown = matches!(req, Request::Shutdown);
                if !router.dispatch(req, Reply::channel(resp_tx)) {
                    break; // executor gone
                }
                match resp_rx.recv_timeout(reply_timeout) {
                    Ok(resp) => {
                        writer.write_all(resp.as_bytes())?;
                        writer.write_all(b"\n")?;
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        // Answer instead of silently dropping the client.
                        writer.write_all(format!("{TIMEOUT_REPLY}\n").as_bytes())?;
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
                if shutdown {
                    break;
                }
            }
            Err(e) => {
                let msg = format!("{{\"ok\":false,\"error\":{}}}\n", escape(&e.to_string()));
                writer.write_all(msg.as_bytes())?;
            }
        }
    }
    Ok(())
}

/// Minimal blocking client for examples and tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn call(&mut self, request: &str) -> Result<Json> {
        self.writer.write_all(request.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            bail!("server closed connection");
        }
        Json::parse(line.trim())
    }

    pub fn add_context(&mut self, session: &str, tokens: &[i32]) -> Result<Json> {
        self.call(&format!(
            "{{\"op\":\"context\",\"session\":{},\"tokens\":{}}}",
            escape(session),
            fmt_tokens(tokens)
        ))
    }

    /// Admit a context chunk under an explicit compression tier. Only
    /// the chunk that CREATES the session pins the tier; on an existing
    /// session the field is ignored.
    pub fn add_context_tiered(
        &mut self,
        session: &str,
        tokens: &[i32],
        strategy: StrategyKind,
    ) -> Result<Json> {
        self.call(&format!(
            "{{\"op\":\"context\",\"session\":{},\"tokens\":{},\"strategy\":{}}}",
            escape(session),
            fmt_tokens(tokens),
            escape(strategy.name())
        ))
    }

    pub fn query(&mut self, session: &str, tokens: &[i32], topk: usize) -> Result<Vec<(i32, f32)>> {
        let resp = self.call(&format!(
            "{{\"op\":\"query\",\"session\":{},\"tokens\":{},\"topk\":{topk}}}",
            escape(session),
            fmt_tokens(tokens)
        ))?;
        let next = resp.get("next")?.arr()?;
        next.iter()
            .map(|p| {
                let pair = p.arr()?;
                // A null logprob means the logit was non-finite.
                let lp = match &pair[1] {
                    Json::Null => f32::NEG_INFINITY,
                    v => v.f64()? as f32,
                };
                Ok((pair[0].i64()? as i32, lp))
            })
            .collect()
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.call("{\"op\":\"stats\"}")
    }

    /// Stats including the per-session `sessions_detail` array (id,
    /// time step, kv_bytes, age/idle in ms; merged across shards).
    pub fn stats_detailed(&mut self) -> Result<Json> {
        self.call("{\"op\":\"stats\",\"detail\":true}")
    }

    /// Detailed stats with the `sessions_detail` view bounded to ids
    /// starting with `prefix` and at most `limit` rows (by id, after
    /// the cross-shard merge).
    pub fn stats_page(&mut self, prefix: &str, limit: usize) -> Result<Json> {
        self.call(&format!(
            "{{\"op\":\"stats\",\"detail\":true,\"prefix\":{},\"limit\":{limit}}}",
            escape(prefix)
        ))
    }

    /// Next `limit`-sized detail page strictly after the cursor id
    /// (pass the last id of the previous page; pages chain without
    /// re-sending earlier rows). `prefix` composes with the cursor.
    pub fn stats_page_after(
        &mut self,
        prefix: &str,
        after_id: &str,
        limit: usize,
    ) -> Result<Json> {
        self.call(&format!(
            "{{\"op\":\"stats\",\"detail\":true,\"prefix\":{},\"after_id\":{},\"limit\":{limit}}}",
            escape(prefix),
            escape(after_id)
        ))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        match self.call("{\"op\":\"shutdown\"}") {
            // The ack means "drained, listener closed"; an ok:false
            // reply (e.g. a connection-level timeout) is not success.
            Ok(resp) => {
                if resp.get("ok")? == &Json::Bool(true) {
                    Ok(())
                } else {
                    bail!("shutdown not confirmed: {resp}")
                }
            }
            Err(e) if e.to_string().contains("closed") => Ok(()),
            Err(e) => Err(e),
        }
    }
}

pub(crate) fn fmt_tokens(tokens: &[i32]) -> String {
    let inner: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
    format!("[{}]", inner.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_requests() {
        let r = Request::parse(r#"{"op":"context","session":"u1","tokens":[1,2,3]}"#).unwrap();
        match r {
            Request::Context { session, tokens, strategy } => {
                assert_eq!(session, "u1");
                assert_eq!(tokens, vec![1, 2, 3]);
                assert_eq!(strategy, None, "absent strategy means the server default");
            }
            _ => panic!("wrong kind"),
        }
        let r = Request::parse(
            r#"{"op":"context","session":"u1","tokens":[1],"strategy":"sliding-window"}"#,
        )
        .unwrap();
        match r {
            Request::Context { strategy, .. } => {
                assert_eq!(strategy, Some(StrategyKind::SlidingWindow));
            }
            _ => panic!("wrong kind"),
        }
        // A present-but-unknown tier is refused, not silently defaulted.
        assert!(Request::parse(r#"{"op":"context","session":"u","tokens":[],"strategy":"zip"}"#)
            .is_err());
        let r = Request::parse(r#"{"op":"query","session":"u","tokens":[9],"topk":2}"#).unwrap();
        matches!(r, Request::Query { topk: 2, .. }).then_some(()).unwrap();
        let r = Request::parse(r#"{"op":"stats"}"#).unwrap();
        assert!(
            matches!(r, Request::Stats(StatsQuery { detail: false, .. })),
            "detail is opt-in"
        );
        let r = Request::parse(r#"{"op":"stats","detail":true}"#).unwrap();
        assert!(matches!(r, Request::Stats(StatsQuery { detail: true, .. })));
        assert!(Request::parse(r#"{"op":"nope"}"#).is_err());
        assert!(Request::parse("garbage").is_err());
    }

    #[test]
    fn stats_request_parses_prefix_and_limit() {
        let r = Request::parse(r#"{"op":"stats","detail":true,"prefix":"u-","limit":10}"#).unwrap();
        match r {
            Request::Stats(q) => {
                assert!(q.detail);
                assert_eq!(q.prefix.as_deref(), Some("u-"));
                assert_eq!(q.limit, Some(10));
                assert!(q.after_id.is_none(), "cursor is opt-in");
                assert!(q.per_reactor.is_none(), "per_reactor is router-internal");
            }
            _ => panic!("wrong kind"),
        }
        let r = Request::parse(r#"{"op":"stats","detail":true,"after_id":"u-41","limit":5}"#)
            .unwrap();
        match r {
            Request::Stats(q) => assert_eq!(q.after_id.as_deref(), Some("u-41")),
            _ => panic!("wrong kind"),
        }
        // Absent or malformed knobs degrade to unbounded, not an error.
        let r = Request::parse(r#"{"op":"stats","limit":"many"}"#).unwrap();
        match r {
            Request::Stats(q) => {
                assert!(!q.detail && q.prefix.is_none() && q.limit.is_none());
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn reactor_count_resolution_is_bounded() {
        let auto = auto_reactors();
        assert!((1..=4).contains(&auto), "auto = min(4, cores), got {auto}");
        // Env-driven default parses to >= 1 whatever the environment
        // says (unset → 1; the CI matrix exports 1 or 4).
        assert!(reactors_from_env() >= 1);
    }

    #[test]
    fn request_session_is_the_routing_key() {
        let ctx = Request::Context { session: "u1".into(), tokens: vec![1], strategy: None };
        let q = Request::Query { session: "u2".into(), tokens: vec![2], topk: 1 };
        assert_eq!(ctx.session(), Some("u1"));
        assert_eq!(q.session(), Some("u2"));
        assert_eq!(Request::Stats(StatsQuery::default()).session(), None);
        assert_eq!(Request::Stats(StatsQuery::detailed()).session(), None);
        assert_eq!(Request::Shutdown.session(), None);
    }

    #[test]
    fn reactor_mode_parses_and_names() {
        assert_eq!(ReactorMode::parse("threads").unwrap(), ReactorMode::Threads);
        assert_eq!(ReactorMode::parse("epoll").unwrap(), ReactorMode::Epoll);
        assert!(ReactorMode::parse("auto").is_err(), "auto is resolved by the CLI, not here");
        assert!(ReactorMode::parse("uring").is_err());
        assert_eq!(ReactorMode::Threads.name(), "threads");
        assert_eq!(ReactorMode::Epoll.name(), "epoll");
    }

    #[test]
    fn read_line_capped_bounds_memory_and_resyncs() {
        use std::io::Cursor;
        let mut r = Cursor::new(b"short\nnext\n".to_vec());
        assert!(matches!(read_line_capped(&mut r, 64).unwrap(), ReadLine::Line(l) if l == "short"));
        assert!(matches!(read_line_capped(&mut r, 64).unwrap(), ReadLine::Line(l) if l == "next"));
        assert!(matches!(read_line_capped(&mut r, 64).unwrap(), ReadLine::Eof));

        // An overlong line is consumed through its newline and refused;
        // the framing resynchronises on the next line.
        let mut data = vec![b'y'; 5000];
        data.push(b'\n');
        data.extend_from_slice(b"ok\n");
        let mut r = Cursor::new(data);
        assert!(matches!(read_line_capped(&mut r, 1024).unwrap(), ReadLine::Overlong));
        assert!(matches!(read_line_capped(&mut r, 1024).unwrap(), ReadLine::Line(l) if l == "ok"));

        // Overlong with EOF instead of a newline still reports once.
        let mut r = Cursor::new(vec![b'z'; 5000]);
        assert!(matches!(read_line_capped(&mut r, 1024).unwrap(), ReadLine::Overlong));
        assert!(matches!(read_line_capped(&mut r, 1024).unwrap(), ReadLine::Eof));

        // A line of exactly the cap passes.
        let mut exact = vec![b'a'; 1024];
        exact.push(b'\n');
        let mut r = Cursor::new(exact);
        let line = match read_line_capped(&mut r, 1024).unwrap() {
            ReadLine::Line(line) => line,
            _ => panic!("exact-cap line must pass"),
        };
        assert_eq!(line.len(), 1024);
    }

    #[test]
    fn fmt_tokens_roundtrip() {
        let j = Json::parse(&fmt_tokens(&[1, -2, 30])).unwrap();
        assert_eq!(
            j.arr().unwrap().iter().map(|v| v.i64().unwrap()).collect::<Vec<_>>(),
            vec![1, -2, 30]
        );
    }
}
