//! JSON-lines TCP serving front-end.
//!
//! Connection threads parse newline-delimited JSON requests and forward
//! them over a channel to the single executor thread that owns the PJRT
//! runtime (XLA executables are not Sync; one executor per device is the
//! standard topology). The executor is a continuously-pumped pipeline:
//! each turn it (1) drains whatever requests are queued, (2) executes at
//! most one batch through the coordinator, and (3) delivers any finished
//! query results — so a fast query is never stuck behind another
//! session's full queue drain (no head-of-line blocking), and intake
//! keeps flowing while batches execute.
//!
//! ## Protocol (one JSON object per line)
//!
//! Requests:
//!   {"op":"context","session":"u1","tokens":[5,6,7]}
//!   {"op":"query","session":"u1","tokens":[9,2],"topk":5}
//!   {"op":"stats"}            {"op":"shutdown"}
//!
//! Responses:
//!   {"ok":true,"kind":"context","t":3,"kv_bytes":12288}
//!       `t` is the time step the chunk will land on: two chunks queued
//!       back-to-back for one session ack t+1 and t+2. `kv_bytes` is the
//!       session's compressed-KV size at ack time (pre-compression).
//!   {"ok":true,"kind":"query","next":[[tok,logprob],...]}
//!   {"ok":true,"kind":"stats",...}
//!       Numeric fields: sessions, kv_bytes, kv_budget_bytes (or null),
//!       pending (queued work items), waiting (queries in flight),
//!       requests, compressions, inferences, batches, rejected_overload,
//!       sessions_evicted, sessions_reaped, peak_kv_bytes; plus `report`
//!       (the human-readable metrics block, JSON-escaped).
//!   {"ok":true,"kind":"shutdown"}
//!       Sent after in-flight work has drained; the listener is closed
//!       and the acceptor thread joined before `serve` returns.
//!
//! Error responses (admission control and lifecycle):
//!   {"ok":false,"error":"overloaded","pending":N}
//!       The bounded pending queue (`max_pending`) is full. Back off and
//!       retry; the connection stays open.
//!   {"ok":false,"error":"shutting_down","pending":N}
//!       A shutdown is draining; no new work is admitted.
//!   {"ok":false,"error":"too_long","what":"chunk"|"input","got":N,"limit":N}
//!       Token list exceeds the artifact shape (chunk_max / input_max);
//!       validated at admission so it never fails a batch.
//!   {"ok":false,"error":"timeout"}
//!       The executor did not answer within the per-request deadline.
//!   {"ok":false,"error":"..."} for malformed requests.
//!
//! ## Memory governance
//!
//! With `kv_budget_bytes` set, the executor enforces a global
//! compressed-KV budget after every executed batch: oldest-created idle
//! sessions are evicted (their memory is dropped) until under budget.
//! Sessions with queued work are never evicted. With `session_ttl` set,
//! sessions idle longer than the TTL are reaped periodically. Both are
//! counted in `stats` (`sessions_evicted`, `sessions_reaped`). A later
//! request for an evicted session transparently starts a fresh session
//! (its compressed memory is gone — that is the cost of the budget).

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::compress::{Compute, Engine};
use crate::coordinator::batcher::WorkKind;
use crate::coordinator::session::SessionPolicy;
use crate::coordinator::Coordinator;
use crate::model::manifest::Manifest;
use crate::model::Checkpoint;
use crate::runtime::Runtime;
use crate::util::json::{escape, Json};

#[derive(Debug)]
pub enum Request {
    Context { session: String, tokens: Vec<i32> },
    Query { session: String, tokens: Vec<i32>, topk: usize },
    Stats,
    Shutdown,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line)?;
        let op = j.get("op")?.str()?.to_string();
        let tokens = || -> Result<Vec<i32>> {
            j.get("tokens")?.arr()?.iter().map(|t| Ok(t.i64()? as i32)).collect()
        };
        let session = || -> Result<String> { Ok(j.get("session")?.str()?.to_string()) };
        Ok(match op.as_str() {
            "context" => Request::Context { session: session()?, tokens: tokens()? },
            "query" => Request::Query {
                session: session()?,
                tokens: tokens()?,
                topk: j.opt("topk").and_then(|v| v.usize().ok()).unwrap_or(5),
            },
            "stats" => Request::Stats,
            "shutdown" => Request::Shutdown,
            _ => bail!("unknown op {op:?}"),
        })
    }
}

/// Serving configuration. `new` fills production-shaped defaults; set
/// the public fields to tune.
pub struct ServerConfig {
    pub addr: String,
    pub policy: SessionPolicy,
    /// Artifact batch width the coordinator packs towards.
    pub max_batch: usize,
    /// Dynamic-batching age trigger (how long a lone item waits).
    pub max_wait: Duration,
    /// Admission control: queued work items beyond this are refused
    /// with an `overloaded` reply instead of buffered without bound.
    pub max_pending: usize,
    /// Global compressed-KV budget across all sessions (bytes).
    pub kv_budget_bytes: Option<usize>,
    /// Idle-session TTL; idle sessions beyond it are reaped.
    pub session_ttl: Option<Duration>,
}

impl ServerConfig {
    pub fn new(addr: impl Into<String>, policy: SessionPolicy) -> ServerConfig {
        ServerConfig {
            addr: addr.into(),
            policy,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            max_pending: 256,
            kv_budget_bytes: None,
            session_ttl: None,
        }
    }
}

type Reply = Sender<String>;

/// Run the server until a shutdown request arrives, over the XLA engine.
/// `ready` receives the bound local address (tests bind port 0).
pub fn serve(
    rt: &Runtime,
    ck: &Checkpoint,
    cfg: ServerConfig,
    ready: Option<Sender<String>>,
) -> Result<()> {
    let engine = Engine::new(rt, ck, cfg.policy.comp_len)?;
    serve_with_backend(&rt.manifest, Box::new(engine), cfg, ready)
}

/// Run the server over any [`Compute`] backend (protocol tests and
/// host-only benches inject [`crate::compress::SimCompute`]).
pub fn serve_with_backend<'a>(
    manifest: &Manifest,
    backend: Box<dyn Compute + 'a>,
    cfg: ServerConfig,
    ready: Option<Sender<String>>,
) -> Result<()> {
    let policy = cfg.policy.clone();
    let mut coord =
        Coordinator::with_backend(manifest, backend, policy, cfg.max_batch, cfg.max_wait);
    coord.batcher.infer_priority = true; // queries are latency-sensitive

    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let local = listener.local_addr()?.to_string();
    crate::info!("serving on {local}");
    if let Some(tx) = ready {
        let _ = tx.send(local.clone());
    }

    let (req_tx, req_rx) = channel::<(Request, Reply)>();
    let stop = Arc::new(AtomicBool::new(false));

    // Acceptor thread: polls the nonblocking listener so it can observe
    // the stop flag; one reader thread per connection. The listener is
    // dropped when this thread exits, releasing the port.
    let acceptor = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let _ = stream.set_nonblocking(false);
                        let tx = req_tx.clone();
                        std::thread::spawn(move || {
                            let _ = handle_connection(stream, tx);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => {
                        crate::debug!("accept error: {e}");
                        std::thread::sleep(Duration::from_millis(2));
                    }
                }
            }
        })
    };

    let limits = (manifest.scenario.chunk_max, manifest.scenario.input_max);
    let result = executor_loop(coord, &cfg, limits, req_rx);
    // Signal the acceptor and join it so the port is actually released
    // before `serve` returns (the seed leaked both thread and port).
    stop.store(true, Ordering::SeqCst);
    let _ = acceptor.join();
    // Only now — listener dropped, port free — ack the shutdown
    // requesters: the ack's documented meaning is "port released".
    let shutdown_replies = result?;
    for reply in shutdown_replies {
        let _ = reply.send("{\"ok\":true,\"kind\":\"shutdown\"}".into());
    }
    Ok(())
}

fn handle_connection(stream: TcpStream, tx: Sender<(Request, Reply)>) -> Result<()> {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    crate::debug!("connection from {peer}");
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (resp_tx, resp_rx) = channel::<String>();
        match Request::parse(&line) {
            Ok(req) => {
                let shutdown = matches!(req, Request::Shutdown);
                if tx.send((req, resp_tx)).is_err() {
                    break; // executor gone
                }
                match resp_rx.recv_timeout(Duration::from_secs(60)) {
                    Ok(resp) => {
                        writer.write_all(resp.as_bytes())?;
                        writer.write_all(b"\n")?;
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        // Answer instead of silently dropping the client.
                        writer.write_all(b"{\"ok\":false,\"error\":\"timeout\"}\n")?;
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
                if shutdown {
                    break;
                }
            }
            Err(e) => {
                let msg = format!("{{\"ok\":false,\"error\":{}}}\n", escape(&e.to_string()));
                writer.write_all(msg.as_bytes())?;
            }
        }
    }
    Ok(())
}

/// A query whose batch has not executed yet.
struct WaitingQuery {
    seq: u64,
    reply: Reply,
    input_len: usize,
    topk: usize,
}

/// Executor state threaded through request admission.
struct ExecState {
    waiting: VecDeque<WaitingQuery>,
    draining: bool,
    /// Everyone who asked for shutdown; all are acked once drained.
    shutdown_replies: Vec<Reply>,
    /// Artifact shape limits (validated at admission so an oversized
    /// request is a per-request error, not a batch-execution failure).
    chunk_max: usize,
    input_max: usize,
}

/// Runs until shutdown; returns the repliers to ack once the caller
/// has released the listener.
fn executor_loop(
    mut coord: Coordinator,
    cfg: &ServerConfig,
    (chunk_max, input_max): (usize, usize),
    rx: Receiver<(Request, Reply)>,
) -> Result<Vec<Reply>> {
    let idle_wait = cfg.max_wait.max(Duration::from_millis(1));
    let intake_cap = (cfg.max_batch * 4).max(32);
    let mut st = ExecState {
        waiting: VecDeque::new(),
        draining: false,
        shutdown_replies: Vec::new(),
        chunk_max,
        input_max,
    };
    let mut disconnected = false;
    let mut last_reap = Instant::now();
    loop {
        // 1. Intake: drain queued requests without stalling the pump.
        let mut got = 0usize;
        while got < intake_cap {
            match rx.try_recv() {
                Ok((req, reply)) => {
                    admit(&mut coord, cfg, &mut st, req, reply);
                    got += 1;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }

        // 2. Execute at most one batch (force while draining so the tail
        //    flushes without waiting for age triggers), then immediately
        //    deliver whatever finished — queries never wait for an
        //    unrelated session's backlog to drain.
        // A batch-execution failure must not kill the server (it owns
        // every session's memory): fail exactly the queries whose batch
        // died, leave unrelated queued work alone, and keep serving.
        let n = match coord.pump(st.draining || disconnected) {
            Ok(n) => n,
            Err(e) => {
                crate::info!("batch execution failed: {e:#}");
                let msg = format!(
                    "{{\"ok\":false,\"error\":{}}}",
                    escape(&format!("execution failed: {e:#}"))
                );
                let failed = coord.take_failed();
                st.waiting.retain(|w| {
                    if failed.contains(&w.seq) {
                        let _ = w.reply.send(msg.clone());
                        false
                    } else {
                        true
                    }
                });
                0
            }
        };
        deliver_finished(&mut coord, &mut st.waiting);
        if st.waiting.is_empty() {
            // Any result with no waiting consumer is orphaned (its
            // query was failed on a batch error): free it.
            coord.clear_results();
        }
        if n > 0 {
            // KV only grows inside pump, so enforcing right after keeps
            // the server under budget at every observable point.
            if let Some(budget) = cfg.kv_budget_bytes {
                let evicted = coord.enforce_kv_budget(budget);
                if !evicted.is_empty() {
                    crate::debug!("kv budget {budget}: evicted {} sessions", evicted.len());
                }
            }
        }

        // 3. Idle-session reaping on a coarse timer.
        if let Some(ttl) = cfg.session_ttl {
            if last_reap.elapsed() >= Duration::from_millis(100) {
                last_reap = Instant::now();
                coord.reap_idle(ttl, Instant::now());
            }
        }

        // 4. Graceful shutdown once in-flight work is drained.
        if (st.draining || disconnected) && coord.pending() == 0 && st.waiting.is_empty() {
            crate::info!("shutdown: {}", coord.metrics.report());
            return Ok(std::mem::take(&mut st.shutdown_replies));
        }

        // 5. Nothing executed and nothing arrived: block for the next
        //    request. With queued-but-unripe work, wake within max_wait
        //    so the age trigger fires; fully idle, park long (a reap
        //    tick if a TTL is set, else effectively until woken) rather
        //    than spinning at millisecond cadence.
        if n == 0 && got == 0 && !disconnected {
            let fully_idle = coord.pending() == 0 && st.waiting.is_empty() && !st.draining;
            let wait = if !fully_idle {
                idle_wait
            } else if cfg.session_ttl.is_some() {
                Duration::from_millis(100)
            } else {
                Duration::from_secs(3600)
            };
            match rx.recv_timeout(wait) {
                Ok((req, reply)) => admit(&mut coord, cfg, &mut st, req, reply),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => disconnected = true,
            }
        }
    }
}

fn admit(
    coord: &mut Coordinator,
    cfg: &ServerConfig,
    st: &mut ExecState,
    req: Request,
    reply: Reply,
) {
    match req {
        Request::Context { session, tokens } => {
            if let Some(refusal) = refuse(coord, cfg, st) {
                let _ = reply.send(refusal);
                return;
            }
            if tokens.len() > st.chunk_max {
                let _ = reply.send(too_long("chunk", tokens.len(), st.chunk_max));
                return;
            }
            coord.add_context(&session, tokens);
            // Ack with the step the chunk will actually land on: t
            // advances once per queued chunk, so two chunks queued in
            // one window ack t+1 and t+2 (the seed acked t+1 twice).
            let queued = coord.batcher.queued_for(&session, WorkKind::Compress);
            let s = coord.sessions.get_or_create(&session);
            let msg = format!(
                "{{\"ok\":true,\"kind\":\"context\",\"t\":{},\"kv_bytes\":{}}}",
                s.t + queued,
                s.mem.kv_bytes()
            );
            let _ = reply.send(msg);
        }
        Request::Query { session, tokens, topk } => {
            if let Some(refusal) = refuse(coord, cfg, st) {
                let _ = reply.send(refusal);
                return;
            }
            if tokens.len() > st.input_max {
                let _ = reply.send(too_long("input", tokens.len(), st.input_max));
                return;
            }
            let input_len = tokens.len();
            let seq = coord.query(&session, tokens);
            st.waiting.push_back(WaitingQuery { seq, reply, input_len, topk });
        }
        Request::Stats => {
            let _ = reply.send(stats_json(coord, cfg, st.waiting.len()));
        }
        Request::Shutdown => {
            // Every shutdown requester is acked only once the drain
            // completes — the ack means "listener closed, port free".
            st.draining = true;
            st.shutdown_replies.push(reply);
        }
    }
}

/// `{"ok":false,"error":"too_long",...}` for oversized token lists.
fn too_long(what: &str, got: usize, limit: usize) -> String {
    format!(
        "{{\"ok\":false,\"error\":\"too_long\",\"what\":\"{what}\",\"got\":{got},\"limit\":{limit}}}"
    )
}

/// Admission control: refuse new work while draining or over the
/// pending bound. Returns the refusal response, if any.
fn refuse(coord: &mut Coordinator, cfg: &ServerConfig, st: &ExecState) -> Option<String> {
    if st.draining {
        return Some(format!(
            "{{\"ok\":false,\"error\":\"shutting_down\",\"pending\":{}}}",
            coord.pending()
        ));
    }
    if coord.pending() >= cfg.max_pending {
        coord.metrics.rejected_overload += 1;
        return Some(format!(
            "{{\"ok\":false,\"error\":\"overloaded\",\"pending\":{}}}",
            coord.pending()
        ));
    }
    None
}

fn deliver_finished(coord: &mut Coordinator, waiting: &mut VecDeque<WaitingQuery>) {
    waiting.retain(|w| {
        if let Some(logits) = coord.take_result(w.seq) {
            let msg = format_query_response(&logits, w.input_len, w.topk);
            let _ = w.reply.send(msg);
            false
        } else {
            true
        }
    });
}

fn stats_json(coord: &Coordinator, cfg: &ServerConfig, waiting: usize) -> String {
    let m = &coord.metrics;
    format!(
        "{{\"ok\":true,\"kind\":\"stats\",\"sessions\":{},\"kv_bytes\":{},\"kv_budget_bytes\":{},\
         \"pending\":{},\"waiting\":{},\"requests\":{},\"compressions\":{},\"inferences\":{},\
         \"batches\":{},\"rejected_overload\":{},\"sessions_evicted\":{},\"sessions_reaped\":{},\
         \"peak_kv_bytes\":{},\"report\":{}}}",
        coord.sessions.len(),
        coord.sessions.total_kv_bytes(),
        cfg.kv_budget_bytes.map_or_else(|| "null".to_string(), |b| b.to_string()),
        coord.pending(),
        waiting,
        m.requests,
        m.compressions,
        m.inferences,
        m.batches,
        m.rejected_overload,
        m.sessions_evicted,
        m.sessions_reaped,
        m.peak_kv_bytes,
        escape(&m.report()),
    )
}

/// Top-k next-token distribution at the last real input position.
/// Total order via `f32::total_cmp`: a NaN logit (a backend bug) must
/// degrade to a bad ranking, not a panicking comparator in the server.
fn format_query_response(logits: &crate::tensor::Tensor, input_len: usize, topk: usize) -> String {
    let row = logits.row(&[input_len.saturating_sub(1)]);
    // Normalize over the finite logits only: one NaN must not poison
    // the log-sum-exp (and thereby every logprob in the response).
    let finite = || row.iter().copied().filter(|x| x.is_finite());
    let mx = finite().fold(f32::NEG_INFINITY, f32::max);
    let lse: f32 = finite().map(|x| (x - mx).exp()).sum::<f32>().ln() + mx;
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
    let pairs: Vec<String> = idx
        .iter()
        .take(topk)
        .map(|&i| {
            let lp = row[i] - lse;
            // JSON has no NaN/Infinity literal; degrade to null.
            if lp.is_finite() {
                format!("[{},{:.4}]", i, lp)
            } else {
                format!("[{},null]", i)
            }
        })
        .collect();
    format!("{{\"ok\":true,\"kind\":\"query\",\"next\":[{}]}}", pairs.join(","))
}

/// Minimal blocking client for examples and tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn call(&mut self, request: &str) -> Result<Json> {
        self.writer.write_all(request.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            bail!("server closed connection");
        }
        Json::parse(line.trim())
    }

    pub fn add_context(&mut self, session: &str, tokens: &[i32]) -> Result<Json> {
        self.call(&format!(
            "{{\"op\":\"context\",\"session\":{},\"tokens\":{}}}",
            escape(session),
            fmt_tokens(tokens)
        ))
    }

    pub fn query(&mut self, session: &str, tokens: &[i32], topk: usize) -> Result<Vec<(i32, f32)>> {
        let resp = self.call(&format!(
            "{{\"op\":\"query\",\"session\":{},\"tokens\":{},\"topk\":{topk}}}",
            escape(session),
            fmt_tokens(tokens)
        ))?;
        let next = resp.get("next")?.arr()?;
        next.iter()
            .map(|p| {
                let pair = p.arr()?;
                // A null logprob means the logit was non-finite.
                let lp = match &pair[1] {
                    Json::Null => f32::NEG_INFINITY,
                    v => v.f64()? as f32,
                };
                Ok((pair[0].i64()? as i32, lp))
            })
            .collect()
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.call("{\"op\":\"stats\"}")
    }

    pub fn shutdown(&mut self) -> Result<()> {
        match self.call("{\"op\":\"shutdown\"}") {
            // The ack means "drained, listener closed"; an ok:false
            // reply (e.g. a connection-level timeout) is not success.
            Ok(resp) => {
                if resp.get("ok")? == &Json::Bool(true) {
                    Ok(())
                } else {
                    bail!("shutdown not confirmed: {resp}")
                }
            }
            Err(e) if e.to_string().contains("closed") => Ok(()),
            Err(e) => Err(e),
        }
    }
}

fn fmt_tokens(tokens: &[i32]) -> String {
    let inner: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
    format!("[{}]", inner.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SimCompute;

    fn toy_coordinator(max_batch: usize) -> Coordinator<'static> {
        let m = Manifest::toy();
        let sim = SimCompute::from_manifest(&m);
        Coordinator::with_backend(
            &m,
            Box::new(sim),
            SessionPolicy::concat(2),
            max_batch,
            Duration::ZERO,
        )
    }

    fn recv_json(rx: &std::sync::mpsc::Receiver<String>) -> Json {
        Json::parse(&rx.recv().expect("reply")).expect("valid JSON reply")
    }

    fn exec_state() -> ExecState {
        ExecState {
            waiting: VecDeque::new(),
            draining: false,
            shutdown_replies: Vec::new(),
            chunk_max: 8,
            input_max: 8,
        }
    }

    #[test]
    fn admission_acks_queued_steps_and_refuses_over_bound() {
        let mut coord = toy_coordinator(4);
        let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
        cfg.max_pending = 2;
        let mut st = exec_state();

        // Two chunks queued in one window ack t=1 and t=2 (seed bug:
        // both acked t=1).
        let (tx, rx) = channel();
        let ctx = |toks: Vec<i32>| Request::Context { session: "u".into(), tokens: toks };
        admit(&mut coord, &cfg, &mut st, ctx(vec![4, 5]), tx.clone());
        assert_eq!(recv_json(&rx).get("t").unwrap().i64().unwrap(), 1);
        admit(&mut coord, &cfg, &mut st, ctx(vec![6, 7]), tx.clone());
        assert_eq!(recv_json(&rx).get("t").unwrap().i64().unwrap(), 2);

        // The pending bound is hit: the third chunk is refused.
        admit(&mut coord, &cfg, &mut st, ctx(vec![8]), tx.clone());
        let refusal = recv_json(&rx);
        assert_eq!(refusal.get("ok").unwrap(), &Json::Bool(false));
        assert_eq!(refusal.get("error").unwrap().str().unwrap(), "overloaded");
        assert_eq!(refusal.get("pending").unwrap().usize().unwrap(), 2);
        assert_eq!(coord.metrics.rejected_overload, 1);

        // After executing, acks continue from the session's real step.
        coord.run_until_idle().unwrap();
        admit(&mut coord, &cfg, &mut st, ctx(vec![9]), tx.clone());
        assert_eq!(recv_json(&rx).get("t").unwrap().i64().unwrap(), 3);

        // Oversized requests are refused at admission, not detonated
        // inside a batch (which would take the whole server down).
        admit(&mut coord, &cfg, &mut st, ctx(vec![0; 9]), tx.clone());
        let refusal = recv_json(&rx);
        assert_eq!(refusal.get("error").unwrap().str().unwrap(), "too_long");
        assert_eq!(refusal.get("limit").unwrap().usize().unwrap(), 8);
        let query = Request::Query { session: "u".into(), tokens: vec![0; 9], topk: 1 };
        admit(&mut coord, &cfg, &mut st, query, tx.clone());
        assert_eq!(recv_json(&rx).get("error").unwrap().str().unwrap(), "too_long");
        assert!(st.waiting.is_empty(), "refused query must not wait for results");
        coord.run_until_idle().expect("no oversized item reached the backend");
    }

    #[test]
    fn admission_refuses_new_work_while_draining() {
        let mut coord = toy_coordinator(4);
        let cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
        let mut st = exec_state();
        let (tx, rx) = channel();
        admit(&mut coord, &cfg, &mut st, Request::Shutdown, tx.clone());
        assert!(st.draining && st.shutdown_replies.len() == 1);
        admit(
            &mut coord,
            &cfg,
            &mut st,
            Request::Query { session: "q".into(), tokens: vec![1], topk: 1 },
            tx.clone(),
        );
        let refusal = recv_json(&rx);
        assert_eq!(refusal.get("error").unwrap().str().unwrap(), "shutting_down");
        assert_eq!(coord.pending(), 0, "refused work must not be queued");
        // Stats are still served during the drain.
        admit(&mut coord, &cfg, &mut st, Request::Stats, tx.clone());
        let stats = recv_json(&rx);
        assert_eq!(stats.get("kind").unwrap().str().unwrap(), "stats");
        // A second shutdown during the drain is deferred too: the ack
        // contract is "drained, listener closed", so nobody is acked
        // until then.
        admit(&mut coord, &cfg, &mut st, Request::Shutdown, tx.clone());
        assert_eq!(st.shutdown_replies.len(), 2);
        assert!(
            rx.try_recv().is_err(),
            "no shutdown ack may be sent before the drain completes"
        );
    }

    #[test]
    fn stats_json_is_valid_and_structured() {
        let mut coord = toy_coordinator(4);
        let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(2));
        cfg.kv_budget_bytes = Some(1 << 20);
        coord.add_context("a", vec![1, 2]);
        coord.run_until_idle().unwrap();
        let s = stats_json(&coord, &cfg, 3);
        let j = Json::parse(&s).expect("stats must be valid JSON");
        assert_eq!(j.get("sessions").unwrap().usize().unwrap(), 1);
        assert_eq!(j.get("waiting").unwrap().usize().unwrap(), 3);
        assert_eq!(j.get("kv_budget_bytes").unwrap().usize().unwrap(), 1 << 20);
        assert!(j.get("kv_bytes").unwrap().usize().unwrap() > 0);
        // The multi-line report embeds as a proper JSON string (the
        // seed used {:?}, which can emit non-JSON escapes).
        assert!(j.get("report").unwrap().str().unwrap().contains("requests="));
    }

    #[test]
    fn parses_requests() {
        let r = Request::parse(r#"{"op":"context","session":"u1","tokens":[1,2,3]}"#).unwrap();
        match r {
            Request::Context { session, tokens } => {
                assert_eq!(session, "u1");
                assert_eq!(tokens, vec![1, 2, 3]);
            }
            _ => panic!("wrong kind"),
        }
        let r = Request::parse(r#"{"op":"query","session":"u","tokens":[9],"topk":2}"#).unwrap();
        matches!(r, Request::Query { topk: 2, .. }).then_some(()).unwrap();
        assert!(Request::parse(r#"{"op":"nope"}"#).is_err());
        assert!(Request::parse("garbage").is_err());
    }

    #[test]
    fn formats_query_response_as_valid_json() {
        let mut logits = crate::tensor::Tensor::zeros(&[4, 6]);
        logits.set(&[1, 3], 5.0);
        let s = format_query_response(&logits, 2, 3);
        let j = Json::parse(&s).unwrap();
        let next = j.get("next").unwrap().arr().unwrap();
        assert_eq!(next.len(), 3);
        assert_eq!(next[0].arr().unwrap()[0].i64().unwrap(), 3);
        // log-probs <= 0
        assert!(next[0].arr().unwrap()[1].f64().unwrap() <= 0.0);
    }

    #[test]
    fn query_response_survives_nan_logits() {
        // Regression: the seed used partial_cmp().unwrap(), which
        // panicked the executor on any NaN logit.
        let mut logits = crate::tensor::Tensor::zeros(&[2, 5]);
        logits.set(&[1, 2], f32::NAN);
        logits.set(&[1, 4], 3.0);
        let s = format_query_response(&logits, 2, 2);
        let j = Json::parse(&s).expect("still valid JSON");
        let next = j.get("next").unwrap().arr().unwrap();
        assert_eq!(next.len(), 2);
        // total_cmp ranks NaN above every real number (descending sort),
        // but the finite top token must still be present.
        let toks: Vec<i64> =
            next.iter().map(|p| p.arr().unwrap()[0].i64().unwrap()).collect();
        assert!(toks.contains(&4), "finite max must rank in top-2: {toks:?}");
        // The NaN entry degrades to null; finite entries keep real
        // logprobs (lse is computed over finite logits only).
        for p in next {
            let pair = p.arr().unwrap();
            match pair[0].i64().unwrap() {
                2 => assert_eq!(pair[1], Json::Null),
                _ => assert!(pair[1].f64().unwrap() <= 0.0),
            }
        }
    }

    #[test]
    fn fmt_tokens_roundtrip() {
        let j = Json::parse(&fmt_tokens(&[1, -2, 30])).unwrap();
        assert_eq!(
            j.arr().unwrap().iter().map(|v| v.i64().unwrap()).collect::<Vec<_>>(),
            vec![1, -2, 30]
        );
    }
}
