//! Newline-framed JSON IPC between the serving front-end and shard
//! worker processes (`ccm worker`), plus the front-end's per-worker
//! connection proxy.
//!
//! ## Framing
//!
//! One frame per line. Requests travel front-end → worker as the normal
//! protocol object with a pipelining `id` added:
//!
//! ```text
//! {"id":7,"op":"query","session":"u1","tokens":[9,2],"topk":5}
//! ```
//!
//! and replies travel back as an `{"id":N,"resp":...}` envelope whose
//! `resp` is the executor's reply object embedded verbatim:
//!
//! ```text
//! {"id":7,"resp":{"ok":true,"kind":"query","next":[[9,-0.1]]}}
//! ```
//!
//! Because every frame is newline-terminated and every embedded string
//! is JSON-escaped (`\n` never appears raw inside a frame), a torn read
//! can never desync the stream: [`FrameBuf`] reassembles lines from
//! arbitrarily split reads, an unparsable line is skipped (logged) and
//! framing resynchronises at the next newline, and an overlong line is
//! discarded through its terminator without buffering more than
//! [`IPC_MAX_FRAME`] bytes. Property tests below drive the codec
//! through split-at-every-byte feeds and garbage-prefix resync.
//!
//! ## The binary codec
//!
//! JSON is the fallback and the negotiation carrier; the hot path is a
//! length-prefixed binary codec selected per connection by a hello
//! handshake (see `server` module docs). A binary frame is
//!
//! ```text
//! 0xCC | payload_len: u32 LE | kind: u8 | fields...
//! ```
//!
//! with kinds context=1 / query=2 / stats=3 / shutdown=4 / reply=5,
//! all integers little-endian, strings as `u32 len + UTF-8 bytes`, and
//! token lists as `u32 count + i32 each` — a memcpy instead of a
//! per-token itoa/atoi. A reply frame carries the executor's reply
//! JSON verbatim as its string field, so the bytes the client sees
//! stay identical under both codecs. `0xCC` can never begin a JSON
//! line (`{` = 0x7B), so [`FrameBuf::next_frame`] tells the codecs
//! apart per frame from the first unconsumed byte and a connection can
//! carry both — which is exactly the state during negotiation (JSON
//! hello, JSON ack, then binary requests with late JSON replies still
//! in flight). Length-prefixed framing cannot resync from arbitrary
//! mid-stream corruption the way newline framing does; it is used only
//! between our own processes, where the prefix is trusted, and an
//! oversize declared length is skipped exactly rather than buffered.
//!
//! ## The proxy
//!
//! [`WorkerProxy`] is the front-end side of one worker connection: a
//! pipelined request-id map (dispatch never blocks the caller — frames
//! go to a writer thread through an unbounded queue, replies come back
//! on a reader thread that completes the pending entry), a per-worker
//! connection state machine (`Down` ⇄ `Up`; while `Down` every
//! session-routed request is refused with the documented
//! `shard_unavailable` reply instead of hanging), and shutdown-ack
//! interception (worker drain acks are stashed until the serve shell
//! has released the listener, preserving the "ack means port released"
//! contract across the process boundary). Reconnect-with-backoff and
//! process respawn live in the supervisor (`worker.rs`); the proxy only
//! tracks the current connection epoch so a stale reader from a
//! previous connection can never tear down its successor.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::compress::StrategyKind;
use crate::server::{fmt_tokens, IpcCodec, Reply, Request, StatsQuery, SHARD_UNAVAILABLE};
use crate::util::json::{escape, Json};

/// Upper bound on one IPC frame (a stats reply embedding a large
/// `sessions_detail` view is the biggest legitimate frame). Beyond it
/// the decoder discards through the next newline instead of buffering.
pub(crate) const IPC_MAX_FRAME: usize = 16 << 20;

/// First byte of a binary frame. A JSON frame's first byte is `{`
/// (0x7B), so the two codecs are distinguishable per frame.
pub(crate) const BIN_MAGIC: u8 = 0xCC;

/// Binary frame header size: the magic byte plus the `u32` payload
/// length.
const BIN_HEADER: usize = 5;

/// IPC protocol version carried by the hello handshake. Version 2
/// added the per-session compression-strategy byte on binary context
/// frames and the `after_id` stats cursor; both are encoded only when
/// the peer's hello ack reported version >= 2, so a v1 worker still
/// attaches and simply serves every session on the default tier (the
/// JSON codec needs no gating — unknown keys are ignored there).
pub(crate) const IPC_VERSION: u64 = 2;

/// Most frames a writer thread packs into one gathered `writev`
/// submission (matches `poll::WRITE_GATHER_MAX`, the Linux `IOV_MAX`).
pub(crate) const IPC_WRITE_BATCH: usize = 1024;

// ---------------------------------------------------------------------
// Incremental line framing.

/// One decoded frame from [`FrameBuf::next_frame`]: a JSON line
/// (without its newline) or a binary frame's payload, borrowed from
/// the buffer until the next `feed`.
pub(crate) enum Frame<'a> {
    Line(String),
    Bin(&'a [u8]),
}

/// Reassembles frames of BOTH codecs from arbitrarily split reads,
/// telling them apart by the first unconsumed byte ([`BIN_MAGIC`] vs.
/// anything else, which is treated as line mode). Overlong lines (no
/// newline within `max_line` buffered bytes) are dropped through their
/// terminator so a corrupt peer cannot pin memory; an oversize binary
/// payload is skipped exactly by its declared length. Framing advances
/// a cursor and compacts the consumed prefix once per `feed` — one IPC
/// socket multiplexes a whole shard's pipelined traffic, so a
/// per-frame front drain would memmove the remaining buffer per frame
/// and make bursts quadratic (the same fix the reactor's line framing
/// uses).
pub(crate) struct FrameBuf {
    buf: Vec<u8>,
    /// Start of the unconsumed region of `buf`.
    cursor: usize,
    max_line: usize,
    discarding: bool,
    /// Bytes of an oversize binary payload still to be skipped.
    bin_skip: usize,
}

impl FrameBuf {
    pub(crate) fn new(max_line: usize) -> FrameBuf {
        FrameBuf { buf: Vec::new(), cursor: 0, max_line, discarding: false, bin_skip: 0 }
    }

    pub(crate) fn feed(&mut self, bytes: &[u8]) {
        if self.cursor > 0 {
            // One compaction for everything consumed since the last
            // feed (amortized O(1) per byte).
            self.buf.drain(..self.cursor);
            self.cursor = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame of either codec, or `None` when no
    /// complete frame is buffered yet.
    pub(crate) fn next_frame(&mut self) -> Option<Frame<'_>> {
        loop {
            // Finish skipping an oversize binary payload first.
            if self.bin_skip > 0 {
                let take = self.bin_skip.min(self.buf.len() - self.cursor);
                self.cursor += take;
                self.bin_skip -= take;
                if self.bin_skip > 0 {
                    return None;
                }
                continue;
            }
            let avail = self.buf.len() - self.cursor;
            if avail == 0 {
                return None;
            }
            if !self.discarding && self.buf[self.cursor] == BIN_MAGIC {
                if avail < BIN_HEADER {
                    return None;
                }
                let h = self.cursor;
                let len = u32::from_le_bytes([
                    self.buf[h + 1],
                    self.buf[h + 2],
                    self.buf[h + 3],
                    self.buf[h + 4],
                ]) as usize;
                if len > self.max_line {
                    // Oversize declared length: consume the header and
                    // skip the payload exactly, never buffering it.
                    self.cursor += BIN_HEADER;
                    self.bin_skip = len;
                    continue;
                }
                if avail < BIN_HEADER + len {
                    return None;
                }
                let start = self.cursor + BIN_HEADER;
                self.cursor = start + len;
                return Some(Frame::Bin(&self.buf[start..start + len]));
            }
            let rel = self.buf[self.cursor..].iter().position(|&b| b == b'\n');
            let Some(rel) = rel else {
                if avail > self.max_line {
                    // Cap enforcement: drop the partial line, resume at
                    // the next newline.
                    self.buf.clear();
                    self.cursor = 0;
                    self.discarding = true;
                }
                return None;
            };
            let (start, end) = (self.cursor, self.cursor + rel);
            self.cursor = end + 1;
            if self.discarding {
                self.discarding = false;
                continue;
            }
            if end - start > self.max_line {
                continue; // overlong but terminated: skip it whole
            }
            return Some(Frame::Line(String::from_utf8_lossy(&self.buf[start..end]).into_owned()));
        }
    }

    /// Pop the next complete line (without its newline), or `None` when
    /// no complete line is buffered yet. The line-only view for streams
    /// known to speak JSON; binary frames arriving here are skipped.
    pub(crate) fn next_line(&mut self) -> Option<String> {
        loop {
            match self.next_frame() {
                None => return None,
                Some(Frame::Line(line)) => return Some(line),
                Some(Frame::Bin(_)) => continue,
            }
        }
    }
}

// ---------------------------------------------------------------------
// Frame codec.

/// Encode one request frame (newline included). `Stats.per_reactor` is
/// router-internal plumbing and never crosses the IPC boundary: the
/// front-end renders transport rows itself in the merged view.
pub(crate) fn encode_request(id: u64, req: &Request) -> String {
    match req {
        Request::Context { session, tokens, strategy } => {
            let strategy = match strategy {
                Some(k) => format!(",\"strategy\":\"{}\"", k.name()),
                None => String::new(),
            };
            format!(
                "{{\"id\":{id},\"op\":\"context\",\"session\":{},\"tokens\":{}{strategy}}}\n",
                escape(session),
                fmt_tokens(tokens)
            )
        }
        Request::Query { session, tokens, topk } => format!(
            "{{\"id\":{id},\"op\":\"query\",\"session\":{},\"tokens\":{},\"topk\":{topk}}}\n",
            escape(session),
            fmt_tokens(tokens)
        ),
        Request::Stats(q) => {
            let mut s = format!("{{\"id\":{id},\"op\":\"stats\",\"detail\":{}", q.detail);
            if let Some(prefix) = &q.prefix {
                s.push_str(&format!(",\"prefix\":{}", escape(prefix)));
            }
            if let Some(after) = &q.after_id {
                s.push_str(&format!(",\"after_id\":{}", escape(after)));
            }
            if let Some(limit) = q.limit {
                s.push_str(&format!(",\"limit\":{limit}"));
            }
            s.push_str("}\n");
            s
        }
        Request::Shutdown => format!("{{\"id\":{id},\"op\":\"shutdown\"}}\n"),
    }
}

/// Decode a request frame into its pipelining id and the request.
pub(crate) fn decode_request(line: &str) -> Result<(u64, Request)> {
    let j = Json::parse(line).context("request frame")?;
    let id = frame_id_of(&j)?;
    let req = Request::from_json(&j).context("request frame body")?;
    Ok((id, req))
}

/// Encode one reply frame. `resp` must be a complete JSON object (every
/// executor reply is); it is embedded verbatim so the bytes the client
/// sees are exactly what the worker's executor produced.
pub(crate) fn encode_reply(id: u64, resp: &str) -> String {
    format!("{{\"id\":{id},\"resp\":{resp}}}\n")
}

/// Decode a reply frame to `(id, resp)`. The envelope layout is fixed
/// (`{"id":N,"resp":...}`, produced only by [`encode_reply`]), so the
/// reply body can be recovered verbatim — no re-rendering — while the
/// embedded-JSON validation still rejects torn or corrupt frames.
pub(crate) fn decode_reply(line: &str) -> Result<(u64, String)> {
    let rest = line.strip_prefix("{\"id\":").ok_or_else(|| anyhow!("not a reply frame"))?;
    let digits = rest.bytes().take_while(|b| b.is_ascii_digit()).count();
    if digits == 0 {
        bail!("reply frame missing id");
    }
    let id: u64 = rest[..digits].parse().context("reply frame id")?;
    let body = rest[digits..]
        .strip_prefix(",\"resp\":")
        .and_then(|b| b.strip_suffix('}'))
        .ok_or_else(|| anyhow!("malformed reply envelope"))?;
    Json::parse(body).context("reply frame body")?;
    Ok((id, body.to_string()))
}

/// Best-effort id extraction from a frame that failed to decode as a
/// request, so the worker can still answer a malformed body instead of
/// dropping it silently (id-less garbage is skipped: resync).
pub(crate) fn frame_id(line: &str) -> Option<u64> {
    let j = Json::parse(line).ok()?;
    frame_id_of(&j).ok()
}

fn frame_id_of(j: &Json) -> Result<u64> {
    let id = j.get("id")?.i64()?;
    if id < 0 {
        bail!("negative frame id {id}");
    }
    Ok(id as u64)
}

/// One decoded line-mode frame on the worker side: either the codec
/// hello (handled at the IPC layer, never forwarded to the executor)
/// or a normal request. One JSON parse covers both.
pub(crate) enum LineFrame {
    Hello { id: u64, codec: IpcCodec },
    Req(u64, Request),
}

/// Decode a line-mode frame, intercepting the hello before the request
/// grammar sees it (`hello` is not a client op; `Request::from_json`
/// would reject it — which is precisely what makes pre-codec workers
/// answer a hello with an error and negotiate the connection down).
pub(crate) fn decode_line(line: &str) -> Result<LineFrame> {
    let j = Json::parse(line).context("request frame")?;
    let id = frame_id_of(&j)?;
    if j.opt("op").and_then(|v| v.str().ok()) == Some("hello") {
        let codec = match j.opt("codec").and_then(|v| v.str().ok()) {
            Some("binary") => IpcCodec::Binary,
            _ => IpcCodec::Json,
        };
        return Ok(LineFrame::Hello { id, codec });
    }
    let req = Request::from_json(&j).context("request frame body")?;
    Ok(LineFrame::Req(id, req))
}

/// The proxy's opening frame on a fresh connection (newline included):
/// always JSON, because the peer's codec support is unknown until it
/// answers.
pub(crate) fn encode_hello(id: u64, codec: IpcCodec) -> String {
    let codec = codec.name();
    format!("{{\"id\":{id},\"op\":\"hello\",\"codec\":\"{codec}\",\"version\":{IPC_VERSION}}}\n")
}

/// The worker's hello reply body, reporting the codec it granted.
pub(crate) fn hello_ack(granted: IpcCodec) -> String {
    let codec = granted.name();
    format!("{{\"ok\":true,\"kind\":\"hello\",\"codec\":\"{codec}\",\"version\":{IPC_VERSION}}}")
}

/// Whether a hello reply grants the binary codec. An error reply (a
/// pre-codec worker's "unknown op", or an explicit refusal) reads as
/// `false`: the connection stays on JSON.
pub(crate) fn hello_grants_binary(resp: &str) -> bool {
    match Json::parse(resp) {
        Ok(j) => {
            j.opt("ok") == Some(&Json::Bool(true))
                && j.opt("codec").and_then(|v| v.str().ok()) == Some("binary")
        }
        Err(_) => false,
    }
}

/// The protocol version a hello reply reports. Absent or unparsable
/// reads as 1 — the pre-versioned wire, which never carries the v2
/// fields — so a peer that predates the field negotiates down safely.
pub(crate) fn hello_peer_version(resp: &str) -> u64 {
    match Json::parse(resp) {
        Ok(j) => j.opt("version").and_then(|v| v.i64().ok()).filter(|&v| v >= 1).unwrap_or(1)
            as u64,
        Err(_) => 1,
    }
}

// ---------------------------------------------------------------------
// Binary frame codec (layout in the module docs).

const BIN_REQ_CONTEXT: u8 = 1;
const BIN_REQ_QUERY: u8 = 2;
const BIN_REQ_STATS: u8 = 3;
const BIN_REQ_SHUTDOWN: u8 = 4;
const BIN_REPLY: u8 = 5;

const STATS_DETAIL: u8 = 1;
const STATS_HAS_PREFIX: u8 = 2;
const STATS_HAS_LIMIT: u8 = 4;
/// v2: the stats frame carries an `after_id` cursor string (between
/// the prefix and the limit).
const STATS_HAS_AFTER: u8 = 8;

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn put_tokens(out: &mut Vec<u8>, tokens: &[i32]) {
    out.extend_from_slice(&(tokens.len() as u32).to_le_bytes());
    for t in tokens {
        out.extend_from_slice(&t.to_le_bytes());
    }
}

/// Start a binary frame in `out` (cleared), leaving the length field
/// zero until [`finish_frame`] patches it.
fn start_frame(out: &mut Vec<u8>, kind: u8, id: u64) {
    out.clear();
    out.extend_from_slice(&[BIN_MAGIC, 0, 0, 0, 0, kind]);
    put_u64(out, id);
}

fn finish_frame(out: &mut Vec<u8>) {
    let len = (out.len() - BIN_HEADER) as u32;
    out[1..BIN_HEADER].copy_from_slice(&len.to_le_bytes());
}

/// Encode one request as a binary frame into `out` (reused buffer).
/// Same contract as [`encode_request`]: `Stats.per_reactor` never
/// crosses the IPC boundary. `peer_version` is the version the peer's
/// hello ack reported: the v2 fields (context strategy byte, stats
/// `after_id`) are encoded only when the peer understands them, so a
/// v1 worker's exact-length decoder never sees trailing bytes — the
/// fields are dropped and the worker serves the default tier.
pub(crate) fn encode_request_bin(id: u64, req: &Request, peer_version: u64, out: &mut Vec<u8>) {
    match req {
        Request::Context { session, tokens, strategy } => {
            start_frame(out, BIN_REQ_CONTEXT, id);
            put_str(out, session);
            put_tokens(out, tokens);
            if peer_version >= 2 {
                // One trailing byte: 0 = no explicit tier requested,
                // else `StrategyKind::wire()`.
                out.push(strategy.map_or(0, |k| k.wire()));
            }
        }
        Request::Query { session, tokens, topk } => {
            start_frame(out, BIN_REQ_QUERY, id);
            put_str(out, session);
            put_tokens(out, tokens);
            put_u64(out, *topk as u64);
        }
        Request::Stats(q) => {
            start_frame(out, BIN_REQ_STATS, id);
            let mut flags = 0u8;
            if q.detail {
                flags |= STATS_DETAIL;
            }
            if q.prefix.is_some() {
                flags |= STATS_HAS_PREFIX;
            }
            if q.limit.is_some() {
                flags |= STATS_HAS_LIMIT;
            }
            if q.after_id.is_some() && peer_version >= 2 {
                flags |= STATS_HAS_AFTER;
            }
            out.push(flags);
            if let Some(prefix) = &q.prefix {
                put_str(out, prefix);
            }
            if flags & STATS_HAS_AFTER != 0 {
                // lint: allow(unwrap) — the flag is set only when
                // `after_id` is Some, two lines up.
                put_str(out, q.after_id.as_deref().expect("flag implies cursor"));
            }
            if let Some(limit) = q.limit {
                put_u64(out, limit as u64);
            }
        }
        Request::Shutdown => start_frame(out, BIN_REQ_SHUTDOWN, id),
    }
    finish_frame(out);
}

/// Encode one reply as a binary frame into `out` (reused buffer). The
/// executor's reply JSON is carried verbatim as the string field — no
/// envelope rendering, no escaping pass, no newline scan.
pub(crate) fn encode_reply_bin(id: u64, resp: &str, out: &mut Vec<u8>) {
    start_frame(out, BIN_REPLY, id);
    put_str(out, resp);
    finish_frame(out);
}

/// Bounds-checked cursor over one binary payload.
struct BinReader<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> BinReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.at.checked_add(n).filter(|&e| e <= self.b.len());
        let Some(end) = end else { bail!("binary frame truncated") };
        let s = &self.b[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8_lossy(self.take(n)?).into_owned())
    }

    fn tokens(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| anyhow!("token count overflow"))?)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn done(&self) -> Result<()> {
        if self.at != self.b.len() {
            bail!("{} trailing bytes in binary frame", self.b.len() - self.at);
        }
        Ok(())
    }
}

/// Decode a binary request payload into its pipelining id and request.
pub(crate) fn decode_request_bin(payload: &[u8]) -> Result<(u64, Request)> {
    let mut r = BinReader { b: payload, at: 0 };
    let kind = r.u8().context("binary request frame")?;
    let id = r.u64()?;
    let req = match kind {
        BIN_REQ_CONTEXT => {
            let session = r.str()?;
            let tokens = r.tokens()?;
            // v2 appends one strategy byte; a v1 front-end sends none.
            // Tolerating both lets any version pair interoperate.
            let strategy = if r.at < r.b.len() {
                match r.u8()? {
                    0 => None,
                    b => Some(StrategyKind::from_wire(b)?),
                }
            } else {
                None
            };
            Request::Context { session, tokens, strategy }
        }
        BIN_REQ_QUERY => Request::Query {
            session: r.str()?,
            tokens: r.tokens()?,
            topk: r.u64()? as usize,
        },
        BIN_REQ_STATS => {
            let flags = r.u8()?;
            let prefix = if flags & STATS_HAS_PREFIX != 0 { Some(r.str()?) } else { None };
            let after_id = if flags & STATS_HAS_AFTER != 0 { Some(r.str()?) } else { None };
            let limit = if flags & STATS_HAS_LIMIT != 0 { Some(r.u64()? as usize) } else { None };
            Request::Stats(StatsQuery {
                detail: flags & STATS_DETAIL != 0,
                prefix,
                after_id,
                limit,
                per_reactor: None,
            })
        }
        BIN_REQ_SHUTDOWN => Request::Shutdown,
        other => bail!("unknown binary request kind {other}"),
    };
    r.done()?;
    Ok((id, req))
}

/// Decode a binary reply payload to `(id, resp)`. The reply body was
/// carried verbatim, and the length prefix already framed it exactly,
/// so no embedded-JSON validation pass is needed (the newline codec
/// validates to reject torn frames; binary frames cannot tear).
pub(crate) fn decode_reply_bin(payload: &[u8]) -> Result<(u64, String)> {
    let mut r = BinReader { b: payload, at: 0 };
    let kind = r.u8().context("binary reply frame")?;
    if kind != BIN_REPLY {
        bail!("binary frame kind {kind} is not a reply");
    }
    let id = r.u64()?;
    let resp = r.str()?;
    r.done()?;
    Ok((id, resp))
}

// ---------------------------------------------------------------------
// Pooled encode buffers.

/// Reusable frame-encode buffers, recycled between dispatchers and the
/// writer thread so a steady pipelined load stops allocating per
/// frame. Bounded: at most [`BufPool::MAX_POOLED`] buffers are
/// retained and oversized ones (a giant stats frame) are dropped
/// rather than pinned.
pub(crate) struct BufPool {
    free: Mutex<Vec<Vec<u8>>>,
}

impl BufPool {
    const MAX_POOLED: usize = 256;
    const MAX_POOLED_CAPACITY: usize = 64 * 1024;

    pub(crate) fn new() -> BufPool {
        BufPool { free: Mutex::new(Vec::new()) }
    }

    pub(crate) fn take(&self) -> Vec<u8> {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a batch of written buffers to the pool.
    pub(crate) fn put_all(&self, bufs: &mut Vec<Vec<u8>>) {
        let mut free = self.free.lock().unwrap();
        for mut b in bufs.drain(..) {
            if free.len() < Self::MAX_POOLED && b.capacity() <= Self::MAX_POOLED_CAPACITY {
                b.clear();
                free.push(b);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Worker-side reply handle.

/// The worker-process [`Reply`]: tags the executor's reply with the
/// request's pipelining id and the codec its request arrived in (the
/// worker mirrors per frame), and hands it to the connection's writer
/// thread, which frames it onto the IPC socket.
#[derive(Clone)]
pub(crate) struct IpcReplyHandle {
    pub(crate) id: u64,
    /// Reply in the binary codec (the request was a binary frame).
    pub(crate) bin: bool,
    pub(crate) out: Sender<(u64, String, bool)>,
}

impl IpcReplyHandle {
    pub(crate) fn send(&self, msg: String) -> std::result::Result<(), ()> {
        self.out.send((self.id, msg, self.bin)).map_err(|_| ())
    }
}

// ---------------------------------------------------------------------
// Per-worker stats (the merged view's `per_worker` rows).

/// Sliding window of recent IPC round-trip samples (microseconds) for
/// the percentile columns in `per_worker` stats — the observable the
/// bench trajectory (`BENCH_<n>.json`) records. Bounded: once full,
/// new samples overwrite the oldest.
#[derive(Default)]
pub(crate) struct RttWindow {
    samples: Vec<u64>,
    at: usize,
}

/// Capacity of [`RttWindow`].
const RTT_WINDOW: usize = 4096;

impl RttWindow {
    fn push(&mut self, micros: u64) {
        if self.samples.len() < RTT_WINDOW {
            self.samples.push(micros);
        } else {
            self.samples[self.at] = micros;
            self.at = (self.at + 1) % RTT_WINDOW;
        }
    }

    /// `(p50, p99)` in microseconds, `None` before the first sample.
    fn percentiles(&self) -> Option<(u64, u64)> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let pick = |q: usize| sorted[(sorted.len() - 1) * q / 100];
        Some((pick(50), pick(99)))
    }
}

/// Live per-worker supervision counters. The supervisor writes `pid`
/// and `restarts`, the proxy writes `up` and the RTT fields, the
/// router renders them into stats.
#[derive(Default)]
pub(crate) struct WorkerSlot {
    /// Live worker process id; 0 while no process is running.
    pub(crate) pid: AtomicU64,
    /// Times the supervisor respawned this worker after an unexpected
    /// exit (the `shard_restarts` counter).
    pub(crate) restarts: AtomicUsize,
    /// Most recent request→reply round trip over the IPC socket, in
    /// microseconds (clamped to >= 1); 0 until the first reply.
    pub(crate) rtt_micros: AtomicU64,
    /// Recent round-trip samples for the p50/p99 stats columns.
    pub(crate) rtt_window: Mutex<RttWindow>,
    /// The proxy currently holds a live connection to this worker.
    pub(crate) up: AtomicBool,
}

/// One slot per worker shard; absent entirely for in-process shards.
pub(crate) struct WorkerStatsTable {
    slots: Vec<WorkerSlot>,
}

impl WorkerStatsTable {
    pub(crate) fn new(workers: usize) -> WorkerStatsTable {
        WorkerStatsTable { slots: (0..workers).map(|_| WorkerSlot::default()).collect() }
    }

    pub(crate) fn count(&self) -> usize {
        self.slots.len()
    }

    pub(crate) fn slot(&self, worker: usize) -> &WorkerSlot {
        &self.slots[worker]
    }

    pub(crate) fn total_restarts(&self) -> usize {
        // ordering: monitoring sum; slots may tick mid-scan and an
        // approximate total is fine.
        self.slots.iter().map(|s| s.restarts.load(Ordering::Relaxed)).sum()
    }

    /// Comma-joined JSON rows (the caller wraps them in
    /// `"per_worker":[...]`). `pid`/`rtt_ms` and the RTT percentile
    /// columns are `null` while the worker is down / before its first
    /// reply.
    pub(crate) fn render_rows(&self) -> String {
        let rows: Vec<String> = self
            .slots
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let pid = match s.pid.load(Ordering::Relaxed) { // ordering: stats snapshot
                    0 => "null".to_string(),
                    p => p.to_string(),
                };
                let ms = |us: u64| format!("{:.3}", us as f64 / 1e3);
                let rtt = match s.rtt_micros.load(Ordering::Relaxed) { // ordering: stats snapshot
                    0 => "null".to_string(),
                    us => ms(us),
                };
                let (p50, p99) = match s.rtt_window.lock().unwrap().percentiles() {
                    Some((p50, p99)) => (ms(p50), ms(p99)),
                    None => ("null".to_string(), "null".to_string()),
                };
                format!(
                    "{{\"worker\":{i},\"pid\":{pid},\"up\":{},\"restarts\":{},\"rtt_ms\":{rtt},\
                     \"rtt_p50_ms\":{p50},\"rtt_p99_ms\":{p99}}}",
                    s.up.load(Ordering::Relaxed), // ordering: stats snapshot
                    s.restarts.load(Ordering::Relaxed), // ordering: stats snapshot
                )
            })
            .collect();
        rows.join(",")
    }
}

// ---------------------------------------------------------------------
// The front-end proxy for one worker.

struct PendingRemote {
    reply: Reply,
    shutdown: bool,
    sent_at: Instant,
}

struct ProxyInner {
    /// `Some` while a connection is up: the writer thread's inbox of
    /// encoded frames.
    out: Option<Sender<Vec<u8>>>,
    pending: HashMap<u64, PendingRemote>,
    next_id: u64,
    /// Encode requests in binary on the current connection (set once
    /// the worker's hello ack grants it; reset on every attach).
    bin: bool,
    /// The peer's negotiated IPC version (from its hello ack; 1 until
    /// the ack arrives, and for peers that predate the field). Gates
    /// the v2 binary fields — JSON needs no gating.
    peer_version: u64,
    /// Pipelining id of the current connection's in-flight hello, so
    /// `complete` consumes the ack internally instead of looking it up
    /// in `pending`.
    hello_id: Option<u64>,
}

/// Shutdown-ack ledger of a [`WorkerProxy`]. The serve shell reads it
/// exactly once (`take_drained`, right after the supervisors join),
/// which closes it; a shutdown arriving after that point must be
/// refused — a reply stashed in a closed ledger is never read, which
/// used to park the late requester until the per-request reply timeout.
struct DrainLedger {
    replies: Vec<Reply>,
    closed: bool,
}

/// Front-end endpoint of one worker's IPC connection. Cheap to share
/// (`Arc`); the router dispatches through it, the supervisor attaches
/// and detaches connections around worker lifecycles.
pub(crate) struct WorkerProxy {
    shard: usize,
    inner: Mutex<ProxyInner>,
    table: Arc<WorkerStatsTable>,
    /// Codec preference: `Binary` sends the hello on every attach and
    /// upgrades when acked; `Json` never attempts the upgrade.
    codec: IpcCodec,
    /// Reusable encode buffers shared with the writer thread.
    pool: Arc<BufPool>,
    /// A shutdown request has been dispatched to this worker.
    shutdown: AtomicBool,
    /// The worker acked its drain (or died after shutdown was
    /// requested, which drains it maximally: its sessions are gone).
    drain_done: AtomicBool,
    /// Shutdown requesters to ack once the serve shell has released the
    /// listener — the cross-process form of the executor's returned
    /// shutdown repliers.
    drained: Mutex<DrainLedger>,
    /// Connection generation; a reader from epoch E tears down state
    /// only while the proxy is still in epoch E.
    epoch: AtomicU64,
}

impl WorkerProxy {
    pub(crate) fn new(shard: usize, table: Arc<WorkerStatsTable>, codec: IpcCodec) -> WorkerProxy {
        WorkerProxy {
            shard,
            inner: Mutex::new(ProxyInner {
                out: None,
                pending: HashMap::new(),
                next_id: 0,
                bin: false,
                peer_version: 1,
                hello_id: None,
            }),
            table,
            codec,
            pool: Arc::new(BufPool::new()),
            shutdown: AtomicBool::new(false),
            drain_done: AtomicBool::new(false),
            drained: Mutex::new(DrainLedger { replies: Vec::new(), closed: false }),
            epoch: AtomicU64::new(0),
        }
    }

    pub(crate) fn shard(&self) -> usize {
        self.shard
    }

    pub(crate) fn slot(&self) -> &WorkerSlot {
        self.table.slot(self.shard)
    }

    pub(crate) fn is_up(&self) -> bool {
        self.slot().up.load(Ordering::SeqCst)
    }

    pub(crate) fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    pub(crate) fn drain_done(&self) -> bool {
        self.drain_done.load(Ordering::SeqCst)
    }

    /// The shutdown repliers owed an ack at port release. Closes the
    /// ledger: this runs once, after the supervisors joined, so any
    /// later shutdown is refused by `dispatch` (the connection closes
    /// and EOF is the ack) instead of being stashed where nobody will
    /// ever read it.
    pub(crate) fn take_drained(&self) -> Vec<Reply> {
        let mut ledger = self.drained.lock().unwrap();
        ledger.closed = true;
        std::mem::take(&mut ledger.replies)
    }

    /// Route one request to the worker. `Err` returns the reply so the
    /// router can answer `shard_unavailable` — the worker is down (its
    /// supervisor may yet respawn it; the refusal is immediate either
    /// way, never a hang). Shutdown requests succeed while the drain
    /// ledger is open: delivered over IPC when the worker is up,
    /// recorded as trivially drained when it is down (a dead worker has
    /// nothing left to drain). After the shell has collected the ledger
    /// a shutdown is refused instead — its requester's connection
    /// closes promptly (EOF is the ack), rather than parking until the
    /// reply timeout behind a stash nobody reads anymore.
    ///
    /// Ordering invariant: the `shutdown` flag is published only AFTER
    /// the requester's reply is reachable (inserted into `pending`, or
    /// pushed to `drained`). Supervisors exit on that flag and the
    /// serve shell collects `drained` right after they join, so a
    /// flag-first ordering could let the collection race ahead of the
    /// recording and strand the client's shutdown ack.
    pub(crate) fn dispatch(&self, req: Request, reply: Reply) -> std::result::Result<(), Reply> {
        let is_shutdown = matches!(req, Request::Shutdown);
        let mut inner = self.inner.lock().unwrap();
        let Some(out) = inner.out.clone() else {
            drop(inner);
            if is_shutdown {
                self.stash_drained(reply)?;
                self.drain_done.store(true, Ordering::SeqCst);
                self.shutdown.store(true, Ordering::SeqCst);
                return Ok(());
            }
            return Err(reply);
        };
        let id = inner.next_id;
        inner.next_id += 1;
        let mut frame = self.pool.take();
        if inner.bin {
            encode_request_bin(id, &req, inner.peer_version, &mut frame);
        } else {
            frame.clear();
            frame.extend_from_slice(encode_request(id, &req).as_bytes());
        }
        inner
            .pending
            .insert(id, PendingRemote { reply, shutdown: is_shutdown, sent_at: Instant::now() });
        if out.send(frame).is_err() {
            // Writer raced away between the state check and the send.
            // lint: allow(unwrap) — inserted above under this same
            // lock, so the entry is still there.
            let p = inner.pending.remove(&id).expect("just inserted");
            drop(inner);
            if is_shutdown {
                self.stash_drained(p.reply)?;
                self.drain_done.store(true, Ordering::SeqCst);
                self.shutdown.store(true, Ordering::SeqCst);
                return Ok(());
            }
            return Err(p.reply);
        }
        drop(inner);
        if is_shutdown {
            self.shutdown.store(true, Ordering::SeqCst);
        }
        Ok(())
    }

    /// Record a shutdown requester in the drain ledger. `Err` hands the
    /// reply back when the ledger is already closed — the shell has
    /// collected the acks, so the caller must refuse (which closes the
    /// requester's connection promptly) instead of stranding the reply.
    fn stash_drained(&self, reply: Reply) -> std::result::Result<(), Reply> {
        let mut ledger = self.drained.lock().unwrap();
        if ledger.closed {
            return Err(reply);
        }
        ledger.replies.push(reply);
        Ok(())
    }

    /// Adopt a fresh connection: spawn its writer and reader threads
    /// and flip the proxy `Up`. Any previous epoch's reader becomes
    /// inert (its detach no-ops on the epoch check). With a `Binary`
    /// codec preference the connection's first frame is the JSON
    /// hello; requests dispatched before the ack arrives simply go out
    /// as JSON (the worker mirrors per frame, so mixed codecs on one
    /// connection are well-defined).
    pub(crate) fn attach(self: &Arc<Self>, stream: TcpStream) -> Result<()> {
        let _ = stream.set_nodelay(true);
        let write_half = stream.try_clone().context("clone worker stream")?;
        let epoch = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
        let (out_tx, out_rx) = channel::<Vec<u8>>();
        {
            let mut inner = self.inner.lock().unwrap();
            inner.bin = false;
            inner.peer_version = 1;
            inner.hello_id = None;
            if self.codec == IpcCodec::Binary {
                // Assigned under the same lock that orders dispatches,
                // so the hello is frame one on this connection.
                let id = inner.next_id;
                inner.next_id += 1;
                inner.hello_id = Some(id);
                let mut frame = self.pool.take();
                frame.extend_from_slice(encode_hello(id, IpcCodec::Binary).as_bytes());
                let _ = out_tx.send(frame);
            }
            inner.out = Some(out_tx);
        }
        self.slot().up.store(true, Ordering::SeqCst);
        let shard = self.shard;
        let pool = self.pool.clone();
        std::thread::spawn(move || {
            // Drain bursts: block for the first frame, then gather
            // everything already queued (up to the writev batch cap)
            // into one syscall.
            let mut batch: Vec<Vec<u8>> = Vec::new();
            loop {
                match out_rx.recv() {
                    Ok(frame) => batch.push(frame),
                    Err(_) => break,
                }
                while batch.len() < IPC_WRITE_BATCH {
                    match out_rx.try_recv() {
                        Ok(frame) => batch.push(frame),
                        Err(_) => break,
                    }
                }
                let ok = crate::server::poll::write_gathered(&write_half, &batch).is_ok();
                pool.put_all(&mut batch);
                if !ok {
                    // The connection is gone; the reader observes the
                    // same and runs the (idempotent) detach.
                    break;
                }
            }
        });
        let proxy = self.clone();
        std::thread::spawn(move || {
            let mut stream = stream;
            let mut frames = FrameBuf::new(IPC_MAX_FRAME);
            let mut scratch = [0u8; 64 * 1024];
            loop {
                match stream.read(&mut scratch) {
                    Ok(0) => break,
                    Ok(n) => {
                        frames.feed(&scratch[..n]);
                        while let Some(frame) = frames.next_frame() {
                            let decoded = match frame {
                                Frame::Line(line) => decode_reply(&line),
                                Frame::Bin(payload) => decode_reply_bin(payload),
                            };
                            match decoded {
                                Ok((id, resp)) => proxy.complete(id, resp),
                                Err(e) => {
                                    // Resync: skip the bad frame, keep
                                    // the connection (its peer is our
                                    // own worker; torn frames cannot
                                    // happen, garbage is logged).
                                    crate::debug!("worker {shard}: bad reply frame: {e:#}");
                                }
                            }
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(_) => break,
                }
            }
            proxy.detach(epoch);
        });
        Ok(())
    }

    /// Complete a pending request with the worker's reply. Unknown ids
    /// (already failed over by a detach) are dropped, mirroring the
    /// reactor dropping late replies for timed-out requests. Shutdown
    /// acks move into `drained` UNDER the state lock, so a supervisor
    /// running `force_detach` + collect after the worker exits can
    /// never observe the ack in neither place (which would lose the
    /// client's shutdown reply).
    fn complete(&self, id: u64, resp: String) {
        let mut inner = self.inner.lock().unwrap();
        if inner.hello_id == Some(id) {
            // The codec handshake completes internally; it was never in
            // `pending` and no client is waiting on it.
            inner.hello_id = None;
            inner.bin = hello_grants_binary(&resp);
            inner.peer_version = hello_peer_version(&resp);
            if !inner.bin {
                crate::info!(
                    "worker {}: peer declined the binary codec; staying on json",
                    self.shard
                );
            }
            return;
        }
        let Some(p) = inner.pending.remove(&id) else { return };
        let rtt = p.sent_at.elapsed().as_micros().max(1) as u64;
        // ordering: stats-only gauge read by render_rows; no other
        // state is published through it.
        self.slot().rtt_micros.store(rtt, Ordering::Relaxed);
        self.slot().rtt_window.lock().unwrap().push(rtt);
        if p.shutdown {
            // A closed ledger drops the ack: the late requester's
            // connection is closing, and EOF stands in for the ack.
            let _ = self.stash_drained(p.reply);
            self.drain_done.store(true, Ordering::SeqCst);
        } else {
            drop(inner);
            let _ = p.reply.send(resp);
        }
    }

    /// Tear down epoch `epoch`'s connection state: flip `Down` and fail
    /// every in-flight request with `shard_unavailable` (in-flight
    /// shutdown requesters count as drained — the worker died, taking
    /// every session with it). No-op if a newer connection already
    /// replaced this epoch.
    pub(crate) fn detach(&self, epoch: u64) {
        if self.epoch.load(Ordering::SeqCst) != epoch {
            return;
        }
        let mut failed = Vec::new();
        {
            let mut inner = self.inner.lock().unwrap();
            if inner.out.is_none() {
                return; // already detached
            }
            inner.out = None;
            // The next attach renegotiates from scratch.
            inner.bin = false;
            inner.peer_version = 1;
            inner.hello_id = None;
            let mut acked = Vec::new();
            for (_, p) in inner.pending.drain() {
                if p.shutdown {
                    acked.push(p.reply);
                } else {
                    failed.push(p.reply);
                }
            }
            // Shutdown-ack bookkeeping stays under the state lock (see
            // `complete`): once any detach/force_detach returns, every
            // requester is either in `drained` or about to be failed
            // over below — never invisible to a collecting supervisor.
            if !acked.is_empty() {
                let mut ledger = self.drained.lock().unwrap();
                // A closed ledger drops late acks: those requesters'
                // connections close, and EOF stands in for the ack.
                if !ledger.closed {
                    ledger.replies.extend(acked);
                }
            }
            if self.shutdown.load(Ordering::SeqCst) {
                self.drain_done.store(true, Ordering::SeqCst);
            }
        }
        self.slot().up.store(false, Ordering::SeqCst);
        for reply in failed {
            let _ = reply.send(SHARD_UNAVAILABLE.into());
        }
    }

    /// Detach whatever connection is current (supervisor cleanup after
    /// observing the worker process exit; idempotent with the reader's
    /// own EOF detach).
    pub(crate) fn force_detach(&self) {
        self.detach(self.epoch.load(Ordering::SeqCst));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;
    use std::sync::mpsc::channel as mpsc_channel;

    fn arbitrary_request(rng: &mut Rng) -> Request {
        let session = {
            // Exercise ids needing JSON escapes too.
            let alphabet = ["u", "s-1", "Ω", "a b", "q\"uote", "tab\there", "line\nbreak"];
            format!("{}{}", rng.choice(&alphabet), rng.range(0, 1000))
        };
        let tokens: Vec<i32> =
            (0..rng.range(0, 9)).map(|_| rng.range(0, 65_536) as i32 - 32_768).collect();
        match rng.range(0, 4) {
            0 => {
                let strategy = match rng.range(0, 4) {
                    0 => None,
                    1 => Some(StrategyKind::Ccm),
                    2 => Some(StrategyKind::SlidingWindow),
                    _ => Some(StrategyKind::NoCompress),
                };
                Request::Context { session, tokens, strategy }
            }
            1 => Request::Query { session, tokens, topk: rng.range(1, 64) },
            2 => Request::Stats(StatsQuery {
                detail: rng.bool(0.5),
                prefix: rng.bool(0.5).then(|| format!("p{}", rng.range(0, 10))),
                after_id: rng.bool(0.5).then(|| format!("u{}", rng.range(0, 50))),
                limit: rng.bool(0.5).then(|| rng.range(0, 100)),
                per_reactor: None,
            }),
            _ => Request::Shutdown,
        }
    }

    fn arbitrary_reply(rng: &mut Rng) -> String {
        match rng.range(0, 3) {
            0 => format!(
                "{{\"ok\":true,\"kind\":\"context\",\"t\":{},\"kv_bytes\":{}}}",
                rng.range(0, 100),
                rng.range(0, 1 << 20)
            ),
            1 => {
                let pairs: Vec<String> = (0..rng.range(1, 6))
                    .map(|_| format!("[{},{:.4}]", rng.range(0, 512), -(rng.f64() * 10.0)))
                    .collect();
                format!("{{\"ok\":true,\"kind\":\"query\",\"next\":[{}]}}", pairs.join(","))
            }
            _ => format!(
                "{{\"ok\":false,\"error\":{}}}",
                escape(&format!("weird \"error\"\nno. {}", rng.range(0, 50)))
            ),
        }
    }

    #[test]
    fn request_frames_roundtrip() {
        check("ipc-request-roundtrip", 200, |rng| {
            let id = rng.next_u64() >> 12; // JSON numbers are f64-exact to 2^53
            let req = arbitrary_request(rng);
            let frame = encode_request(id, &req);
            crate::prop_assert!(frame.ends_with('\n'), "frame must be newline-terminated");
            let (got_id, got) = decode_request(frame.trim_end()).map_err(|e| format!("{e:#}"))?;
            crate::prop_assert!(got_id == id, "id {got_id} != {id}");
            crate::prop_assert!(got == req, "decoded {got:?} != {req:?}");
            Ok(())
        });
    }

    #[test]
    fn reply_frames_roundtrip_verbatim() {
        check("ipc-reply-roundtrip", 200, |rng| {
            let id = rng.next_u64() >> 12;
            let resp = arbitrary_reply(rng);
            let frame = encode_reply(id, &resp);
            let (got_id, got) = decode_reply(frame.trim_end()).map_err(|e| format!("{e:#}"))?;
            crate::prop_assert!(got_id == id, "id {got_id} != {id}");
            crate::prop_assert!(got == resp, "reply body must round-trip verbatim:\n{got}\n{resp}");
            Ok(())
        });
    }

    #[test]
    fn framebuf_reassembles_any_byte_split() {
        // Split a multi-frame stream at EVERY byte boundary: the decoder
        // must recover the identical frame sequence from each split.
        let frames = [
            encode_request(
                1,
                &Request::Context { session: "a".into(), tokens: vec![1, 2], strategy: None },
            ),
            encode_reply(2, "{\"ok\":true,\"kind\":\"query\",\"next\":[[7,-0.5]]}"),
            encode_request(3, &Request::Shutdown),
        ];
        let stream: Vec<u8> = frames.iter().flat_map(|f| f.bytes()).collect();
        let expect: Vec<String> = frames.iter().map(|f| f.trim_end().to_string()).collect();
        for split in 0..=stream.len() {
            let mut fb = FrameBuf::new(IPC_MAX_FRAME);
            let mut got = Vec::new();
            fb.feed(&stream[..split]);
            while let Some(line) = fb.next_line() {
                got.push(line);
            }
            fb.feed(&stream[split..]);
            while let Some(line) = fb.next_line() {
                got.push(line);
            }
            assert_eq!(got, expect, "split at byte {split}");
        }
    }

    #[test]
    fn framebuf_survives_incremental_drip_feeds() {
        check("ipc-drip-feed", 60, |rng| {
            let n = rng.range(1, 8);
            let frames: Vec<String> = (0..n)
                .map(|i| {
                    if rng.bool(0.5) {
                        encode_request(i as u64, &arbitrary_request(rng))
                    } else {
                        encode_reply(i as u64, &arbitrary_reply(rng))
                    }
                })
                .collect();
            let stream: Vec<u8> = frames.iter().flat_map(|f| f.bytes()).collect();
            let mut fb = FrameBuf::new(IPC_MAX_FRAME);
            let mut got = Vec::new();
            let mut i = 0;
            while i < stream.len() {
                let step = rng.range(1, 7).min(stream.len() - i);
                fb.feed(&stream[i..i + step]);
                i += step;
                while let Some(line) = fb.next_line() {
                    got.push(line);
                }
            }
            let expect: Vec<String> = frames.iter().map(|f| f.trim_end().to_string()).collect();
            crate::prop_assert!(got == expect, "drip-fed frames diverged: {got:?} != {expect:?}");
            Ok(())
        });
    }

    #[test]
    fn garbage_prefix_resyncs_at_the_next_newline() {
        check("ipc-garbage-resync", 100, |rng| {
            // Newline-free garbage (newlines would legitimately frame),
            // then a newline, then valid frames: every valid frame must
            // decode; the garbage line must error, not panic or desync.
            // The first byte avoids BIN_MAGIC: a frame START opening
            // with the magic is by definition a binary frame, and
            // resync-from-garbage is the line codec's guarantee.
            let garbage: Vec<u8> = (0..rng.range(1, 200))
                .map(|i| {
                    let b = rng.range(0, 255) as u8;
                    if b == b'\n' || (i == 0 && b == BIN_MAGIC) {
                        b'x'
                    } else {
                        b
                    }
                })
                .collect();
            let req = arbitrary_request(rng);
            let reply = arbitrary_reply(rng);
            let mut stream = garbage.clone();
            stream.push(b'\n');
            stream.extend_from_slice(encode_request(9, &req).as_bytes());
            stream.extend_from_slice(encode_reply(10, &reply).as_bytes());
            let mut fb = FrameBuf::new(IPC_MAX_FRAME);
            fb.feed(&stream);
            let first = fb.next_line().ok_or("garbage line must frame")?;
            crate::prop_assert!(decode_request(&first).is_err(), "garbage decoded as a request");
            crate::prop_assert!(decode_reply(&first).is_err(), "garbage decoded as a reply");
            let (id, got) = decode_request(&fb.next_line().ok_or("request frame lost")?)
                .map_err(|e| format!("post-garbage request: {e:#}"))?;
            crate::prop_assert!(id == 9 && got == req, "request diverged after resync");
            let (id, got) = decode_reply(&fb.next_line().ok_or("reply frame lost")?)
                .map_err(|e| format!("post-garbage reply: {e:#}"))?;
            crate::prop_assert!(id == 10 && got == reply, "reply diverged after resync");
            crate::prop_assert!(fb.next_line().is_none(), "no trailing frames");
            Ok(())
        });
    }

    #[test]
    fn framebuf_caps_overlong_lines_and_recovers() {
        let mut fb = FrameBuf::new(16);
        // Terminated overlong line: skipped whole.
        fb.feed(b"aaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\nok\n");
        assert_eq!(fb.next_line().as_deref(), Some("ok"));
        assert!(fb.next_line().is_none());
        // Unterminated overlong line: dropped incrementally, resync at
        // the next newline.
        fb.feed(&vec![b'b'; 40]);
        assert!(fb.next_line().is_none());
        fb.feed(&vec![b'c'; 40]);
        assert!(fb.next_line().is_none());
        fb.feed(b"tail\nnext\n");
        // "tail" belongs to the discarded line; "next" frames cleanly.
        assert_eq!(fb.next_line().as_deref(), Some("next"));
        assert!(fb.next_line().is_none());
    }

    #[test]
    fn binary_request_frames_roundtrip() {
        check("ipc-bin-request-roundtrip", 200, |rng| {
            let id = rng.next_u64() >> 12;
            let req = arbitrary_request(rng);
            let mut frame = Vec::new();
            encode_request_bin(id, &req, IPC_VERSION, &mut frame);
            crate::prop_assert!(frame[0] == BIN_MAGIC, "frame must open with the magic");
            let declared = u32::from_le_bytes([frame[1], frame[2], frame[3], frame[4]]) as usize;
            crate::prop_assert!(declared == frame.len() - 5, "length prefix must be exact");
            let (got_id, got) = decode_request_bin(&frame[5..]).map_err(|e| format!("{e:#}"))?;
            crate::prop_assert!(got_id == id, "id {got_id} != {id}");
            crate::prop_assert!(got == req, "decoded {got:?} != {req:?}");
            Ok(())
        });
    }

    #[test]
    fn binary_reply_frames_roundtrip_verbatim() {
        check("ipc-bin-reply-roundtrip", 200, |rng| {
            let id = rng.next_u64() >> 12;
            let resp = arbitrary_reply(rng);
            let mut frame = Vec::new();
            encode_reply_bin(id, &resp, &mut frame);
            let (got_id, got) = decode_reply_bin(&frame[5..]).map_err(|e| format!("{e:#}"))?;
            crate::prop_assert!(got_id == id, "id {got_id} != {id}");
            crate::prop_assert!(got == resp, "reply body must round-trip verbatim:\n{got}\n{resp}");
            Ok(())
        });
    }

    #[test]
    fn cross_codec_values_decode_identically() {
        // The equivalence the negotiation relies on: whichever codec a
        // frame travels in, the decoded value is the same.
        check("ipc-cross-codec", 200, |rng| {
            let id = rng.next_u64() >> 12;
            let req = arbitrary_request(rng);
            let via_json = decode_request(encode_request(id, &req).trim_end())
                .map_err(|e| format!("json: {e:#}"))?;
            let mut frame = Vec::new();
            encode_request_bin(id, &req, IPC_VERSION, &mut frame);
            let via_bin = decode_request_bin(&frame[5..]).map_err(|e| format!("bin: {e:#}"))?;
            crate::prop_assert!(
                via_json == via_bin,
                "request codecs diverged: {via_json:?} != {via_bin:?}"
            );
            let resp = arbitrary_reply(rng);
            let via_json = decode_reply(encode_reply(id, &resp).trim_end())
                .map_err(|e| format!("json reply: {e:#}"))?;
            encode_reply_bin(id, &resp, &mut frame);
            let via_bin = decode_reply_bin(&frame[5..]).map_err(|e| format!("bin reply: {e:#}"))?;
            crate::prop_assert!(via_json == via_bin, "reply codecs diverged");
            Ok(())
        });
    }

    /// A mixed-codec stream (exactly what the wire carries during
    /// negotiation) as `(bytes, expected id sequence)`.
    fn mixed_stream(rng: &mut Rng) -> (Vec<u8>, Vec<u64>) {
        let mut stream = Vec::new();
        let mut ids = Vec::new();
        for i in 0..rng.range(1, 8) as u64 {
            ids.push(i);
            match rng.range(0, 4) {
                0 => {
                    let line = encode_request(i, &arbitrary_request(rng));
                    stream.extend_from_slice(line.as_bytes());
                }
                1 => stream.extend_from_slice(encode_reply(i, &arbitrary_reply(rng)).as_bytes()),
                2 => {
                    let mut f = Vec::new();
                    encode_request_bin(i, &arbitrary_request(rng), IPC_VERSION, &mut f);
                    stream.extend_from_slice(&f);
                }
                _ => {
                    let mut f = Vec::new();
                    encode_reply_bin(i, &arbitrary_reply(rng), &mut f);
                    stream.extend_from_slice(&f);
                }
            }
        }
        (stream, ids)
    }

    /// Decode every buffered frame of either codec to its frame id.
    fn drain_ids(fb: &mut FrameBuf, out: &mut Vec<u64>) {
        while let Some(frame) = fb.next_frame() {
            let id = match frame {
                Frame::Line(line) => decode_request(&line)
                    .map(|(id, _)| id)
                    .or_else(|_| decode_reply(&line).map(|(id, _)| id))
                    .expect("line frame decodes"),
                Frame::Bin(payload) => decode_request_bin(payload)
                    .map(|(id, _)| id)
                    .or_else(|_| decode_reply_bin(payload).map(|(id, _)| id))
                    .expect("binary frame decodes"),
            };
            out.push(id);
        }
    }

    #[test]
    fn framebuf_reassembles_mixed_codec_streams_at_any_split() {
        let mut rng = Rng::new(0xC0DEC);
        let (stream, ids) = mixed_stream(&mut rng);
        for split in 0..=stream.len() {
            let mut fb = FrameBuf::new(IPC_MAX_FRAME);
            let mut got = Vec::new();
            fb.feed(&stream[..split]);
            drain_ids(&mut fb, &mut got);
            fb.feed(&stream[split..]);
            drain_ids(&mut fb, &mut got);
            assert_eq!(got, ids, "split at byte {split}");
        }
    }

    #[test]
    fn framebuf_survives_mixed_codec_drip_feeds() {
        check("ipc-bin-drip-feed", 60, |rng| {
            let (stream, ids) = mixed_stream(rng);
            let mut fb = FrameBuf::new(IPC_MAX_FRAME);
            let mut got = Vec::new();
            let mut i = 0;
            while i < stream.len() {
                let step = rng.range(1, 7).min(stream.len() - i);
                fb.feed(&stream[i..i + step]);
                i += step;
                drain_ids(&mut fb, &mut got);
            }
            crate::prop_assert!(got == ids, "drip-fed mixed frames diverged: {got:?} != {ids:?}");
            Ok(())
        });
    }

    #[test]
    fn framebuf_skips_oversize_binary_frames_and_recovers() {
        let mut fb = FrameBuf::new(32);
        // A binary frame whose declared payload (64 bytes) exceeds the
        // cap: skipped exactly, even fed in pieces.
        let mut oversize = vec![BIN_MAGIC];
        oversize.extend_from_slice(&64u32.to_le_bytes());
        oversize.extend_from_slice(&[7u8; 40]);
        fb.feed(&oversize);
        assert!(fb.next_frame().is_none());
        fb.feed(&[7u8; 24]); // the rest of the skipped payload
        let mut good = Vec::new();
        encode_reply_bin(3, "{\"ok\":true}", &mut good);
        fb.feed(&good);
        match fb.next_frame() {
            Some(Frame::Bin(payload)) => {
                assert_eq!(decode_reply_bin(payload).unwrap(), (3, "{\"ok\":true}".to_string()));
            }
            other => panic!("expected the post-skip binary frame, got {:?}", other.is_some()),
        }
        assert!(fb.next_frame().is_none());
    }

    #[test]
    fn hello_handshake_grants_and_declines() {
        // Worker side: the hello is intercepted before the request
        // grammar (which would reject it — the negotiate-down path for
        // pre-codec peers).
        let hello = encode_hello(0, IpcCodec::Binary);
        match decode_line(hello.trim_end()).unwrap() {
            LineFrame::Hello { id, codec } => {
                assert_eq!(id, 0);
                assert_eq!(codec, IpcCodec::Binary);
            }
            LineFrame::Req(..) => panic!("hello parsed as a request"),
        }
        assert!(decode_request(hello.trim_end()).is_err(), "request grammar must reject hello");
        // Proxy side: only an ok+binary ack grants the upgrade.
        assert!(hello_grants_binary(&hello_ack(IpcCodec::Binary)));
        assert!(!hello_grants_binary(&hello_ack(IpcCodec::Json)));
        assert!(!hello_grants_binary("{\"ok\":false,\"error\":\"unknown op \\\"hello\\\"\"}"));
        assert!(!hello_grants_binary("not json"));
    }

    #[test]
    fn hello_ack_version_parses_and_negotiates_down() {
        // Our own ack reports the current version...
        assert_eq!(hello_peer_version(&hello_ack(IpcCodec::Binary)), IPC_VERSION);
        // ...a pre-versioned peer's ack (no field), an error reply, and
        // garbage all read as v1 — the wire that never carries the v2
        // fields.
        assert_eq!(hello_peer_version("{\"ok\":true,\"kind\":\"hello\",\"codec\":\"binary\"}"), 1);
        assert_eq!(hello_peer_version("{\"ok\":false,\"error\":\"unknown op\"}"), 1);
        assert_eq!(hello_peer_version("not json"), 1);
        assert_eq!(hello_peer_version("{\"version\":0}"), 1, "nonsense versions clamp to 1");
    }

    #[test]
    fn v2_binary_context_carries_the_strategy_byte() {
        let req = Request::Context {
            session: "u".into(),
            tokens: vec![1, 2],
            strategy: Some(StrategyKind::SlidingWindow),
        };
        let mut frame = Vec::new();
        encode_request_bin(7, &req, 2, &mut frame);
        let (id, got) = decode_request_bin(&frame[5..]).unwrap();
        assert_eq!(id, 7);
        assert_eq!(got, req);
        // No explicit tier encodes as the reserved 0 byte and decodes
        // back to None.
        let none = Request::Context { session: "u".into(), tokens: vec![1], strategy: None };
        encode_request_bin(8, &none, 2, &mut frame);
        assert_eq!(decode_request_bin(&frame[5..]).unwrap().1, none);
    }

    #[test]
    fn v1_binary_encoding_drops_the_v2_fields() {
        // Talking to a v1 worker: the strategy byte is omitted (its
        // exact-length decoder would reject trailing bytes), so the
        // request decodes with the field defaulted — negotiate-down.
        let req = Request::Context {
            session: "u".into(),
            tokens: vec![4],
            strategy: Some(StrategyKind::NoCompress),
        };
        let mut frame = Vec::new();
        encode_request_bin(9, &req, 1, &mut frame);
        let (_, got) = decode_request_bin(&frame[5..]).unwrap();
        assert_eq!(
            got,
            Request::Context { session: "u".into(), tokens: vec![4], strategy: None },
            "a v1 frame must decode with no explicit tier"
        );
        // Same for the stats cursor: the flag (and string) are dropped.
        let stats = Request::Stats(StatsQuery {
            detail: true,
            prefix: Some("u".into()),
            after_id: Some("u3".into()),
            limit: Some(5),
            per_reactor: None,
        });
        encode_request_bin(10, &stats, 1, &mut frame);
        let (_, got) = decode_request_bin(&frame[5..]).unwrap();
        let Request::Stats(q) = got else { panic!("stats frame decoded as {got:?}") };
        assert_eq!(q.after_id, None, "v1 frames cannot carry the cursor");
        assert_eq!(q.prefix.as_deref(), Some("u"));
        assert_eq!(q.limit, Some(5));
        // At v2 the cursor survives.
        encode_request_bin(11, &stats, 2, &mut frame);
        let (_, got) = decode_request_bin(&frame[5..]).unwrap();
        let Request::Stats(q) = got else { panic!("stats frame decoded as {got:?}") };
        assert_eq!(q.after_id.as_deref(), Some("u3"));
    }

    #[test]
    fn frame_id_recovers_ids_from_malformed_request_bodies() {
        assert_eq!(frame_id("{\"id\":42,\"op\":\"nope\"}"), Some(42));
        assert_eq!(frame_id("{\"op\":\"stats\"}"), None);
        assert_eq!(frame_id("total garbage"), None);
        assert_eq!(frame_id("{\"id\":-3,\"op\":\"stats\"}"), None);
    }

    #[test]
    fn proxy_down_refuses_and_stashes_shutdown() {
        let table = Arc::new(WorkerStatsTable::new(1));
        let proxy = Arc::new(WorkerProxy::new(0, table, IpcCodec::Json));
        // Session-routed work while down: refused (the router turns the
        // returned reply into shard_unavailable).
        let (tx, _rx) = mpsc_channel();
        let req = Request::Query { session: "u".into(), tokens: vec![1], topk: 1 };
        assert!(proxy.dispatch(req, Reply::channel(tx)).is_err());
        // Shutdown while down: accepted, trivially drained, the reply
        // stashed for the port-release ack.
        let (tx, rx) = mpsc_channel();
        assert!(proxy.dispatch(Request::Shutdown, Reply::channel(tx)).is_ok());
        assert!(proxy.drain_done());
        assert!(rx.try_recv().is_err(), "no ack before the port is released");
        assert_eq!(proxy.take_drained().len(), 1);
    }

    #[test]
    fn late_shutdown_after_ledger_collection_is_refused() {
        let table = Arc::new(WorkerStatsTable::new(1));
        let proxy = Arc::new(WorkerProxy::new(0, table, IpcCodec::Json));
        // Normal drain: a shutdown while down is stashed, then the
        // serve shell collects the ledger at port release.
        let (tx, _rx) = mpsc_channel();
        assert!(proxy.dispatch(Request::Shutdown, Reply::channel(tx)).is_ok());
        assert_eq!(proxy.take_drained().len(), 1);
        // A late shutdown (a client that raced the drain) must be
        // refused so its connection closes promptly — the pre-fix stash
        // was never read again, parking the client until the reply
        // timeout.
        let (tx, rx) = mpsc_channel();
        assert!(proxy.dispatch(Request::Shutdown, Reply::channel(tx)).is_err());
        assert!(rx.try_recv().is_err(), "no fabricated ack for a refused shutdown");
        assert!(proxy.take_drained().is_empty(), "nothing is stashed after collection");
    }

    // Miri has no socket support; the drain/refusal logic above runs
    // under it, the wire-level test does not.
    #[cfg_attr(miri, ignore)]
    #[test]
    fn proxy_detach_fails_pending_with_shard_unavailable() {
        use std::net::{TcpListener, TcpStream};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (_server_side, _) = listener.accept().unwrap();
        let table = Arc::new(WorkerStatsTable::new(1));
        let proxy = Arc::new(WorkerProxy::new(0, table.clone(), IpcCodec::Json));
        proxy.attach(client).unwrap();
        assert!(proxy.is_up());
        let (tx, rx) = mpsc_channel();
        let req = Request::Query { session: "u".into(), tokens: vec![2], topk: 1 };
        assert!(proxy.dispatch(req, Reply::channel(tx)).is_ok());
        assert!(rx.try_recv().is_err(), "no reply yet");
        // The worker "dies": the supervisor force-detaches. The pending
        // request fails over immediately — no hang, no dropped channel.
        proxy.force_detach();
        assert!(!proxy.is_up());
        let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).expect("failover reply");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("error").unwrap().str().unwrap(), "shard_unavailable");
        // And a stale second detach of the same epoch is a no-op.
        proxy.force_detach();
    }

    // The proxy half of the codec negotiation over a real socket: the
    // hello is frame one, requests stay JSON until the ack, and flip to
    // binary after it (with the JSON reply to a pre-ack request still
    // completing correctly — the mixed-codec window).
    #[cfg_attr(miri, ignore)]
    #[test]
    fn proxy_negotiates_binary_after_hello_ack() {
        use std::net::{TcpListener, TcpStream};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (mut worker_side, _) = listener.accept().unwrap();
        let table = Arc::new(WorkerStatsTable::new(1));
        let proxy = Arc::new(WorkerProxy::new(0, table, IpcCodec::Binary));
        proxy.attach(client).unwrap();

        // A request dispatched before the ack goes out as JSON, after
        // the hello.
        let (tx, rx) = mpsc_channel();
        let req = Request::Query { session: "u".into(), tokens: vec![1, 2], topk: 1 };
        proxy.dispatch(req, Reply::channel(tx)).unwrap();

        let mut fb = FrameBuf::new(IPC_MAX_FRAME);
        let mut scratch = [0u8; 4096];
        let mut read_frame = |fb: &mut FrameBuf, worker_side: &mut TcpStream| -> (u64, bool) {
            loop {
                if let Some(frame) = match fb.next_frame() {
                    Some(Frame::Line(line)) => match decode_line(&line).unwrap() {
                        LineFrame::Hello { id, codec } => {
                            assert_eq!(codec, IpcCodec::Binary);
                            Some((id, false))
                        }
                        LineFrame::Req(id, _) => Some((id, false)),
                    },
                    Some(Frame::Bin(payload)) => {
                        Some((decode_request_bin(payload).unwrap().0, true))
                    }
                    None => None,
                } {
                    return frame;
                }
                let n = worker_side.read(&mut scratch).unwrap();
                assert!(n > 0, "proxy closed early");
                fb.feed(&scratch[..n]);
            }
        };
        let (hello_id, bin) = read_frame(&mut fb, &mut worker_side);
        assert!(!bin, "the hello is a JSON line");
        let (req_id, bin) = read_frame(&mut fb, &mut worker_side);
        assert!(!bin, "pre-ack requests stay JSON");

        // Ack the hello, then answer the pending JSON request.
        let ack = encode_reply(hello_id, &hello_ack(IpcCodec::Binary));
        worker_side.write_all(ack.as_bytes()).unwrap();
        worker_side.write_all(encode_reply(req_id, "{\"ok\":true}").as_bytes()).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        assert_eq!(resp, "{\"ok\":true}");

        // Post-ack dispatches arrive as binary frames; a binary reply
        // completes them. (The ack is processed by the proxy's reader
        // asynchronously; it strictly precedes the reply to req_id on
        // the socket, and completion of that reply happens-before the
        // recv above returned, so the upgrade is visible now.)
        let (tx, rx) = mpsc_channel();
        let req = Request::Context { session: "u".into(), tokens: vec![3], strategy: None };
        proxy.dispatch(req, Reply::channel(tx)).unwrap();
        let (bin_id, bin) = read_frame(&mut fb, &mut worker_side);
        assert!(bin, "post-ack requests must be binary");
        let mut reply = Vec::new();
        encode_reply_bin(bin_id, "{\"ok\":true,\"t\":1}", &mut reply);
        worker_side.write_all(&reply).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        assert_eq!(resp, "{\"ok\":true,\"t\":1}");
        proxy.force_detach();
    }

    #[test]
    fn worker_stats_rows_render_valid_json() {
        let table = WorkerStatsTable::new(2);
        table.slot(0).pid.store(4242, Ordering::Relaxed);
        table.slot(0).up.store(true, Ordering::Relaxed);
        table.slot(0).rtt_micros.store(1500, Ordering::Relaxed);
        // 1..=100 µs of samples: p50 = 50 µs, p99 = 99 µs exactly.
        for us in 1..=100 {
            table.slot(0).rtt_window.lock().unwrap().push(us);
        }
        table.slot(1).restarts.store(3, Ordering::Relaxed);
        assert_eq!(table.total_restarts(), 3);
        let parsed = Json::parse(&format!("[{}]", table.render_rows())).expect("valid JSON");
        let rows = parsed.arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("worker").unwrap().usize().unwrap(), 0);
        assert_eq!(rows[0].get("pid").unwrap().usize().unwrap(), 4242);
        assert_eq!(rows[0].get("up").unwrap(), &Json::Bool(true));
        assert!((rows[0].get("rtt_ms").unwrap().f64().unwrap() - 1.5).abs() < 1e-9);
        assert!((rows[0].get("rtt_p50_ms").unwrap().f64().unwrap() - 0.050).abs() < 1e-9);
        assert!((rows[0].get("rtt_p99_ms").unwrap().f64().unwrap() - 0.099).abs() < 1e-9);
        assert_eq!(rows[1].get("pid").unwrap(), &Json::Null);
        assert_eq!(rows[1].get("rtt_ms").unwrap(), &Json::Null);
        assert_eq!(rows[1].get("rtt_p50_ms").unwrap(), &Json::Null);
        assert_eq!(rows[1].get("restarts").unwrap().usize().unwrap(), 3);
    }

    #[test]
    fn rtt_window_caps_and_rolls() {
        let mut w = RttWindow::default();
        assert_eq!(w.percentiles(), None);
        for us in 0..(RTT_WINDOW as u64 + 500) {
            w.push(us + 1);
        }
        let (p50, p99) = w.percentiles().unwrap();
        // The window holds the most recent RTT_WINDOW samples
        // (501..=RTT_WINDOW+500), so the percentiles sit inside that
        // range and the earliest samples are gone.
        assert!(p50 > 500, "oldest samples must have been overwritten (p50={p50})");
        assert!(p99 <= RTT_WINDOW as u64 + 500);
        assert!(p50 < p99);
    }
}
