//! Newline-framed JSON IPC between the serving front-end and shard
//! worker processes (`ccm worker`), plus the front-end's per-worker
//! connection proxy.
//!
//! ## Framing
//!
//! One frame per line. Requests travel front-end → worker as the normal
//! protocol object with a pipelining `id` added:
//!
//! ```text
//! {"id":7,"op":"query","session":"u1","tokens":[9,2],"topk":5}
//! ```
//!
//! and replies travel back as an `{"id":N,"resp":...}` envelope whose
//! `resp` is the executor's reply object embedded verbatim:
//!
//! ```text
//! {"id":7,"resp":{"ok":true,"kind":"query","next":[[9,-0.1]]}}
//! ```
//!
//! Because every frame is newline-terminated and every embedded string
//! is JSON-escaped (`\n` never appears raw inside a frame), a torn read
//! can never desync the stream: [`FrameBuf`] reassembles lines from
//! arbitrarily split reads, an unparsable line is skipped (logged) and
//! framing resynchronises at the next newline, and an overlong line is
//! discarded through its terminator without buffering more than
//! [`IPC_MAX_FRAME`] bytes. Property tests below drive the codec
//! through split-at-every-byte feeds and garbage-prefix resync.
//!
//! ## The proxy
//!
//! [`WorkerProxy`] is the front-end side of one worker connection: a
//! pipelined request-id map (dispatch never blocks the caller — frames
//! go to a writer thread through an unbounded queue, replies come back
//! on a reader thread that completes the pending entry), a per-worker
//! connection state machine (`Down` ⇄ `Up`; while `Down` every
//! session-routed request is refused with the documented
//! `shard_unavailable` reply instead of hanging), and shutdown-ack
//! interception (worker drain acks are stashed until the serve shell
//! has released the listener, preserving the "ack means port released"
//! contract across the process boundary). Reconnect-with-backoff and
//! process respawn live in the supervisor (`worker.rs`); the proxy only
//! tracks the current connection epoch so a stale reader from a
//! previous connection can never tear down its successor.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::server::{fmt_tokens, Reply, Request, SHARD_UNAVAILABLE};
use crate::util::json::{escape, Json};

/// Upper bound on one IPC frame (a stats reply embedding a large
/// `sessions_detail` view is the biggest legitimate frame). Beyond it
/// the decoder discards through the next newline instead of buffering.
pub(crate) const IPC_MAX_FRAME: usize = 16 << 20;

// ---------------------------------------------------------------------
// Incremental line framing.

/// Reassembles newline-terminated frames from arbitrarily split reads.
/// Overlong lines (no newline within `max_line` buffered bytes) are
/// dropped through their terminator so a corrupt peer cannot pin
/// memory; the next line frames normally. Framing advances a cursor
/// and compacts the consumed prefix once per `feed` — one IPC socket
/// multiplexes a whole shard's pipelined traffic, so a per-line front
/// drain would memmove the remaining buffer per frame and make bursts
/// quadratic (the same fix the reactor's line framing uses).
pub(crate) struct FrameBuf {
    buf: Vec<u8>,
    /// Start of the unconsumed region of `buf`.
    cursor: usize,
    max_line: usize,
    discarding: bool,
}

impl FrameBuf {
    pub(crate) fn new(max_line: usize) -> FrameBuf {
        FrameBuf { buf: Vec::new(), cursor: 0, max_line, discarding: false }
    }

    pub(crate) fn feed(&mut self, bytes: &[u8]) {
        if self.cursor > 0 {
            // One compaction for everything consumed since the last
            // feed (amortized O(1) per byte).
            self.buf.drain(..self.cursor);
            self.cursor = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete line (without its newline), or `None` when
    /// no complete line is buffered yet.
    pub(crate) fn next_line(&mut self) -> Option<String> {
        loop {
            let rel = self.buf[self.cursor..].iter().position(|&b| b == b'\n');
            let Some(rel) = rel else {
                if self.buf.len() - self.cursor > self.max_line {
                    // Cap enforcement: drop the partial line, resume at
                    // the next newline.
                    self.buf.clear();
                    self.cursor = 0;
                    self.discarding = true;
                }
                return None;
            };
            let (start, end) = (self.cursor, self.cursor + rel);
            self.cursor = end + 1;
            if self.discarding {
                self.discarding = false;
                continue;
            }
            if end - start > self.max_line {
                continue; // overlong but terminated: skip it whole
            }
            return Some(String::from_utf8_lossy(&self.buf[start..end]).into_owned());
        }
    }
}

// ---------------------------------------------------------------------
// Frame codec.

/// Encode one request frame (newline included). `Stats.per_reactor` is
/// router-internal plumbing and never crosses the IPC boundary: the
/// front-end renders transport rows itself in the merged view.
pub(crate) fn encode_request(id: u64, req: &Request) -> String {
    match req {
        Request::Context { session, tokens } => format!(
            "{{\"id\":{id},\"op\":\"context\",\"session\":{},\"tokens\":{}}}\n",
            escape(session),
            fmt_tokens(tokens)
        ),
        Request::Query { session, tokens, topk } => format!(
            "{{\"id\":{id},\"op\":\"query\",\"session\":{},\"tokens\":{},\"topk\":{topk}}}\n",
            escape(session),
            fmt_tokens(tokens)
        ),
        Request::Stats(q) => {
            let mut s = format!("{{\"id\":{id},\"op\":\"stats\",\"detail\":{}", q.detail);
            if let Some(prefix) = &q.prefix {
                s.push_str(&format!(",\"prefix\":{}", escape(prefix)));
            }
            if let Some(limit) = q.limit {
                s.push_str(&format!(",\"limit\":{limit}"));
            }
            s.push_str("}\n");
            s
        }
        Request::Shutdown => format!("{{\"id\":{id},\"op\":\"shutdown\"}}\n"),
    }
}

/// Decode a request frame into its pipelining id and the request.
pub(crate) fn decode_request(line: &str) -> Result<(u64, Request)> {
    let j = Json::parse(line).context("request frame")?;
    let id = frame_id_of(&j)?;
    let req = Request::from_json(&j).context("request frame body")?;
    Ok((id, req))
}

/// Encode one reply frame. `resp` must be a complete JSON object (every
/// executor reply is); it is embedded verbatim so the bytes the client
/// sees are exactly what the worker's executor produced.
pub(crate) fn encode_reply(id: u64, resp: &str) -> String {
    format!("{{\"id\":{id},\"resp\":{resp}}}\n")
}

/// Decode a reply frame to `(id, resp)`. The envelope layout is fixed
/// (`{"id":N,"resp":...}`, produced only by [`encode_reply`]), so the
/// reply body can be recovered verbatim — no re-rendering — while the
/// embedded-JSON validation still rejects torn or corrupt frames.
pub(crate) fn decode_reply(line: &str) -> Result<(u64, String)> {
    let rest = line.strip_prefix("{\"id\":").ok_or_else(|| anyhow!("not a reply frame"))?;
    let digits = rest.bytes().take_while(|b| b.is_ascii_digit()).count();
    if digits == 0 {
        bail!("reply frame missing id");
    }
    let id: u64 = rest[..digits].parse().context("reply frame id")?;
    let body = rest[digits..]
        .strip_prefix(",\"resp\":")
        .and_then(|b| b.strip_suffix('}'))
        .ok_or_else(|| anyhow!("malformed reply envelope"))?;
    Json::parse(body).context("reply frame body")?;
    Ok((id, body.to_string()))
}

/// Best-effort id extraction from a frame that failed to decode as a
/// request, so the worker can still answer a malformed body instead of
/// dropping it silently (id-less garbage is skipped: resync).
pub(crate) fn frame_id(line: &str) -> Option<u64> {
    let j = Json::parse(line).ok()?;
    frame_id_of(&j).ok()
}

fn frame_id_of(j: &Json) -> Result<u64> {
    let id = j.get("id")?.i64()?;
    if id < 0 {
        bail!("negative frame id {id}");
    }
    Ok(id as u64)
}

// ---------------------------------------------------------------------
// Worker-side reply handle.

/// The worker-process [`Reply`]: tags the executor's reply with the
/// request's pipelining id and hands it to the connection's writer
/// thread, which frames it onto the IPC socket.
#[derive(Clone)]
pub(crate) struct IpcReplyHandle {
    pub(crate) id: u64,
    pub(crate) out: Sender<(u64, String)>,
}

impl IpcReplyHandle {
    pub(crate) fn send(&self, msg: String) -> std::result::Result<(), ()> {
        self.out.send((self.id, msg)).map_err(|_| ())
    }
}

// ---------------------------------------------------------------------
// Per-worker stats (the merged view's `per_worker` rows).

/// Live per-worker supervision counters. The supervisor writes `pid`
/// and `restarts`, the proxy writes `up` and `rtt_micros`, the router
/// renders them into stats.
#[derive(Default)]
pub(crate) struct WorkerSlot {
    /// Live worker process id; 0 while no process is running.
    pub(crate) pid: AtomicU64,
    /// Times the supervisor respawned this worker after an unexpected
    /// exit (the `shard_restarts` counter).
    pub(crate) restarts: AtomicUsize,
    /// Most recent request→reply round trip over the IPC socket, in
    /// microseconds (clamped to >= 1); 0 until the first reply.
    pub(crate) rtt_micros: AtomicU64,
    /// The proxy currently holds a live connection to this worker.
    pub(crate) up: AtomicBool,
}

/// One slot per worker shard; absent entirely for in-process shards.
pub(crate) struct WorkerStatsTable {
    slots: Vec<WorkerSlot>,
}

impl WorkerStatsTable {
    pub(crate) fn new(workers: usize) -> WorkerStatsTable {
        WorkerStatsTable { slots: (0..workers).map(|_| WorkerSlot::default()).collect() }
    }

    pub(crate) fn count(&self) -> usize {
        self.slots.len()
    }

    pub(crate) fn slot(&self, worker: usize) -> &WorkerSlot {
        &self.slots[worker]
    }

    pub(crate) fn total_restarts(&self) -> usize {
        // ordering: monitoring sum; slots may tick mid-scan and an
        // approximate total is fine.
        self.slots.iter().map(|s| s.restarts.load(Ordering::Relaxed)).sum()
    }

    /// Comma-joined JSON rows (the caller wraps them in
    /// `"per_worker":[...]`). `pid`/`rtt_ms` are `null` while the
    /// worker is down / before its first reply.
    pub(crate) fn render_rows(&self) -> String {
        let rows: Vec<String> = self
            .slots
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let pid = match s.pid.load(Ordering::Relaxed) { // ordering: stats snapshot
                    0 => "null".to_string(),
                    p => p.to_string(),
                };
                let rtt = match s.rtt_micros.load(Ordering::Relaxed) { // ordering: stats snapshot
                    0 => "null".to_string(),
                    us => format!("{:.3}", us as f64 / 1e3),
                };
                format!(
                    "{{\"worker\":{i},\"pid\":{pid},\"up\":{},\"restarts\":{},\"rtt_ms\":{rtt}}}",
                    s.up.load(Ordering::Relaxed), // ordering: stats snapshot
                    s.restarts.load(Ordering::Relaxed), // ordering: stats snapshot
                )
            })
            .collect();
        rows.join(",")
    }
}

// ---------------------------------------------------------------------
// The front-end proxy for one worker.

struct PendingRemote {
    reply: Reply,
    shutdown: bool,
    sent_at: Instant,
}

struct ProxyInner {
    /// `Some` while a connection is up: the writer thread's inbox.
    out: Option<Sender<String>>,
    pending: HashMap<u64, PendingRemote>,
    next_id: u64,
}

/// Shutdown-ack ledger of a [`WorkerProxy`]. The serve shell reads it
/// exactly once (`take_drained`, right after the supervisors join),
/// which closes it; a shutdown arriving after that point must be
/// refused — a reply stashed in a closed ledger is never read, which
/// used to park the late requester until the per-request reply timeout.
struct DrainLedger {
    replies: Vec<Reply>,
    closed: bool,
}

/// Front-end endpoint of one worker's IPC connection. Cheap to share
/// (`Arc`); the router dispatches through it, the supervisor attaches
/// and detaches connections around worker lifecycles.
pub(crate) struct WorkerProxy {
    shard: usize,
    inner: Mutex<ProxyInner>,
    table: Arc<WorkerStatsTable>,
    /// A shutdown request has been dispatched to this worker.
    shutdown: AtomicBool,
    /// The worker acked its drain (or died after shutdown was
    /// requested, which drains it maximally: its sessions are gone).
    drain_done: AtomicBool,
    /// Shutdown requesters to ack once the serve shell has released the
    /// listener — the cross-process form of the executor's returned
    /// shutdown repliers.
    drained: Mutex<DrainLedger>,
    /// Connection generation; a reader from epoch E tears down state
    /// only while the proxy is still in epoch E.
    epoch: AtomicU64,
}

impl WorkerProxy {
    pub(crate) fn new(shard: usize, table: Arc<WorkerStatsTable>) -> WorkerProxy {
        WorkerProxy {
            shard,
            inner: Mutex::new(ProxyInner { out: None, pending: HashMap::new(), next_id: 0 }),
            table,
            shutdown: AtomicBool::new(false),
            drain_done: AtomicBool::new(false),
            drained: Mutex::new(DrainLedger { replies: Vec::new(), closed: false }),
            epoch: AtomicU64::new(0),
        }
    }

    pub(crate) fn shard(&self) -> usize {
        self.shard
    }

    pub(crate) fn slot(&self) -> &WorkerSlot {
        self.table.slot(self.shard)
    }

    pub(crate) fn is_up(&self) -> bool {
        self.slot().up.load(Ordering::SeqCst)
    }

    pub(crate) fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    pub(crate) fn drain_done(&self) -> bool {
        self.drain_done.load(Ordering::SeqCst)
    }

    /// The shutdown repliers owed an ack at port release. Closes the
    /// ledger: this runs once, after the supervisors joined, so any
    /// later shutdown is refused by `dispatch` (the connection closes
    /// and EOF is the ack) instead of being stashed where nobody will
    /// ever read it.
    pub(crate) fn take_drained(&self) -> Vec<Reply> {
        let mut ledger = self.drained.lock().unwrap();
        ledger.closed = true;
        std::mem::take(&mut ledger.replies)
    }

    /// Route one request to the worker. `Err` returns the reply so the
    /// router can answer `shard_unavailable` — the worker is down (its
    /// supervisor may yet respawn it; the refusal is immediate either
    /// way, never a hang). Shutdown requests succeed while the drain
    /// ledger is open: delivered over IPC when the worker is up,
    /// recorded as trivially drained when it is down (a dead worker has
    /// nothing left to drain). After the shell has collected the ledger
    /// a shutdown is refused instead — its requester's connection
    /// closes promptly (EOF is the ack), rather than parking until the
    /// reply timeout behind a stash nobody reads anymore.
    ///
    /// Ordering invariant: the `shutdown` flag is published only AFTER
    /// the requester's reply is reachable (inserted into `pending`, or
    /// pushed to `drained`). Supervisors exit on that flag and the
    /// serve shell collects `drained` right after they join, so a
    /// flag-first ordering could let the collection race ahead of the
    /// recording and strand the client's shutdown ack.
    pub(crate) fn dispatch(&self, req: Request, reply: Reply) -> std::result::Result<(), Reply> {
        let is_shutdown = matches!(req, Request::Shutdown);
        let mut inner = self.inner.lock().unwrap();
        let Some(out) = inner.out.clone() else {
            drop(inner);
            if is_shutdown {
                self.stash_drained(reply)?;
                self.drain_done.store(true, Ordering::SeqCst);
                self.shutdown.store(true, Ordering::SeqCst);
                return Ok(());
            }
            return Err(reply);
        };
        let id = inner.next_id;
        inner.next_id += 1;
        let line = encode_request(id, &req);
        inner
            .pending
            .insert(id, PendingRemote { reply, shutdown: is_shutdown, sent_at: Instant::now() });
        if out.send(line).is_err() {
            // Writer raced away between the state check and the send.
            // lint: allow(unwrap) — inserted above under this same
            // lock, so the entry is still there.
            let p = inner.pending.remove(&id).expect("just inserted");
            drop(inner);
            if is_shutdown {
                self.stash_drained(p.reply)?;
                self.drain_done.store(true, Ordering::SeqCst);
                self.shutdown.store(true, Ordering::SeqCst);
                return Ok(());
            }
            return Err(p.reply);
        }
        drop(inner);
        if is_shutdown {
            self.shutdown.store(true, Ordering::SeqCst);
        }
        Ok(())
    }

    /// Record a shutdown requester in the drain ledger. `Err` hands the
    /// reply back when the ledger is already closed — the shell has
    /// collected the acks, so the caller must refuse (which closes the
    /// requester's connection promptly) instead of stranding the reply.
    fn stash_drained(&self, reply: Reply) -> std::result::Result<(), Reply> {
        let mut ledger = self.drained.lock().unwrap();
        if ledger.closed {
            return Err(reply);
        }
        ledger.replies.push(reply);
        Ok(())
    }

    /// Adopt a fresh connection: spawn its writer and reader threads
    /// and flip the proxy `Up`. Any previous epoch's reader becomes
    /// inert (its detach no-ops on the epoch check).
    pub(crate) fn attach(self: &Arc<Self>, stream: TcpStream) -> Result<()> {
        let _ = stream.set_nodelay(true);
        let write_half = stream.try_clone().context("clone worker stream")?;
        let epoch = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
        let (out_tx, out_rx) = channel::<String>();
        {
            let mut inner = self.inner.lock().unwrap();
            inner.out = Some(out_tx);
        }
        self.slot().up.store(true, Ordering::SeqCst);
        let shard = self.shard;
        let proxy = self.clone();
        std::thread::spawn(move || {
            let mut write_half = write_half;
            while let Ok(line) = out_rx.recv() {
                if write_half.write_all(line.as_bytes()).is_err() {
                    break;
                }
            }
            // A write failure means the connection is gone; the reader
            // observes the same and runs the (idempotent) detach.
        });
        std::thread::spawn(move || {
            let mut stream = stream;
            let mut frames = FrameBuf::new(IPC_MAX_FRAME);
            let mut scratch = [0u8; 64 * 1024];
            loop {
                match stream.read(&mut scratch) {
                    Ok(0) => break,
                    Ok(n) => {
                        frames.feed(&scratch[..n]);
                        while let Some(line) = frames.next_line() {
                            match decode_reply(&line) {
                                Ok((id, resp)) => proxy.complete(id, resp),
                                Err(e) => {
                                    // Resync: skip the bad frame, keep
                                    // the connection (its peer is our
                                    // own worker; torn frames cannot
                                    // happen, garbage is logged).
                                    crate::debug!("worker {shard}: bad reply frame: {e:#}");
                                }
                            }
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(_) => break,
                }
            }
            proxy.detach(epoch);
        });
        Ok(())
    }

    /// Complete a pending request with the worker's reply. Unknown ids
    /// (already failed over by a detach) are dropped, mirroring the
    /// reactor dropping late replies for timed-out requests. Shutdown
    /// acks move into `drained` UNDER the state lock, so a supervisor
    /// running `force_detach` + collect after the worker exits can
    /// never observe the ack in neither place (which would lose the
    /// client's shutdown reply).
    fn complete(&self, id: u64, resp: String) {
        let mut inner = self.inner.lock().unwrap();
        let Some(p) = inner.pending.remove(&id) else { return };
        let rtt = p.sent_at.elapsed().as_micros().max(1) as u64;
        // ordering: stats-only gauge read by render_rows; no other
        // state is published through it.
        self.slot().rtt_micros.store(rtt, Ordering::Relaxed);
        if p.shutdown {
            // A closed ledger drops the ack: the late requester's
            // connection is closing, and EOF stands in for the ack.
            let _ = self.stash_drained(p.reply);
            self.drain_done.store(true, Ordering::SeqCst);
        } else {
            drop(inner);
            let _ = p.reply.send(resp);
        }
    }

    /// Tear down epoch `epoch`'s connection state: flip `Down` and fail
    /// every in-flight request with `shard_unavailable` (in-flight
    /// shutdown requesters count as drained — the worker died, taking
    /// every session with it). No-op if a newer connection already
    /// replaced this epoch.
    pub(crate) fn detach(&self, epoch: u64) {
        if self.epoch.load(Ordering::SeqCst) != epoch {
            return;
        }
        let mut failed = Vec::new();
        {
            let mut inner = self.inner.lock().unwrap();
            if inner.out.is_none() {
                return; // already detached
            }
            inner.out = None;
            let mut acked = Vec::new();
            for (_, p) in inner.pending.drain() {
                if p.shutdown {
                    acked.push(p.reply);
                } else {
                    failed.push(p.reply);
                }
            }
            // Shutdown-ack bookkeeping stays under the state lock (see
            // `complete`): once any detach/force_detach returns, every
            // requester is either in `drained` or about to be failed
            // over below — never invisible to a collecting supervisor.
            if !acked.is_empty() {
                let mut ledger = self.drained.lock().unwrap();
                // A closed ledger drops late acks: those requesters'
                // connections close, and EOF stands in for the ack.
                if !ledger.closed {
                    ledger.replies.extend(acked);
                }
            }
            if self.shutdown.load(Ordering::SeqCst) {
                self.drain_done.store(true, Ordering::SeqCst);
            }
        }
        self.slot().up.store(false, Ordering::SeqCst);
        for reply in failed {
            let _ = reply.send(SHARD_UNAVAILABLE.into());
        }
    }

    /// Detach whatever connection is current (supervisor cleanup after
    /// observing the worker process exit; idempotent with the reader's
    /// own EOF detach).
    pub(crate) fn force_detach(&self) {
        self.detach(self.epoch.load(Ordering::SeqCst));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::StatsQuery;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;
    use std::sync::mpsc::channel as mpsc_channel;

    fn arbitrary_request(rng: &mut Rng) -> Request {
        let session = {
            // Exercise ids needing JSON escapes too.
            let alphabet = ["u", "s-1", "Ω", "a b", "q\"uote", "tab\there", "line\nbreak"];
            format!("{}{}", rng.choice(&alphabet), rng.range(0, 1000))
        };
        let tokens: Vec<i32> =
            (0..rng.range(0, 9)).map(|_| rng.range(0, 65_536) as i32 - 32_768).collect();
        match rng.range(0, 4) {
            0 => Request::Context { session, tokens },
            1 => Request::Query { session, tokens, topk: rng.range(1, 64) },
            2 => Request::Stats(StatsQuery {
                detail: rng.bool(0.5),
                prefix: rng.bool(0.5).then(|| format!("p{}", rng.range(0, 10))),
                limit: rng.bool(0.5).then(|| rng.range(0, 100)),
                per_reactor: None,
            }),
            _ => Request::Shutdown,
        }
    }

    fn arbitrary_reply(rng: &mut Rng) -> String {
        match rng.range(0, 3) {
            0 => format!(
                "{{\"ok\":true,\"kind\":\"context\",\"t\":{},\"kv_bytes\":{}}}",
                rng.range(0, 100),
                rng.range(0, 1 << 20)
            ),
            1 => {
                let pairs: Vec<String> = (0..rng.range(1, 6))
                    .map(|_| format!("[{},{:.4}]", rng.range(0, 512), -(rng.f64() * 10.0)))
                    .collect();
                format!("{{\"ok\":true,\"kind\":\"query\",\"next\":[{}]}}", pairs.join(","))
            }
            _ => format!(
                "{{\"ok\":false,\"error\":{}}}",
                escape(&format!("weird \"error\"\nno. {}", rng.range(0, 50)))
            ),
        }
    }

    #[test]
    fn request_frames_roundtrip() {
        check("ipc-request-roundtrip", 200, |rng| {
            let id = rng.next_u64() >> 12; // JSON numbers are f64-exact to 2^53
            let req = arbitrary_request(rng);
            let frame = encode_request(id, &req);
            crate::prop_assert!(frame.ends_with('\n'), "frame must be newline-terminated");
            let (got_id, got) = decode_request(frame.trim_end()).map_err(|e| format!("{e:#}"))?;
            crate::prop_assert!(got_id == id, "id {got_id} != {id}");
            crate::prop_assert!(got == req, "decoded {got:?} != {req:?}");
            Ok(())
        });
    }

    #[test]
    fn reply_frames_roundtrip_verbatim() {
        check("ipc-reply-roundtrip", 200, |rng| {
            let id = rng.next_u64() >> 12;
            let resp = arbitrary_reply(rng);
            let frame = encode_reply(id, &resp);
            let (got_id, got) = decode_reply(frame.trim_end()).map_err(|e| format!("{e:#}"))?;
            crate::prop_assert!(got_id == id, "id {got_id} != {id}");
            crate::prop_assert!(got == resp, "reply body must round-trip verbatim:\n{got}\n{resp}");
            Ok(())
        });
    }

    #[test]
    fn framebuf_reassembles_any_byte_split() {
        // Split a multi-frame stream at EVERY byte boundary: the decoder
        // must recover the identical frame sequence from each split.
        let frames = [
            encode_request(1, &Request::Context { session: "a".into(), tokens: vec![1, 2] }),
            encode_reply(2, "{\"ok\":true,\"kind\":\"query\",\"next\":[[7,-0.5]]}"),
            encode_request(3, &Request::Shutdown),
        ];
        let stream: Vec<u8> = frames.iter().flat_map(|f| f.bytes()).collect();
        let expect: Vec<String> = frames.iter().map(|f| f.trim_end().to_string()).collect();
        for split in 0..=stream.len() {
            let mut fb = FrameBuf::new(IPC_MAX_FRAME);
            let mut got = Vec::new();
            fb.feed(&stream[..split]);
            while let Some(line) = fb.next_line() {
                got.push(line);
            }
            fb.feed(&stream[split..]);
            while let Some(line) = fb.next_line() {
                got.push(line);
            }
            assert_eq!(got, expect, "split at byte {split}");
        }
    }

    #[test]
    fn framebuf_survives_incremental_drip_feeds() {
        check("ipc-drip-feed", 60, |rng| {
            let n = rng.range(1, 8);
            let frames: Vec<String> = (0..n)
                .map(|i| {
                    if rng.bool(0.5) {
                        encode_request(i as u64, &arbitrary_request(rng))
                    } else {
                        encode_reply(i as u64, &arbitrary_reply(rng))
                    }
                })
                .collect();
            let stream: Vec<u8> = frames.iter().flat_map(|f| f.bytes()).collect();
            let mut fb = FrameBuf::new(IPC_MAX_FRAME);
            let mut got = Vec::new();
            let mut i = 0;
            while i < stream.len() {
                let step = rng.range(1, 7).min(stream.len() - i);
                fb.feed(&stream[i..i + step]);
                i += step;
                while let Some(line) = fb.next_line() {
                    got.push(line);
                }
            }
            let expect: Vec<String> = frames.iter().map(|f| f.trim_end().to_string()).collect();
            crate::prop_assert!(got == expect, "drip-fed frames diverged: {got:?} != {expect:?}");
            Ok(())
        });
    }

    #[test]
    fn garbage_prefix_resyncs_at_the_next_newline() {
        check("ipc-garbage-resync", 100, |rng| {
            // Newline-free garbage (newlines would legitimately frame),
            // then a newline, then valid frames: every valid frame must
            // decode; the garbage line must error, not panic or desync.
            let garbage: Vec<u8> = (0..rng.range(1, 200))
                .map(|_| {
                    let b = rng.range(0, 255) as u8;
                    if b == b'\n' {
                        b'x'
                    } else {
                        b
                    }
                })
                .collect();
            let req = arbitrary_request(rng);
            let reply = arbitrary_reply(rng);
            let mut stream = garbage.clone();
            stream.push(b'\n');
            stream.extend_from_slice(encode_request(9, &req).as_bytes());
            stream.extend_from_slice(encode_reply(10, &reply).as_bytes());
            let mut fb = FrameBuf::new(IPC_MAX_FRAME);
            fb.feed(&stream);
            let first = fb.next_line().ok_or("garbage line must frame")?;
            crate::prop_assert!(decode_request(&first).is_err(), "garbage decoded as a request");
            crate::prop_assert!(decode_reply(&first).is_err(), "garbage decoded as a reply");
            let (id, got) = decode_request(&fb.next_line().ok_or("request frame lost")?)
                .map_err(|e| format!("post-garbage request: {e:#}"))?;
            crate::prop_assert!(id == 9 && got == req, "request diverged after resync");
            let (id, got) = decode_reply(&fb.next_line().ok_or("reply frame lost")?)
                .map_err(|e| format!("post-garbage reply: {e:#}"))?;
            crate::prop_assert!(id == 10 && got == reply, "reply diverged after resync");
            crate::prop_assert!(fb.next_line().is_none(), "no trailing frames");
            Ok(())
        });
    }

    #[test]
    fn framebuf_caps_overlong_lines_and_recovers() {
        let mut fb = FrameBuf::new(16);
        // Terminated overlong line: skipped whole.
        fb.feed(b"aaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\nok\n");
        assert_eq!(fb.next_line().as_deref(), Some("ok"));
        assert!(fb.next_line().is_none());
        // Unterminated overlong line: dropped incrementally, resync at
        // the next newline.
        fb.feed(&vec![b'b'; 40]);
        assert!(fb.next_line().is_none());
        fb.feed(&vec![b'c'; 40]);
        assert!(fb.next_line().is_none());
        fb.feed(b"tail\nnext\n");
        // "tail" belongs to the discarded line; "next" frames cleanly.
        assert_eq!(fb.next_line().as_deref(), Some("next"));
        assert!(fb.next_line().is_none());
    }

    #[test]
    fn frame_id_recovers_ids_from_malformed_request_bodies() {
        assert_eq!(frame_id("{\"id\":42,\"op\":\"nope\"}"), Some(42));
        assert_eq!(frame_id("{\"op\":\"stats\"}"), None);
        assert_eq!(frame_id("total garbage"), None);
        assert_eq!(frame_id("{\"id\":-3,\"op\":\"stats\"}"), None);
    }

    #[test]
    fn proxy_down_refuses_and_stashes_shutdown() {
        let table = Arc::new(WorkerStatsTable::new(1));
        let proxy = Arc::new(WorkerProxy::new(0, table));
        // Session-routed work while down: refused (the router turns the
        // returned reply into shard_unavailable).
        let (tx, _rx) = mpsc_channel();
        let req = Request::Query { session: "u".into(), tokens: vec![1], topk: 1 };
        assert!(proxy.dispatch(req, Reply::channel(tx)).is_err());
        // Shutdown while down: accepted, trivially drained, the reply
        // stashed for the port-release ack.
        let (tx, rx) = mpsc_channel();
        assert!(proxy.dispatch(Request::Shutdown, Reply::channel(tx)).is_ok());
        assert!(proxy.drain_done());
        assert!(rx.try_recv().is_err(), "no ack before the port is released");
        assert_eq!(proxy.take_drained().len(), 1);
    }

    #[test]
    fn late_shutdown_after_ledger_collection_is_refused() {
        let table = Arc::new(WorkerStatsTable::new(1));
        let proxy = Arc::new(WorkerProxy::new(0, table));
        // Normal drain: a shutdown while down is stashed, then the
        // serve shell collects the ledger at port release.
        let (tx, _rx) = mpsc_channel();
        assert!(proxy.dispatch(Request::Shutdown, Reply::channel(tx)).is_ok());
        assert_eq!(proxy.take_drained().len(), 1);
        // A late shutdown (a client that raced the drain) must be
        // refused so its connection closes promptly — the pre-fix stash
        // was never read again, parking the client until the reply
        // timeout.
        let (tx, rx) = mpsc_channel();
        assert!(proxy.dispatch(Request::Shutdown, Reply::channel(tx)).is_err());
        assert!(rx.try_recv().is_err(), "no fabricated ack for a refused shutdown");
        assert!(proxy.take_drained().is_empty(), "nothing is stashed after collection");
    }

    // Miri has no socket support; the drain/refusal logic above runs
    // under it, the wire-level test does not.
    #[cfg_attr(miri, ignore)]
    #[test]
    fn proxy_detach_fails_pending_with_shard_unavailable() {
        use std::net::{TcpListener, TcpStream};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (_server_side, _) = listener.accept().unwrap();
        let table = Arc::new(WorkerStatsTable::new(1));
        let proxy = Arc::new(WorkerProxy::new(0, table.clone()));
        proxy.attach(client).unwrap();
        assert!(proxy.is_up());
        let (tx, rx) = mpsc_channel();
        let req = Request::Query { session: "u".into(), tokens: vec![2], topk: 1 };
        assert!(proxy.dispatch(req, Reply::channel(tx)).is_ok());
        assert!(rx.try_recv().is_err(), "no reply yet");
        // The worker "dies": the supervisor force-detaches. The pending
        // request fails over immediately — no hang, no dropped channel.
        proxy.force_detach();
        assert!(!proxy.is_up());
        let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).expect("failover reply");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("error").unwrap().str().unwrap(), "shard_unavailable");
        // And a stale second detach of the same epoch is a no-op.
        proxy.force_detach();
    }

    #[test]
    fn worker_stats_rows_render_valid_json() {
        let table = WorkerStatsTable::new(2);
        table.slot(0).pid.store(4242, Ordering::Relaxed);
        table.slot(0).up.store(true, Ordering::Relaxed);
        table.slot(0).rtt_micros.store(1500, Ordering::Relaxed);
        table.slot(1).restarts.store(3, Ordering::Relaxed);
        assert_eq!(table.total_restarts(), 3);
        let parsed = Json::parse(&format!("[{}]", table.render_rows())).expect("valid JSON");
        let rows = parsed.arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("worker").unwrap().usize().unwrap(), 0);
        assert_eq!(rows[0].get("pid").unwrap().usize().unwrap(), 4242);
        assert_eq!(rows[0].get("up").unwrap(), &Json::Bool(true));
        assert!((rows[0].get("rtt_ms").unwrap().f64().unwrap() - 1.5).abs() < 1e-9);
        assert_eq!(rows[1].get("pid").unwrap(), &Json::Null);
        assert_eq!(rows[1].get("rtt_ms").unwrap(), &Json::Null);
        assert_eq!(rows[1].get("restarts").unwrap().usize().unwrap(), 3);
    }
}
