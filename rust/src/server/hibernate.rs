//! On-disk spill store for hibernated sessions — the middle level of
//! the three-level session lifecycle (hot RAM → disk → gone).
//!
//! Each shard owns a [`SpillStore`] rooted at
//! `<hibernate_dir>/shard-<K>/`; a session's snapshot (the versioned,
//! CRC'd codec in [`crate::model::snapshot`]) lives in one file named
//! by the hex of its session id. Writes are ATOMIC at the file level:
//! the encoded bytes land in a `.tmp` sibling first and are renamed
//! over the final path only when complete, so a worker killed mid-spill
//! leaves either the previous snapshot or none — never a torn one. A
//! startup sweep deletes `.tmp` orphans older than the orphan grace
//! (a younger one may still belong to a predecessor process flushing
//! its last spill).
//!
//! The store does IO only — accounting lives in the session manager's
//! hibernated side-table, and the failure contract (corrupt or missing
//! snapshot == eviction, never a client error) is enforced by the
//! executor, which deletes the bad file and serves a fresh session.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::model::snapshot::SessionSnapshot;

/// Per-shard directory of spilled session snapshots.
pub struct SpillStore {
    dir: PathBuf,
}

impl SpillStore {
    /// Open (creating if needed) the spill directory for one shard.
    pub fn open(root: &Path, shard: usize) -> Result<SpillStore> {
        let dir = shard_dir(root, shard);
        std::fs::create_dir_all(&dir).with_context(|| format!("create spill dir {dir:?}"))?;
        Ok(SpillStore { dir })
    }

    /// Final on-disk path for a session's snapshot.
    pub fn path_for(&self, id: &str) -> PathBuf {
        self.dir.join(format!("{}.snap", encode_id(id)))
    }

    /// Spill one snapshot: encode, write to a `.tmp` sibling, rename
    /// into place. Only after this returns `Ok` may the caller drop the
    /// in-RAM session — a failed spill keeps it hot.
    pub fn spill(&self, snap: &SessionSnapshot) -> Result<()> {
        let bytes = snap.encode()?;
        let path = self.path_for(&snap.id);
        let tmp = tmp_sibling(&path);
        std::fs::write(&tmp, &bytes).with_context(|| format!("write spill tmp {tmp:?}"))?;
        std::fs::rename(&tmp, &path).with_context(|| format!("rename spill into {path:?}"))
    }

    /// Load a session's snapshot. `Ok(None)` means no snapshot exists
    /// (was never spilled, or already discarded); `Err` means the file
    /// exists but is corrupt/unreadable — the caller discards it and
    /// serves a fresh session per the failure contract.
    pub fn load(&self, id: &str) -> Result<Option<SessionSnapshot>> {
        let path = self.path_for(id);
        let bytes = match std::fs::read(&path) {
            Ok(bytes) => bytes,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).with_context(|| format!("read snapshot {path:?}")),
        };
        let snap =
            SessionSnapshot::decode(&bytes).with_context(|| format!("decode snapshot {path:?}"))?;
        if snap.id != id {
            anyhow::bail!("snapshot {path:?} holds session {:?}, expected {id:?}", snap.id);
        }
        Ok(Some(snap))
    }

    /// Remove a session's snapshot (rehydrated, reaped, or corrupt).
    /// Best-effort: a missing file is already the desired state.
    pub fn discard(&self, id: &str) {
        let path = self.path_for(id);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(tmp_sibling(&path));
    }

    /// Number of complete (`.snap`) snapshots currently on disk.
    pub fn snap_count(&self) -> usize {
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return 0;
        };
        entries
            .flatten()
            .filter(|e| e.path().extension().is_some_and(|x| x == "snap"))
            .count()
    }

    /// Startup sweep: delete `.tmp` spill leftovers older than
    /// `older_than` (a crashed predecessor's torn writes). Younger tmp
    /// files are left alone — a lingering predecessor may still rename
    /// one into place. Returns how many files were removed.
    pub fn sweep_stale_tmp(&self, older_than: Duration) -> usize {
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return 0;
        };
        let now = std::time::SystemTime::now();
        let mut removed = 0;
        for entry in entries.flatten() {
            let path = entry.path();
            if !path.extension().is_some_and(|x| x == "tmp") {
                continue;
            }
            let stale = entry
                .metadata()
                .and_then(|m| m.modified())
                .ok()
                .and_then(|mtime| now.duration_since(mtime).ok())
                .is_some_and(|age| age >= older_than);
            if stale && std::fs::remove_file(&path).is_ok() {
                removed += 1;
            }
        }
        removed
    }
}

/// Spill directory for one shard under the hibernation root.
pub fn shard_dir(root: &Path, shard: usize) -> PathBuf {
    root.join(format!("shard-{shard}"))
}

/// Final snapshot path for a session — exposed so tests (and operators)
/// can locate a spilled session's file without a store handle.
pub fn snap_path(root: &Path, shard: usize, id: &str) -> PathBuf {
    shard_dir(root, shard).join(format!("{}.snap", encode_id(id)))
}

/// Filename-safe encoding of a session id: lowercase hex of its bytes.
/// Injective, so distinct ids can never collide on disk regardless of
/// what characters the protocol let through.
pub fn encode_id(id: &str) -> String {
    let mut out = String::with_capacity(id.len() * 2);
    for b in id.as_bytes() {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

fn tmp_sibling(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::strategy::{StrategyKind, StrategyState};
    use crate::memory::{MemBuffers, MemoryStore, UpdateKind};

    fn test_root(case: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ccm-hib-test-{}-{case}", std::process::id()))
    }

    fn sample(id: &str, t: u64) -> SessionSnapshot {
        let elems = 4; // layers 1, slots 2, d_model 2
        SessionSnapshot {
            id: id.into(),
            strategy: StrategyKind::Ccm,
            t,
            pos_cursor: 8 * t,
            created: 1,
            raw_context_tokens: 8 * t,
            dropped_tokens: 0,
            mem: MemoryStore {
                buffers: MemBuffers {
                    k: (0..elems).map(|x| x as f32 + t as f32).collect(),
                    v: (0..elems).map(|x| -(x as f32)).collect(),
                    len: 2,
                    layers: 1,
                    slots: 2,
                    d_model: 2,
                },
                kind: UpdateKind::Concat,
                t: t as usize,
                comp_len: 2,
            },
            state: StrategyState::Ccm,
        }
    }

    #[test]
    fn spill_load_roundtrip_and_missing_is_none() {
        let root = test_root("roundtrip");
        let store = SpillStore::open(&root, 0).unwrap();
        assert!(store.load("ghost").unwrap().is_none(), "missing is None, not an error");
        let snap = sample("user-1", 3);
        store.spill(&snap).unwrap();
        assert_eq!(store.snap_count(), 1);
        let back = store.load("user-1").unwrap().expect("spilled snapshot loads");
        assert_eq!(back.t, 3);
        assert_eq!(back.id, "user-1");
        assert_eq!(back.kv_bytes(), snap.kv_bytes());
        // Re-spill overwrites atomically; the newer state wins.
        store.spill(&sample("user-1", 4)).unwrap();
        assert_eq!(store.load("user-1").unwrap().expect("re-spilled").t, 4);
        assert_eq!(store.snap_count(), 1);
        // Shards are isolated directories.
        let other = SpillStore::open(&root, 1).unwrap();
        assert!(other.load("user-1").unwrap().is_none());
        assert_eq!(store.path_for("user-1"), snap_path(&root, 0, "user-1"));
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn corrupt_snapshot_is_an_error_not_a_panic() {
        let root = test_root("corrupt");
        let store = SpillStore::open(&root, 0).unwrap();
        store.spill(&sample("u", 2)).unwrap();
        let path = store.path_for("u");
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x5A;
        std::fs::write(&path, &bytes).unwrap();
        assert!(store.load("u").is_err(), "corruption surfaces as Err for the caller to discard");
        // Truncation likewise.
        std::fs::write(&path, &bytes[..mid]).unwrap();
        assert!(store.load("u").is_err());
        // Discard restores the missing-is-None state.
        store.discard("u");
        assert!(store.load("u").unwrap().is_none());
        store.discard("u"); // idempotent
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn snapshot_under_wrong_id_is_refused() {
        let root = test_root("wrong-id");
        let store = SpillStore::open(&root, 0).unwrap();
        let snap = sample("alice", 1);
        store.spill(&snap).unwrap();
        // A valid snapshot parked at another id's path must not
        // rehydrate as that session.
        std::fs::rename(store.path_for("alice"), store.path_for("bob")).unwrap();
        assert!(store.load("bob").is_err());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn torn_tmp_is_invisible_and_swept_by_grace() {
        let root = test_root("tmp");
        let store = SpillStore::open(&root, 0).unwrap();
        store.spill(&sample("u", 5)).unwrap();
        // Simulate a SIGKILL mid-spill: a partial tmp next to the old
        // snapshot. Loads see the OLD complete snapshot, never the torn
        // bytes.
        let tmp = tmp_sibling(&store.path_for("u"));
        std::fs::write(&tmp, b"torn partial write").unwrap();
        assert_eq!(store.load("u").unwrap().expect("old snapshot intact").t, 5);
        assert_eq!(store.snap_count(), 1, "tmp files are not snapshots");
        // A generous grace keeps the fresh tmp (its writer may live).
        assert_eq!(store.sweep_stale_tmp(Duration::from_secs(3600)), 0);
        assert!(tmp.exists());
        // Past the grace it is garbage and the sweep removes it.
        assert_eq!(store.sweep_stale_tmp(Duration::ZERO), 1);
        assert!(!tmp.exists());
        assert_eq!(store.load("u").unwrap().expect("snapshot survives the sweep").t, 5);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn id_encoding_is_filename_safe_and_injective() {
        assert_eq!(encode_id("u1"), "7531");
        assert_eq!(encode_id("../evil"), "2e2e2f6576696c", "path metacharacters neutralised");
        assert_ne!(encode_id("ab"), encode_id("ba"));
        let p = snap_path(Path::new("/spool"), 3, "u1");
        assert_eq!(p, PathBuf::from("/spool/shard-3/7531.snap"));
    }
}
