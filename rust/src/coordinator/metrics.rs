//! Serving metrics: request counters, latency accumulators, batch-size
//! histogram, and KV-memory gauges. Printed by `ccm serve` on shutdown
//! and sampled by the throughput benches.

use std::time::Duration;

#[derive(Debug, Default, Clone)]
pub struct LatencyAcc {
    samples_ms: Vec<f64>,
}

impl LatencyAcc {
    pub fn record(&mut self, d: Duration) {
        self.samples_ms.push(d.as_secs_f64() * 1e3);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return f64::NAN;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return f64::NAN;
        }
        let mut v = self.samples_ms.clone();
        // lint: allow(unwrap) — samples are finite duration-derived
        // millisecond values, never NaN, so partial_cmp always orders.
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((v.len() as f64 - 1.0) * p / 100.0).round() as usize;
        v[idx]
    }
}

/// Work counters split by compression strategy tier, indexed by
/// [`crate::compress::StrategyKind::index`]. Session counts and KV
/// bytes per tier are gauges owned by the session manager (census),
/// not accumulated here.
#[derive(Debug, Default, Clone, Copy)]
pub struct StrategyCounters {
    pub compressions: u64,
    pub inferences: u64,
    /// Context tokens dropped by lossy retention (sliding-window tier).
    pub tokens_dropped: u64,
    /// Overload refusals attributed to this tier's sessions.
    pub refusals: u64,
}

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub requests: u64,
    pub compressions: u64,
    pub inferences: u64,
    /// Per-tier split of the compress/infer counters above.
    pub by_strategy: [StrategyCounters; 3],
    pub batches: u64,
    pub batch_sizes: Vec<usize>,
    pub compress_latency: LatencyAcc,
    pub infer_latency: LatencyAcc,
    pub queue_latency: LatencyAcc,
    pub peak_kv_bytes: usize,
    pub tokens_compressed: u64,
    /// Requests refused by admission control (bounded pending queue).
    pub rejected_overload: u64,
    /// Sessions evicted by the global KV-byte budget.
    pub sessions_evicted: u64,
    /// Sessions reaped by the idle TTL.
    pub sessions_reaped: u64,
    /// Sessions spilled to the on-disk hibernation tier.
    pub spills: u64,
    /// Hibernated sessions transparently restored on their next touch.
    pub rehydrations: u64,
    /// Snapshots that failed decode/CRC on rehydrate — each degraded to
    /// a fresh session per the failure contract, never a client error.
    pub snapshot_corrupt: u64,
}

impl Metrics {
    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batch_sizes.push(size);
    }

    pub fn note_kv_bytes(&mut self, bytes: usize) {
        self.peak_kv_bytes = self.peak_kv_bytes.max(bytes);
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return f64::NAN;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} compress={} infer={} batches={} mean_batch={:.1}\n\
             compress: mean {:.2} ms, p95 {:.2} ms ({} calls)\n\
             infer:    mean {:.2} ms, p95 {:.2} ms ({} calls)\n\
             queue:    mean {:.2} ms, p95 {:.2} ms\n\
             overload rejections: {}, sessions evicted: {} (budget) + {} (idle ttl)\n\
             hibernation: {} spills, {} rehydrations, {} corrupt snapshots\n\
             peak compressed-KV: {:.2} MB, tokens compressed: {}",
            self.requests,
            self.compressions,
            self.inferences,
            self.batches,
            self.mean_batch_size(),
            self.compress_latency.mean(),
            self.compress_latency.percentile(95.0),
            self.compress_latency.count(),
            self.infer_latency.mean(),
            self.infer_latency.percentile(95.0),
            self.infer_latency.count(),
            self.queue_latency.mean(),
            self.queue_latency.percentile(95.0),
            self.rejected_overload,
            self.sessions_evicted,
            self.sessions_reaped,
            self.spills,
            self.rehydrations,
            self.snapshot_corrupt,
            self.peak_kv_bytes as f64 / 1e6,
            self.tokens_compressed,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut l = LatencyAcc::default();
        for i in 1..=100 {
            l.record(Duration::from_millis(i));
        }
        assert!((l.mean() - 50.5).abs() < 0.5);
        assert!((l.percentile(95.0) - 95.0).abs() <= 1.0);
        assert_eq!(l.count(), 100);
    }

    #[test]
    fn batch_and_kv_tracking() {
        let mut m = Metrics::default();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.mean_batch_size(), 6.0);
        m.note_kv_bytes(100);
        m.note_kv_bytes(50);
        assert_eq!(m.peak_kv_bytes, 100);
        assert!(m.report().contains("mean_batch=6.0"));
    }
}
