//! Session management: one session per interacting identity (user /
//! task / dialogue), holding its compressed context memory Mem(t) and
//! position cursor. The vLLM-router analogue of per-sequence state.
//!
//! Budget eviction order is pluggable ([`EvictionPolicy`]): oldest
//! created first (the PR 1 behavior and default), least recently used,
//! or cost-aware largest-bytes-first. `ccm serve --eviction <policy>`
//! selects one per serving shard via [`EvictionKind`].
//!
//! The compression strategy is likewise pluggable per session
//! ([`CompressionStrategy`], selected at admission via
//! [`StrategyKind`]): CCM sessions hold Mem(t), sliding-window sessions
//! hold a budgeted raw-token window, no-compress sessions hold the full
//! raw context. [`Session::kv_bytes`] is strategy-aware, so the KV
//! budget evicts cheap tiers later and the full-context tier sooner.
//!
//! Sessions live on three levels: hot RAM, hibernated on disk (the
//! side-table here tracks accounting only — the bytes live in the
//! server's spill store and are excluded from the hot KV budget), or
//! gone. This module stays IO-free: the executor performs the actual
//! spill/rehydrate and tells the manager via [`SessionManager::hibernate`]
//! / [`SessionManager::insert_restored`].

use std::cmp::Ordering;
use std::collections::{HashMap, HashSet};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::compress::strategy::{CompressionStrategy, StrategyKind, StrategyState, Tiers};
use crate::masks::{MergeScheme, Method};
use crate::memory::MemoryStore;
use crate::model::manifest::Manifest;
use crate::model::snapshot::SessionSnapshot;

/// Compression policy a session is created with.
#[derive(Debug, Clone)]
pub struct SessionPolicy {
    pub method: Method,
    pub scheme: MergeScheme,
    pub comp_len: usize,
}

impl SessionPolicy {
    pub fn concat(comp_len: usize) -> SessionPolicy {
        SessionPolicy { method: Method::CcmConcat, scheme: MergeScheme::Avg, comp_len }
    }

    pub fn merge(comp_len: usize) -> SessionPolicy {
        SessionPolicy { method: Method::CcmMerge, scheme: MergeScheme::Avg, comp_len }
    }
}

/// Eviction-candidate ordering under KV-budget pressure. `Less` means
/// `a` is evicted before `b`; implementations must define a total order
/// so the victim sequence is deterministic.
pub trait EvictionPolicy: Send {
    fn name(&self) -> &'static str;
    fn victim_cmp(&self, a: &Session, b: &Session) -> Ordering;
}

/// Evict least-recently-created sessions first (the default).
pub struct OldestCreated;

impl EvictionPolicy for OldestCreated {
    fn name(&self) -> &'static str {
        "oldest"
    }

    fn victim_cmp(&self, a: &Session, b: &Session) -> Ordering {
        a.created.cmp(&b.created)
    }
}

/// Evict least-recently-used sessions first (`last_used` is touched on
/// every create or new work item). Ties break by creation order.
pub struct Lru;

impl EvictionPolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn victim_cmp(&self, a: &Session, b: &Session) -> Ordering {
        a.last_used.cmp(&b.last_used).then(a.created.cmp(&b.created))
    }
}

/// Cost-aware: evict the largest compressed memories first, freeing the
/// budget with the fewest victims. Ties break by creation order.
pub struct LargestBytes;

impl EvictionPolicy for LargestBytes {
    fn name(&self) -> &'static str {
        "largest-bytes"
    }

    fn victim_cmp(&self, a: &Session, b: &Session) -> Ordering {
        b.kv_bytes().cmp(&a.kv_bytes()).then(a.created.cmp(&b.created))
    }
}

/// Config-surface selector for the built-in eviction policies (the
/// `--eviction` CLI flag). Custom policies can still be injected with
/// [`SessionManager::set_eviction`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EvictionKind {
    #[default]
    OldestCreated,
    Lru,
    LargestBytes,
}

impl EvictionKind {
    pub fn parse(name: &str) -> Result<EvictionKind> {
        Ok(match name {
            "oldest" | "oldest-created" => EvictionKind::OldestCreated,
            "lru" => EvictionKind::Lru,
            "largest-bytes" | "largest" => EvictionKind::LargestBytes,
            other => bail!("unknown eviction policy {other:?} (oldest|lru|largest-bytes)"),
        })
    }

    pub fn build(self) -> Box<dyn EvictionPolicy> {
        match self {
            EvictionKind::OldestCreated => Box::new(OldestCreated),
            EvictionKind::Lru => Box::new(Lru),
            EvictionKind::LargestBytes => Box::new(LargestBytes),
        }
    }

    /// Delegates to the policy's own name so the merged stats view and
    /// each shard's stats can never disagree on the label.
    pub fn name(self) -> &'static str {
        self.build().name()
    }
}

#[derive(Debug)]
pub struct Session {
    pub id: String,
    pub mem: MemoryStore,
    /// Next absolute position id (grows chunk by chunk).
    pub pos_cursor: usize,
    /// Online time step t (chunks absorbed).
    pub t: usize,
    pub created: u64,
    /// Wall-clock creation time — drives the `age_ms` session stat.
    pub created_at: Instant,
    /// Raw context tokens absorbed (for KV accounting comparisons).
    pub raw_context_tokens: usize,
    /// Last touch (create or new work) — drives idle-session reaping.
    pub last_used: Instant,
    /// Compression strategy pinned at admission (first touch wins).
    pub strategy: StrategyKind,
    /// Strategy-owned retention state (raw tokens kept verbatim).
    pub state: StrategyState,
    /// Raw tokens dropped by window retention (accounting).
    pub dropped_tokens: u64,
}

impl Session {
    /// Live KV bytes under this session's strategy: compressed memory
    /// plus retained raw tokens at full per-token KV cost. Budget
    /// eviction, stats, and context acks all read this (never
    /// `mem.kv_bytes()` alone), keeping tiers comparable.
    pub fn kv_bytes(&self) -> usize {
        let per_tok = 2 * self.mem.buffers.layers * self.mem.buffers.d_model * 4;
        self.mem.kv_bytes() + self.state.raw_kv_tokens() * per_tok
    }

    /// Capture everything the hibernation tier spills to disk. The
    /// wall-clock fields (`created_at` / `last_used`) are deliberately
    /// absent: a rehydrated session counts as freshly touched.
    pub fn to_snapshot(&self) -> SessionSnapshot {
        SessionSnapshot {
            id: self.id.clone(),
            strategy: self.strategy,
            t: self.t as u64,
            pos_cursor: self.pos_cursor as u64,
            created: self.created,
            raw_context_tokens: self.raw_context_tokens as u64,
            dropped_tokens: self.dropped_tokens,
            mem: self.mem.clone(),
            state: self.state.clone(),
        }
    }

    /// Rebuild a session from a decoded snapshot, resuming at the
    /// pre-spill `t`/`pos_cursor` with clocks re-seeded to now.
    pub fn from_snapshot(snap: SessionSnapshot) -> Session {
        Session {
            id: snap.id,
            mem: snap.mem,
            pos_cursor: snap.pos_cursor as usize,
            t: snap.t as usize,
            created: snap.created,
            created_at: Instant::now(),
            raw_context_tokens: snap.raw_context_tokens as usize,
            last_used: Instant::now(),
            strategy: snap.strategy,
            state: snap.state,
            dropped_tokens: snap.dropped_tokens,
        }
    }
}

/// Accounting stub for a session whose state lives on disk, not in
/// RAM. Its bytes are excluded from the hot KV budget — that is the
/// point of the hibernation tier — but surfaced as gauges in stats.
#[derive(Debug, Clone)]
pub struct HibernatedMeta {
    /// Strategy-aware KV bytes the session held when it was spilled.
    pub kv_bytes: usize,
    /// Creation stamp, preserved across the disk round-trip.
    pub created: u64,
    /// When the spill happened — drives hibernated-session TTL reaping.
    pub since: Instant,
}

/// One session's accounting row for the `stats` detail view (the
/// protocol surfaces it as `sessions_detail`).
pub struct SessionStat {
    pub id: String,
    /// Online time step t (chunks absorbed so far).
    pub t: usize,
    /// Live KV bytes this session currently holds (strategy-aware).
    pub kv_bytes: usize,
    /// Time since the session was created.
    pub age: Duration,
    /// Time since the session was last touched.
    pub idle: Duration,
    /// Compression strategy the session was admitted under.
    pub strategy: StrategyKind,
}

pub struct SessionManager {
    sessions: HashMap<String, Session>,
    hibernated: HashMap<String, HibernatedMeta>,
    policy: SessionPolicy,
    eviction: Box<dyn EvictionPolicy>,
    strategies: [Box<dyn CompressionStrategy>; 3],
    default_strategy: StrategyKind,
    layers: usize,
    d_model: usize,
    mem_slots: usize,
    counter: u64,
}

impl SessionManager {
    pub fn new(manifest: &Manifest) -> SessionManager {
        Self::with_policy(manifest, SessionPolicy::concat(manifest.scenario.comp_len_max))
    }

    pub fn with_policy(manifest: &Manifest, policy: SessionPolicy) -> SessionManager {
        let mem_slots = manifest.scenario.mem_slots;
        SessionManager {
            sessions: HashMap::new(),
            hibernated: HashMap::new(),
            layers: manifest.model.n_layers,
            d_model: manifest.model.d_model,
            mem_slots,
            policy,
            eviction: Box::new(OldestCreated),
            strategies: Self::build_strategies(&Tiers::default(), mem_slots),
            default_strategy: StrategyKind::default(),
            counter: 0,
        }
    }

    fn build_strategies(tiers: &Tiers, mem_slots: usize) -> [Box<dyn CompressionStrategy>; 3] {
        StrategyKind::ALL.map(|k| k.build(tiers.get(k), mem_slots))
    }

    pub fn policy(&self) -> &SessionPolicy {
        &self.policy
    }

    /// Swap the budget-eviction policy (default: [`OldestCreated`]).
    pub fn set_eviction(&mut self, eviction: Box<dyn EvictionPolicy>) {
        self.eviction = eviction;
    }

    pub fn eviction_name(&self) -> &'static str {
        self.eviction.name()
    }

    /// Rebuild the strategy table from a tier config (window budgets).
    /// Existing sessions keep the state they were created with.
    pub fn set_tiers(&mut self, tiers: &Tiers) {
        self.strategies = Self::build_strategies(tiers, self.mem_slots);
    }

    /// Strategy assigned to sessions admitted without an explicit one.
    pub fn set_default_strategy(&mut self, kind: StrategyKind) {
        self.default_strategy = kind;
    }

    pub fn default_strategy(&self) -> StrategyKind {
        self.default_strategy
    }

    /// The built behavior for a strategy kind (the dispatch seam).
    pub fn strategy(&self, kind: StrategyKind) -> &dyn CompressionStrategy {
        &*self.strategies[kind.index()]
    }

    pub fn get_or_create(&mut self, id: &str) -> &mut Session {
        self.get_or_create_with(id, None)
    }

    /// Get a session, creating it under `strategy` (or the manager
    /// default) if absent. An existing session keeps the strategy it
    /// was admitted with — first touch pins it.
    pub fn get_or_create_with(
        &mut self,
        id: &str,
        strategy: Option<StrategyKind>,
    ) -> &mut Session {
        if !self.sessions.contains_key(id) {
            let mem = match self.policy.method {
                Method::CcmMerge => crate::memory::MemoryStore::merge(
                    self.layers,
                    self.mem_slots,
                    self.d_model,
                    self.policy.comp_len,
                    self.policy.scheme,
                ),
                _ => crate::memory::MemoryStore::concat(
                    self.layers,
                    self.mem_slots,
                    self.d_model,
                    self.policy.comp_len,
                ),
            };
            let kind = strategy.unwrap_or(self.default_strategy);
            self.counter += 1;
            self.sessions.insert(
                id.to_string(),
                Session {
                    id: id.to_string(),
                    mem,
                    pos_cursor: 0,
                    t: 0,
                    created: self.counter,
                    created_at: Instant::now(),
                    raw_context_tokens: 0,
                    last_used: Instant::now(),
                    strategy: kind,
                    state: self.strategies[kind.index()].new_state(),
                    dropped_tokens: 0,
                },
            );
        }
        // lint: allow(unwrap) — the branch above inserted the session
        // if it was missing.
        let s = self.sessions.get_mut(id).unwrap();
        s.last_used = Instant::now();
        s
    }

    /// Absorb one context chunk session-locally under the session's
    /// strategy (the non-backend path: sliding-window / no-compress).
    /// Returns how many retained tokens the tier's budget dropped.
    pub fn absorb(&mut self, id: &str, chunk: &[i32]) -> Result<usize> {
        let s = match self.sessions.get_mut(id) {
            Some(s) => s,
            None => bail!("unknown session {id:?}"),
        };
        let dropped = self.strategies[s.strategy.index()].absorb(&mut s.state, chunk);
        s.dropped_tokens += dropped as u64;
        s.t += 1;
        s.raw_context_tokens += chunk.len();
        s.pos_cursor += chunk.len();
        Ok(dropped)
    }

    /// Stage the token stream a query conditions on under the session's
    /// strategy, with the absolute position of its first token.
    pub fn stage_input(
        &self,
        id: &str,
        query: &[i32],
        input_max: usize,
    ) -> Result<(Vec<i32>, usize)> {
        let s = self.get(id)?;
        let tokens = self.strategies[s.strategy.index()].stage_input(&s.state, query, input_max);
        let pos_start = match s.strategy {
            StrategyKind::Ccm => s.pos_cursor,
            _ => (s.raw_context_tokens + query.len()).saturating_sub(tokens.len()),
        };
        Ok((tokens, pos_start))
    }

    /// Per-strategy (session count, live KV bytes) census, indexed by
    /// [`StrategyKind::index`] — the stats view's tier breakdown.
    pub fn census(&self) -> [(usize, usize); 3] {
        let mut out = [(0usize, 0usize); 3];
        for s in self.sessions.values() {
            let i = s.strategy.index();
            out[i].0 += 1;
            out[i].1 += s.kv_bytes();
        }
        out
    }

    pub fn get(&self, id: &str) -> Result<&Session> {
        match self.sessions.get(id) {
            Some(s) => Ok(s),
            None => bail!("unknown session {id:?}"),
        }
    }

    pub fn get_mut(&mut self, id: &str) -> Result<&mut Session> {
        match self.sessions.get_mut(id) {
            Some(s) => Ok(s),
            None => bail!("unknown session {id:?}"),
        }
    }

    pub fn remove(&mut self, id: &str) -> bool {
        self.sessions.remove(id).is_some()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Total live KV bytes across sessions, strategy-aware (capacity
    /// planning — the quantity Table 1's max-batch column is about).
    pub fn total_kv_bytes(&self) -> usize {
        self.sessions.values().map(|s| s.kv_bytes()).sum()
    }

    /// Evict sessions in policy order until at most `max_bytes` of
    /// compressed KV remain. Returns evicted session ids.
    pub fn evict_to_budget(&mut self, max_bytes: usize) -> Vec<String> {
        self.evict_to_budget_protected(max_bytes, &HashSet::new())
    }

    /// Budget eviction skipping `protected` ids (sessions with queued
    /// work). Delegates to [`take_victims_to_budget`](Self::take_victims_to_budget)
    /// and drops the victims' state on the floor.
    pub fn evict_to_budget_protected(
        &mut self,
        max_bytes: usize,
        protected: &HashSet<String>,
    ) -> Vec<String> {
        self.take_victims_to_budget(max_bytes, protected).into_iter().map(|s| s.id).collect()
    }

    /// Remove sessions in [`EvictionPolicy`] victim order until at most
    /// `max_bytes` of live KV remain, returning the victims OWNED so
    /// the caller can spill them to disk before they are dropped
    /// (spill-before-drop). One total-bytes pass + one sort — O(n log n)
    /// for any number of evictions. Each victim frees its strategy-aware
    /// [`Session::kv_bytes`], matching `total_kv_bytes` — subtracting
    /// only the compressed-memory bytes here would over-evict raw-token
    /// tiers.
    pub fn take_victims_to_budget(
        &mut self,
        max_bytes: usize,
        protected: &HashSet<String>,
    ) -> Vec<Session> {
        let mut total = self.total_kv_bytes();
        if total <= max_bytes {
            return Vec::new();
        }
        let mut candidates: Vec<&Session> =
            self.sessions.values().filter(|s| !protected.contains(&s.id)).collect();
        candidates.sort_unstable_by(|a, b| self.eviction.victim_cmp(a, b));
        let order: Vec<String> = candidates.iter().map(|s| s.id.clone()).collect();
        let mut victims = Vec::new();
        for id in order {
            if total <= max_bytes {
                break;
            }
            if let Some(s) = self.sessions.remove(&id) {
                total = total.saturating_sub(s.kv_bytes());
                victims.push(s);
            }
        }
        victims
    }

    /// Move a resident session to the hibernated side-table, dropping
    /// its in-RAM state. Call only AFTER its snapshot's atomic rename
    /// landed on disk — a failed spill must keep the session hot.
    /// Returns the KV bytes released from the hot budget (None if the
    /// id is not resident).
    pub fn hibernate(&mut self, id: &str) -> Option<usize> {
        let s = self.sessions.remove(id)?;
        let bytes = s.kv_bytes();
        let meta = HibernatedMeta { kv_bytes: bytes, created: s.created, since: Instant::now() };
        self.hibernated.insert(s.id, meta);
        Some(bytes)
    }

    /// Record an already-removed session (a spilled eviction victim
    /// from [`take_victims_to_budget`](Self::take_victims_to_budget))
    /// as hibernated.
    pub fn note_hibernated(&mut self, session: &Session) {
        self.hibernated.insert(
            session.id.clone(),
            HibernatedMeta {
                kv_bytes: session.kv_bytes(),
                created: session.created,
                since: Instant::now(),
            },
        );
    }

    /// Re-admit a rehydrated session, clearing its hibernated entry.
    /// The creation counter advances past the restored stamp so
    /// sessions created later still sort as younger.
    pub fn insert_restored(&mut self, session: Session) {
        self.hibernated.remove(&session.id);
        self.counter = self.counter.max(session.created);
        self.sessions.insert(session.id.clone(), session);
    }

    pub fn is_hibernated(&self, id: &str) -> bool {
        self.hibernated.contains_key(id)
    }

    /// Forget a hibernated entry without rehydrating it (corrupt or
    /// missing snapshot — the failure contract degrades to a fresh
    /// session). Returns whether the id was hibernated.
    pub fn drop_hibernated(&mut self, id: &str) -> bool {
        self.hibernated.remove(id).is_some()
    }

    /// (count, bytes) gauges for the hibernated tier — bytes that
    /// become hot again on rehydration, excluded from
    /// [`total_kv_bytes`](Self::total_kv_bytes) by construction.
    pub fn hibernated_census(&self) -> (usize, usize) {
        (self.hibernated.len(), self.hibernated.values().map(|m| m.kv_bytes).sum())
    }

    /// Drop hibernated entries parked on disk for at least `ttl`,
    /// returning their ids in creation order. The caller deletes the
    /// spill files — this table never touches IO.
    pub fn reap_hibernated(&mut self, ttl: Duration, now: Instant) -> Vec<String> {
        let mut stale: Vec<(u64, String)> = self
            .hibernated
            .iter()
            .filter(|(_, m)| now.saturating_duration_since(m.since) >= ttl)
            .map(|(id, m)| (m.created, id.clone()))
            .collect();
        stale.sort_unstable_by_key(|(created, _)| *created);
        let ids: Vec<String> = stale.into_iter().map(|(_, id)| id).collect();
        for id in &ids {
            self.hibernated.remove(id);
        }
        ids
    }

    /// Resident sessions idle for at least `threshold` (skipping
    /// `protected`) in creation order — the background spill candidates.
    pub fn idle_sessions(
        &self,
        threshold: Duration,
        now: Instant,
        protected: &HashSet<String>,
    ) -> Vec<String> {
        let mut idle: Vec<(u64, String)> = self
            .sessions
            .values()
            .filter(|s| !protected.contains(&s.id))
            .filter(|s| now.saturating_duration_since(s.last_used) >= threshold)
            .map(|s| (s.created, s.id.clone()))
            .collect();
        idle.sort_unstable_by_key(|(created, _)| *created);
        idle.into_iter().map(|(_, id)| id).collect()
    }

    /// Remove sessions idle for at least `ttl` (skipping `protected`).
    /// Returns the reaped ids in creation order.
    pub fn reap_idle(
        &mut self,
        ttl: Duration,
        now: Instant,
        protected: &HashSet<String>,
    ) -> Vec<String> {
        let mut idle: Vec<(u64, String)> = self
            .sessions
            .values()
            .filter(|s| !protected.contains(&s.id))
            .filter(|s| now.saturating_duration_since(s.last_used) >= ttl)
            .map(|s| (s.created, s.id.clone()))
            .collect();
        idle.sort_unstable_by_key(|(created, _)| *created);
        let ids: Vec<String> = idle.into_iter().map(|(_, id)| id).collect();
        for id in &ids {
            self.sessions.remove(id);
        }
        ids
    }

    pub fn ids(&self) -> Vec<String> {
        let mut v: Vec<String> = self.sessions.keys().cloned().collect();
        v.sort();
        v
    }

    /// Per-session accounting (age, kv_bytes, last-used idle time) at
    /// `now`, sorted by id for a deterministic stats response.
    /// Saturating arithmetic: a `now` taken before a concurrent touch
    /// degrades to zero, never panics.
    pub fn snapshot(&self, now: Instant) -> Vec<SessionStat> {
        self.snapshot_filtered(now, None, None, None)
    }

    /// [`snapshot`](Self::snapshot) restricted to ids starting with
    /// `prefix` (when set), to ids strictly after the `after_id` cursor
    /// (when set), and truncated to the first `limit` rows by id (when
    /// set) — the stats pagination knobs, so a fleet holding 100k+
    /// resident sessions per process can page through the detail view
    /// with `after_id = last id of the previous page` instead of
    /// re-scanning prefixes.
    pub fn snapshot_filtered(
        &self,
        now: Instant,
        prefix: Option<&str>,
        after_id: Option<&str>,
        limit: Option<usize>,
    ) -> Vec<SessionStat> {
        let mut stats: Vec<SessionStat> = self
            .sessions
            .values()
            .filter(|s| match prefix {
                Some(p) => s.id.starts_with(p),
                None => true,
            })
            .filter(|s| match after_id {
                Some(a) => s.id.as_str() > a,
                None => true,
            })
            .map(|s| SessionStat {
                id: s.id.clone(),
                t: s.t,
                kv_bytes: s.kv_bytes(),
                age: now.saturating_duration_since(s.created_at),
                idle: now.saturating_duration_since(s.last_used),
                strategy: s.strategy,
            })
            .collect();
        stats.sort_unstable_by(|a, b| a.id.cmp(&b.id));
        if let Some(limit) = limit {
            stats.truncate(limit);
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::*;

    fn manifest() -> Manifest {
        Manifest {
            config_name: "toy".into(),
            dir: std::path::PathBuf::from("."),
            model: ModelConfig {
                name: "toy".into(),
                vocab: 256,
                d_model: 8,
                n_layers: 2,
                n_heads: 2,
                d_ff: 16,
                max_pos: 128,
                lora_rank: 2,
                lora_alpha: 4.0,
                pad_id: 0,
                bos_id: 1,
                sep_id: 2,
                comp_id: 3,
                d_head: 4,
            },
            scenario: ScenarioConfig {
                t_max: 4,
                chunk_max: 8,
                comp_len_max: 2,
                input_max: 8,
                seq_train: 64,
                mem_slots: 8,
                batch_train: 2,
                infer_batches: vec![1, 4],
                decode_cache: 16,
                rmt_unroll: 2,
                rmt_mem: 2,
            },
            base_layout: ParamLayout { total: 4, entries: vec![] },
            lora_layout: ParamLayout { total: 4, entries: vec![] },
            artifacts: vec![],
            mask_goldens: vec![],
        }
    }

    fn fake_chunk(layers: usize, cl: usize, d: usize) -> crate::memory::CompressedChunk {
        crate::memory::CompressedChunk {
            k: vec![1.0; layers * cl * d],
            v: vec![1.0; layers * cl * d],
            comp_len: cl,
        }
    }

    #[test]
    fn creates_and_reuses_sessions() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        sm.get_or_create("alice").t = 3;
        assert_eq!(sm.get_or_create("alice").t, 3);
        assert_eq!(sm.len(), 1);
        assert!(sm.get("bob").is_err());
        sm.get_or_create("bob");
        assert_eq!(sm.ids(), vec!["alice", "bob"]);
        assert!(sm.remove("bob"));
        assert!(!sm.remove("bob"));
    }

    #[test]
    fn merge_policy_creates_fixed_memory() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::merge(2));
        let s = sm.get_or_create("u");
        for _ in 0..10 {
            s.mem.update(&fake_chunk(2, 2, 8)).unwrap(); // never overflows
        }
        assert_eq!(s.mem.len(), 2);
    }

    #[test]
    fn kv_budget_eviction_is_oldest_first() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        for id in ["a", "b", "c"] {
            let s = sm.get_or_create(id);
            s.mem.update(&fake_chunk(2, 2, 8)).unwrap();
        }
        let per = 2 * 2 * 2 * 8 * 4;
        assert_eq!(sm.total_kv_bytes(), 3 * per);
        let evicted = sm.evict_to_budget(per);
        assert_eq!(evicted, vec!["a", "b"]);
        assert_eq!(sm.len(), 1);
    }

    #[test]
    fn many_session_eviction_is_creation_ordered_and_exact() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        let n = 200usize;
        for i in 0..n {
            let s = sm.get_or_create(&format!("s{i:03}"));
            s.mem.update(&fake_chunk(2, 2, 8)).unwrap();
        }
        let per = 2 * 2 * 2 * 8 * 4;
        assert_eq!(sm.total_kv_bytes(), n * per);
        // Keep room for 50 sessions: the oldest 150 must go, in order.
        let evicted = sm.evict_to_budget(50 * per);
        assert_eq!(evicted.len(), 150);
        for (i, id) in evicted.iter().enumerate() {
            assert_eq!(id, &format!("s{i:03}"));
        }
        assert_eq!(sm.len(), 50);
        assert!(sm.total_kv_bytes() <= 50 * per);
        assert!(sm.get("s150").is_ok() && sm.get("s149").is_err());
    }

    #[test]
    fn protected_sessions_survive_budget_eviction() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        for id in ["a", "b", "c"] {
            sm.get_or_create(id).mem.update(&fake_chunk(2, 2, 8)).unwrap();
        }
        let protected: std::collections::HashSet<String> = ["a".to_string()].into_iter().collect();
        let evicted = sm.evict_to_budget_protected(0, &protected);
        assert_eq!(evicted, vec!["b", "c"]);
        assert!(sm.get("a").is_ok());
    }

    #[test]
    fn lru_eviction_spares_recently_used_sessions() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        sm.set_eviction(EvictionKind::Lru.build());
        assert_eq!(sm.eviction_name(), "lru");
        for id in ["a", "b", "c"] {
            sm.get_or_create(id).mem.update(&fake_chunk(2, 2, 8)).unwrap();
        }
        // Touch "a" (oldest-created) well after the others: under LRU it
        // must survive while "b" and "c" go; under oldest-created it
        // would be the first victim. Set last_used explicitly so the
        // test does not depend on clock resolution.
        sm.get_mut("a").unwrap().last_used = Instant::now() + Duration::from_secs(60);
        let per = 2 * 2 * 2 * 8 * 4;
        let evicted = sm.evict_to_budget(per);
        assert_eq!(evicted, vec!["b", "c"]);
        assert!(sm.get("a").is_ok());
    }

    #[test]
    fn largest_bytes_eviction_frees_budget_with_fewest_victims() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        sm.set_eviction(EvictionKind::LargestBytes.build());
        // "small" holds one chunk, "big" three, "mid" two: the policy
        // must take "big" first even though "small" is oldest.
        for (id, chunks) in [("small", 1), ("big", 3), ("mid", 2)] {
            let s = sm.get_or_create(id);
            for _ in 0..chunks {
                s.mem.update(&fake_chunk(2, 2, 8)).unwrap();
            }
        }
        let per = 2 * 2 * 2 * 8 * 4;
        assert_eq!(sm.total_kv_bytes(), 6 * per);
        let evicted = sm.evict_to_budget(3 * per);
        assert_eq!(evicted, vec!["big"]);
        assert!(sm.get("small").is_ok() && sm.get("mid").is_ok());
    }

    #[test]
    fn eviction_kind_parses_and_names() {
        for (s, k) in [
            ("oldest", EvictionKind::OldestCreated),
            ("oldest-created", EvictionKind::OldestCreated),
            ("lru", EvictionKind::Lru),
            ("largest-bytes", EvictionKind::LargestBytes),
            ("largest", EvictionKind::LargestBytes),
        ] {
            assert_eq!(EvictionKind::parse(s).unwrap(), k);
        }
        assert!(EvictionKind::parse("random").is_err());
        assert_eq!(EvictionKind::default(), EvictionKind::OldestCreated);
        assert_eq!(EvictionKind::Lru.name(), "lru");
        assert_eq!(EvictionKind::Lru.build().name(), "lru");
    }

    #[test]
    fn snapshot_reports_sorted_per_session_accounting() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        for (id, chunks) in [("zed", 1), ("ann", 2)] {
            let s = sm.get_or_create(id);
            for _ in 0..chunks {
                s.mem.update(&fake_chunk(2, 2, 8)).unwrap();
            }
            s.t = chunks;
        }
        let now = Instant::now() + Duration::from_millis(50);
        let stats = sm.snapshot(now);
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].id, "ann");
        assert_eq!(stats[1].id, "zed");
        assert_eq!(stats[0].t, 2);
        let per = 2 * 2 * 2 * 8 * 4;
        assert_eq!(stats[0].kv_bytes, 2 * per);
        assert_eq!(stats[1].kv_bytes, per);
        for s in &stats {
            assert!(s.age >= Duration::from_millis(50), "age measured from creation");
            assert!(s.idle <= s.age, "a session cannot be idle longer than it exists");
        }
    }

    #[test]
    fn snapshot_filtered_applies_prefix_then_limit_by_id() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        for id in ["user-3", "user-1", "admin-1", "user-2"] {
            sm.get_or_create(id);
        }
        let now = Instant::now();
        // Prefix restricts; rows stay id-sorted.
        let stats = sm.snapshot_filtered(now, Some("user-"), None, None);
        let ids: Vec<&str> = stats.iter().map(|s| s.id.as_str()).collect();
        assert_eq!(ids, vec!["user-1", "user-2", "user-3"]);
        // Limit truncates AFTER the sort: the first N by id, not an
        // arbitrary hash-order subset.
        let stats = sm.snapshot_filtered(now, Some("user-"), None, Some(2));
        let ids: Vec<&str> = stats.iter().map(|s| s.id.as_str()).collect();
        assert_eq!(ids, vec!["user-1", "user-2"]);
        // No prefix match: empty, not an error.
        assert!(sm.snapshot_filtered(now, Some("zzz"), None, None).is_empty());
        // A zero limit is honored (count-only probes stay cheap).
        assert!(sm.snapshot_filtered(now, None, None, Some(0)).is_empty());
        // Unfiltered delegation matches snapshot().
        assert_eq!(sm.snapshot(now).len(), 4);
    }

    #[test]
    fn snapshot_after_id_cursor_pages_without_rescanning() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        for i in 0..7 {
            sm.get_or_create(&format!("u{i}"));
        }
        let now = Instant::now();
        // Page through with limit 3, resuming from the last id seen.
        let page1 = sm.snapshot_filtered(now, None, None, Some(3));
        let ids: Vec<&str> = page1.iter().map(|s| s.id.as_str()).collect();
        assert_eq!(ids, vec!["u0", "u1", "u2"]);
        let page2 = sm.snapshot_filtered(now, None, Some("u2"), Some(3));
        let ids: Vec<&str> = page2.iter().map(|s| s.id.as_str()).collect();
        assert_eq!(ids, vec!["u3", "u4", "u5"]);
        let page3 = sm.snapshot_filtered(now, None, Some("u5"), Some(3));
        let ids: Vec<&str> = page3.iter().map(|s| s.id.as_str()).collect();
        assert_eq!(ids, vec!["u6"], "final partial page");
        // Cursor is strict: the boundary id itself never repeats.
        assert!(sm.snapshot_filtered(now, None, Some("u6"), None).is_empty());
        // Cursor composes with prefix.
        sm.get_or_create("admin-1");
        let page = sm.snapshot_filtered(now, Some("u"), Some("u4"), None);
        let ids: Vec<&str> = page.iter().map(|s| s.id.as_str()).collect();
        assert_eq!(ids, vec!["u5", "u6"]);
    }

    #[test]
    fn strategies_pin_at_admission_and_cost_kv_by_tier() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        let per_tok = 2 * 2 * 8 * 4; // 2 layers, d_model 8, f32 K+V
        // No-compress: every raw token is retained and costed.
        let s = sm.get_or_create_with("full", Some(StrategyKind::NoCompress));
        assert_eq!(s.strategy, StrategyKind::NoCompress);
        sm.absorb("full", &[1, 2, 3]).unwrap();
        let s = sm.get("full").unwrap();
        assert_eq!(s.t, 1);
        assert_eq!(s.raw_context_tokens, 3);
        assert_eq!(s.kv_bytes(), 3 * per_tok);
        // Sliding-window: retention capped at mem_slots (8) tokens.
        sm.get_or_create_with("win", Some(StrategyKind::SlidingWindow));
        sm.absorb("win", &(0..20).collect::<Vec<i32>>()).unwrap();
        let s = sm.get("win").unwrap();
        assert_eq!(s.kv_bytes(), 8 * per_tok);
        assert_eq!(s.dropped_tokens, 12);
        // First touch pins the strategy: a later explicit kind is ignored.
        let s = sm.get_or_create_with("full", Some(StrategyKind::Ccm));
        assert_eq!(s.strategy, StrategyKind::NoCompress);
        // Default-strategy sessions are CCM and retain nothing raw.
        let s = sm.get_or_create("plain");
        assert_eq!(s.strategy, StrategyKind::Ccm);
        assert_eq!(s.kv_bytes(), 0);
        // Census: per-tier session counts and KV bytes.
        let census = sm.census();
        assert_eq!(census[StrategyKind::Ccm.index()], (1, 0));
        assert_eq!(census[StrategyKind::SlidingWindow.index()], (1, 8 * per_tok));
        assert_eq!(census[StrategyKind::NoCompress.index()], (1, 3 * per_tok));
        // Detail rows carry the tier label.
        let stats = sm.snapshot(Instant::now());
        let full = stats.iter().find(|s| s.id == "full").unwrap();
        assert_eq!(full.strategy, StrategyKind::NoCompress);
        assert_eq!(full.kv_bytes, 3 * per_tok);
    }

    #[test]
    fn stage_input_conditions_on_retained_context() {
        let m = manifest(); // input_max 8
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        sm.get_or_create_with("full", Some(StrategyKind::NoCompress));
        sm.absorb("full", &[1, 2, 3, 4, 5, 6]).unwrap();
        let (toks, pos) = sm.stage_input("full", &[7, 8], 8).unwrap();
        assert_eq!(toks, vec![1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(pos, 0);
        // Clamped to the newest input_max tokens, position advances.
        let (toks, pos) = sm.stage_input("full", &[7, 8, 9], 4).unwrap();
        assert_eq!(toks, vec![6, 7, 8, 9]);
        assert_eq!(pos, 5);
        // CCM stages the query alone at the memory's position cursor.
        sm.get_or_create("ccm");
        let (toks, pos) = sm.stage_input("ccm", &[9], 8).unwrap();
        assert_eq!(toks, vec![9]);
        assert_eq!(pos, 0);
        assert!(sm.stage_input("ghost", &[1], 8).is_err());
    }

    #[test]
    fn budget_eviction_prefers_expensive_full_context_tier() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        sm.set_eviction(EvictionKind::LargestBytes.build());
        // An old CCM session with one compressed chunk vs a newer
        // full-context session holding many raw tokens: cost-aware
        // eviction must take the expensive tier first.
        sm.get_or_create("ccm").mem.update(&fake_chunk(2, 2, 8)).unwrap();
        sm.get_or_create_with("full", Some(StrategyKind::NoCompress));
        sm.absorb("full", &(0..64).collect::<Vec<i32>>()).unwrap();
        let ccm_bytes = sm.get("ccm").unwrap().kv_bytes();
        assert!(sm.get("full").unwrap().kv_bytes() > ccm_bytes);
        let evicted = sm.evict_to_budget(ccm_bytes);
        assert_eq!(evicted, vec!["full"]);
        assert!(sm.get("ccm").is_ok());
    }

    #[test]
    fn hibernate_excludes_bytes_and_restore_resumes_at_same_t() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        let s = sm.get_or_create("cold");
        s.mem.update(&fake_chunk(2, 2, 8)).unwrap();
        s.t = 5;
        s.pos_cursor = 40;
        let per = 2 * 2 * 2 * 8 * 4;
        assert_eq!(sm.total_kv_bytes(), per);
        // Spill path: snapshot first (executor writes it to disk), then
        // move the session to the side-table.
        let snap = sm.get("cold").unwrap().to_snapshot();
        assert_eq!(sm.hibernate("cold"), Some(per));
        assert_eq!(sm.hibernate("cold"), None, "not resident twice");
        assert_eq!(sm.len(), 0, "hibernated sessions leave the hot map");
        assert_eq!(sm.total_kv_bytes(), 0, "bytes leave the hot KV budget");
        assert!(sm.is_hibernated("cold"));
        assert_eq!(sm.hibernated_census(), (1, per));
        assert!(sm.get("cold").is_err(), "hot lookups miss while on disk");
        // Rehydrate: the session resumes at its pre-spill cursor.
        let restored = Session::from_snapshot(snap);
        sm.insert_restored(restored);
        assert!(!sm.is_hibernated("cold"));
        assert_eq!(sm.hibernated_census(), (0, 0));
        let s = sm.get("cold").unwrap();
        assert_eq!((s.t, s.pos_cursor), (5, 40));
        assert_eq!(s.kv_bytes(), per);
        // Creation order survives the round-trip: a session created
        // after restore is younger than the restored one.
        let old_created = sm.get("cold").unwrap().created;
        let newer = sm.get_or_create("later").created;
        assert!(newer > old_created);
    }

    #[test]
    fn snapshot_bridge_round_trips_window_state() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        sm.get_or_create_with("win", Some(StrategyKind::SlidingWindow));
        sm.absorb("win", &(0..20).collect::<Vec<i32>>()).unwrap();
        let before = sm.get("win").unwrap();
        let bytes = before.to_snapshot().encode().unwrap();
        let snap = crate::model::snapshot::SessionSnapshot::decode(&bytes).unwrap();
        let after = Session::from_snapshot(snap);
        assert_eq!(after.strategy, StrategyKind::SlidingWindow);
        assert_eq!(after.t, before.t);
        assert_eq!(after.kv_bytes(), before.kv_bytes());
        assert_eq!(after.dropped_tokens, before.dropped_tokens);
        assert_eq!(after.state.raw_kv_tokens(), before.state.raw_kv_tokens());
    }

    #[test]
    fn take_victims_subtracts_strategy_aware_bytes() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        // Two full-context sessions; evicting the first must free its
        // raw-token bytes, leaving the second resident under a budget
        // sized for exactly one of them.
        for id in ["a", "b"] {
            sm.get_or_create_with(id, Some(StrategyKind::NoCompress));
            sm.absorb(id, &(0..8).collect::<Vec<i32>>()).unwrap();
        }
        let one = sm.get("a").unwrap().kv_bytes();
        let victims = sm.take_victims_to_budget(one, &HashSet::new());
        let ids: Vec<&str> = victims.iter().map(|s| s.id.as_str()).collect();
        assert_eq!(ids, vec!["a"], "one victim frees enough — not both");
        assert_eq!(victims[0].t, 1, "victims come out owned, state intact");
        assert!(sm.get("b").is_ok());
        // Spill-before-drop: the caller can park the victim instead.
        sm.note_hibernated(&victims[0]);
        assert!(sm.is_hibernated("a"));
        assert_eq!(sm.hibernated_census(), (1, one));
        assert!(sm.drop_hibernated("a"));
        assert!(!sm.drop_hibernated("a"));
    }

    #[test]
    fn reap_hibernated_and_idle_candidates_are_creation_ordered() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        for id in ["one", "two", "three"] {
            sm.get_or_create(id);
        }
        // All three idle well past the threshold; candidates come back
        // in creation order regardless of map iteration order.
        let eval_at = Instant::now() + Duration::from_secs(30);
        let idle = sm.idle_sessions(Duration::from_secs(10), eval_at, &HashSet::new());
        assert_eq!(idle, vec!["one", "two", "three"]);
        let protected: HashSet<String> = ["two".to_string()].into_iter().collect();
        let idle = sm.idle_sessions(Duration::from_secs(10), eval_at, &protected);
        assert_eq!(idle, vec!["one", "three"], "protected sessions never spill");
        assert!(
            sm.idle_sessions(Duration::from_secs(60), eval_at, &HashSet::new()).is_empty(),
            "threshold not yet reached"
        );
        // Hibernate all three, then TTL-reap the side-table.
        for id in ["one", "two", "three"] {
            sm.hibernate(id);
        }
        assert_eq!(sm.hibernated_census().0, 3);
        let reaped = sm.reap_hibernated(Duration::from_secs(10), eval_at);
        assert_eq!(reaped, vec!["one", "two", "three"]);
        assert_eq!(sm.hibernated_census(), (0, 0));
    }

    #[test]
    fn reap_idle_uses_last_used_and_protection() {
        let m = manifest();
        let mut sm = SessionManager::with_policy(&m, SessionPolicy::concat(2));
        sm.get_or_create("stale");
        sm.get_or_create("fresh");
        sm.get_or_create("pinned");
        // Evaluate "now" in the future instead of backdating last_used
        // (Instant cannot always represent times before process start).
        let eval_at = Instant::now() + Duration::from_secs(120);
        sm.get_or_create("fresh").last_used = eval_at;
        let protected: std::collections::HashSet<String> =
            ["pinned".to_string()].into_iter().collect();
        let reaped = sm.reap_idle(Duration::from_secs(60), eval_at, &protected);
        assert_eq!(reaped, vec!["stale"]);
        assert!(sm.get("fresh").is_ok() && sm.get("pinned").is_ok());
        assert!(sm.get("stale").is_err());
    }
}
