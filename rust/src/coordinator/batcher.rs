//! Dynamic batcher: groups pending compression / inference work into
//! artifact-sized batches while preserving per-session ordering.
//!
//! Ordering invariant: work items of one session execute in submission
//! order (an inference that depends on a pending compression never jumps
//! the queue). Batches are homogeneous in kind because the two artifacts
//! differ. Flush policy: size-triggered or age-triggered (max_wait).

use std::collections::{HashSet, VecDeque};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkKind {
    Compress,
    Infer,
}

#[derive(Debug, Clone)]
pub struct WorkItem {
    pub seq: u64,
    pub session: String,
    pub kind: WorkKind,
    pub tokens: Vec<i32>,
    pub submitted: Instant,
}

#[derive(Debug)]
pub struct Batcher {
    queue: VecDeque<WorkItem>,
    next_seq: u64,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        assert!(max_batch >= 1);
        Batcher { queue: VecDeque::new(), next_seq: 0, max_batch, max_wait }
    }

    /// Enqueue; returns the work-item sequence id.
    pub fn push(&mut self, session: &str, kind: WorkKind, tokens: Vec<i32>) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back(WorkItem {
            seq,
            session: session.to_string(),
            kind,
            tokens,
            submitted: Instant::now(),
        });
        seq
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Would a batch be emitted right now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.max_batch {
            return true;
        }
        self.queue
            .front()
            .map(|w| now.duration_since(w.submitted) >= self.max_wait)
            .unwrap_or(false)
    }

    /// Pop the next homogeneous batch (up to max_batch items of the
    /// front item's kind), skipping items whose session has an earlier
    /// still-queued item of another kind — those stay queued, and the
    /// session is "blocked" for the rest of this scan.
    pub fn next_batch(&mut self, now: Instant, force: bool) -> Option<Vec<WorkItem>> {
        if self.queue.is_empty() || (!force && !self.ready(now)) {
            return None;
        }
        let kind = self.queue.front().unwrap().kind;
        let mut blocked: HashSet<String> = HashSet::new();
        let mut taken_idx = Vec::new();
        for (i, w) in self.queue.iter().enumerate() {
            if taken_idx.len() == self.max_batch {
                break;
            }
            if blocked.contains(&w.session) {
                continue;
            }
            if w.kind == kind {
                taken_idx.push(i);
            } else {
                // This session has an unexecuted earlier item of the other
                // kind — later items of this session must wait.
                blocked.insert(w.session.clone());
            }
        }
        let mut batch = Vec::with_capacity(taken_idx.len());
        // Remove back-to-front so indices stay valid.
        for &i in taken_idx.iter().rev() {
            batch.push(self.queue.remove(i).unwrap());
        }
        batch.reverse();
        debug_assert!(!batch.is_empty());
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item_kinds(b: &[WorkItem]) -> Vec<WorkKind> {
        b.iter().map(|w| w.kind).collect()
    }

    #[test]
    fn batches_are_homogeneous_and_fifo() {
        let mut b = Batcher::new(4, Duration::ZERO);
        b.push("a", WorkKind::Compress, vec![1]);
        b.push("b", WorkKind::Compress, vec![2]);
        b.push("c", WorkKind::Infer, vec![3]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Compress; 2]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Infer]);
        assert!(b.next_batch(Instant::now(), true).is_none());
    }

    #[test]
    fn session_order_is_preserved() {
        let mut b = Batcher::new(8, Duration::ZERO);
        b.push("s", WorkKind::Compress, vec![1]);
        b.push("s", WorkKind::Infer, vec![2]); // depends on the compress
        b.push("t", WorkKind::Compress, vec![3]);
        b.push("s", WorkKind::Compress, vec![4]); // after s's infer!
        let batch = b.next_batch(Instant::now(), true).unwrap();
        // s's later compress must NOT ride along: s is blocked by its infer.
        let sessions: Vec<&str> = batch.iter().map(|w| w.session.as_str()).collect();
        assert_eq!(sessions, vec!["s", "t"]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Infer]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(batch[0].tokens, vec![4]);
    }

    #[test]
    fn size_and_age_triggers() {
        let mut b = Batcher::new(2, Duration::from_millis(50));
        b.push("a", WorkKind::Infer, vec![]);
        let now = Instant::now();
        assert!(!b.ready(now));
        assert!(b.next_batch(now, false).is_none());
        b.push("b", WorkKind::Infer, vec![]);
        assert!(b.ready(now)); // size trigger
        assert_eq!(b.next_batch(now, false).unwrap().len(), 2);
        b.push("c", WorkKind::Infer, vec![]);
        let later = now + Duration::from_millis(100);
        assert!(b.ready(later)); // age trigger
    }

    #[test]
    fn property_every_item_emitted_once_in_session_order() {
        crate::util::proptest::check("batcher-order", 60, |rng| {
            let max_batch = rng.range(1, 6);
            let mut b = Batcher::new(max_batch, Duration::ZERO);
            let sessions = ["s0", "s1", "s2"];
            let n = rng.range(1, 40);
            let mut submitted: Vec<(u64, String)> = Vec::new();
            for _ in 0..n {
                let s = sessions[rng.range(0, 3)];
                let kind = if rng.bool(0.5) { WorkKind::Compress } else { WorkKind::Infer };
                let seq = b.push(s, kind, vec![]);
                submitted.push((seq, s.to_string()));
            }
            let mut emitted: Vec<WorkItem> = Vec::new();
            let mut guard = 0;
            while b.pending() > 0 {
                guard += 1;
                crate::prop_assert!(guard < 1000, "batcher stuck");
                let batch = b.next_batch(Instant::now(), true).unwrap();
                crate::prop_assert!(batch.len() <= max_batch, "batch too big");
                let k = batch[0].kind;
                crate::prop_assert!(
                    batch.iter().all(|w| w.kind == k),
                    "mixed-kind batch"
                );
                emitted.extend(batch);
            }
            crate::prop_assert!(emitted.len() == n, "lost items: {} != {n}", emitted.len());
            // Per-session sequence ids must be strictly increasing.
            for s in sessions {
                let seqs: Vec<u64> =
                    emitted.iter().filter(|w| w.session == s).map(|w| w.seq).collect();
                crate::prop_assert!(
                    seqs.windows(2).all(|w| w[0] < w[1]),
                    "session {s} out of order: {seqs:?}"
                );
            }
            Ok(())
        });
    }
}
