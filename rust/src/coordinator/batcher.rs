//! Dynamic batcher: groups pending compression / inference work into
//! artifact-sized batches while preserving per-session ordering.
//!
//! Ordering invariant: work items of one session execute in submission
//! order (an inference that depends on a pending compression never jumps
//! the queue), and a batch holds AT MOST ONE item per session — batch
//! staging snapshots session state (Mem(t-1), pos_cursor) before
//! execution, so a second same-session item in one batch would read
//! stale memory and clash on positions. Batches are homogeneous in kind
//! because the two artifacts differ. Flush policy: size-triggered or
//! age-triggered (max_wait).
//!
//! Scheduling policy: plain FIFO by default. With `infer_priority` set
//! (the serving engine turns it on), ready inference batches are emitted
//! ahead of unrelated sessions' compression backlog — queries are
//! latency-sensitive, compressions are throughput work — while the
//! per-session ordering invariant still holds (an infer never overtakes
//! its own session's queued compress). A consecutive-override cap
//! bounds compress starvation under sustained query load: after
//! `PRIORITY_OVERRIDE_LIMIT` infer batches jump the front, one front
//! batch is forced through, guaranteeing the backlog a fixed share.

use std::collections::{HashSet, VecDeque};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkKind {
    Compress,
    Infer,
}

#[derive(Debug, Clone)]
pub struct WorkItem {
    pub seq: u64,
    pub session: String,
    pub kind: WorkKind,
    pub tokens: Vec<i32>,
    pub submitted: Instant,
}

/// Max consecutive batches that may jump ahead of the front item's
/// kind before fairness forces the front through (bounds starvation).
const PRIORITY_OVERRIDE_LIMIT: u32 = 4;

#[derive(Debug)]
pub struct Batcher {
    queue: VecDeque<WorkItem>,
    next_seq: u64,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Emit ready infer batches ahead of unrelated compress backlog.
    pub infer_priority: bool,
    /// Consecutive emissions that overrode the front item's kind.
    overrides: u32,
    /// Lifetime count of priority overrides (surfaced in serve stats).
    overrides_total: u64,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        assert!(max_batch >= 1);
        Batcher {
            queue: VecDeque::new(),
            next_seq: 0,
            max_batch,
            max_wait,
            infer_priority: false,
            overrides: 0,
            overrides_total: 0,
        }
    }

    /// Total priority overrides emitted over this batcher's lifetime
    /// (how often a ready infer batch jumped the compress backlog).
    pub fn total_overrides(&self) -> u64 {
        self.overrides_total
    }

    /// Enqueue; returns the work-item sequence id.
    pub fn push(&mut self, session: &str, kind: WorkKind, tokens: Vec<i32>) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back(WorkItem {
            seq,
            session: session.to_string(),
            kind,
            tokens,
            submitted: Instant::now(),
        });
        seq
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Queued (unexecuted) items of `kind` for one session. The serving
    /// front-end uses this to ack context chunks with the time step they
    /// will actually land on (t+1, t+2, ... for chunks queued together).
    pub fn queued_for(&self, session: &str, kind: WorkKind) -> usize {
        self.queue.iter().filter(|w| w.kind == kind && w.session == session).count()
    }

    /// Sessions with any queued work (memory governance must not evict
    /// these: their queued items reference session state).
    pub fn pending_sessions(&self) -> HashSet<String> {
        self.queue.iter().map(|w| w.session.clone()).collect()
    }

    /// Would a batch be emitted right now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.max_batch {
            return true;
        }
        self.queue
            .front()
            .map(|w| now.duration_since(w.submitted) >= self.max_wait)
            .unwrap_or(false)
    }

    /// Batch kind for the next emission. FIFO: the front item's kind.
    /// With `infer_priority`: Infer, if some queued infer is executable
    /// (no earlier same-session compress) — unless the last
    /// `PRIORITY_OVERRIDE_LIMIT` emissions already jumped the front, in
    /// which case fairness forces the front through.
    fn pick_kind(&self) -> WorkKind {
        // lint: allow(unwrap) — only called from next_batch after its
        // queue-empty early return, so the front exists.
        let front = self.queue.front().unwrap();
        if !self.infer_priority || front.kind == WorkKind::Infer {
            return front.kind;
        }
        if self.overrides >= PRIORITY_OVERRIDE_LIMIT {
            return front.kind; // anti-starvation: the backlog gets a turn
        }
        let mut blocked: HashSet<&str> = HashSet::new();
        for w in &self.queue {
            match w.kind {
                WorkKind::Infer if !blocked.contains(w.session.as_str()) => {
                    return WorkKind::Infer;
                }
                WorkKind::Infer => {}
                WorkKind::Compress => {
                    blocked.insert(w.session.as_str());
                }
            }
        }
        front.kind
    }

    /// Pop the next homogeneous batch (up to max_batch items of the
    /// picked kind), skipping items whose session has an earlier
    /// still-queued item of another kind — those stay queued, and the
    /// session is "blocked" for the rest of this scan.
    pub fn next_batch(&mut self, now: Instant, force: bool) -> Option<Vec<WorkItem>> {
        if self.queue.is_empty() || (!force && !self.ready(now)) {
            return None;
        }
        let kind = self.pick_kind();
        // lint: allow(unwrap) — the queue-empty case returned above.
        if kind == self.queue.front().unwrap().kind {
            self.overrides = 0;
        } else {
            self.overrides += 1;
            self.overrides_total += 1;
        }
        let mut blocked: HashSet<String> = HashSet::new();
        let mut taken: HashSet<String> = HashSet::new();
        let mut taken_idx = Vec::new();
        for (i, w) in self.queue.iter().enumerate() {
            if taken_idx.len() == self.max_batch {
                break;
            }
            if blocked.contains(&w.session) {
                continue;
            }
            if w.kind == kind && !taken.contains(&w.session) {
                taken.insert(w.session.clone());
                taken_idx.push(i);
            } else {
                // Either this session already has an item in the batch
                // (staging snapshots state, so a second item must wait
                // for the next batch) or it has an unexecuted earlier
                // item of the other kind — later items must wait.
                blocked.insert(w.session.clone());
            }
        }
        let mut batch = Vec::with_capacity(taken_idx.len());
        // Remove back-to-front so indices stay valid.
        for &i in taken_idx.iter().rev() {
            // lint: allow(unwrap) — taken_idx came from enumerating
            // this same queue a few lines up.
            batch.push(self.queue.remove(i).unwrap());
        }
        batch.reverse();
        debug_assert!(!batch.is_empty());
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item_kinds(b: &[WorkItem]) -> Vec<WorkKind> {
        b.iter().map(|w| w.kind).collect()
    }

    #[test]
    fn batches_are_homogeneous_and_fifo() {
        let mut b = Batcher::new(4, Duration::ZERO);
        b.push("a", WorkKind::Compress, vec![1]);
        b.push("b", WorkKind::Compress, vec![2]);
        b.push("c", WorkKind::Infer, vec![3]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Compress; 2]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Infer]);
        assert!(b.next_batch(Instant::now(), true).is_none());
    }

    #[test]
    fn session_order_is_preserved() {
        let mut b = Batcher::new(8, Duration::ZERO);
        b.push("s", WorkKind::Compress, vec![1]);
        b.push("s", WorkKind::Infer, vec![2]); // depends on the compress
        b.push("t", WorkKind::Compress, vec![3]);
        b.push("s", WorkKind::Compress, vec![4]); // after s's infer!
        let batch = b.next_batch(Instant::now(), true).unwrap();
        // s's later compress must NOT ride along: s is blocked by its infer.
        let sessions: Vec<&str> = batch.iter().map(|w| w.session.as_str()).collect();
        assert_eq!(sessions, vec!["s", "t"]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Infer]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(batch[0].tokens, vec![4]);
    }

    #[test]
    fn size_and_age_triggers() {
        let mut b = Batcher::new(2, Duration::from_millis(50));
        b.push("a", WorkKind::Infer, vec![]);
        let now = Instant::now();
        assert!(!b.ready(now));
        assert!(b.next_batch(now, false).is_none());
        b.push("b", WorkKind::Infer, vec![]);
        assert!(b.ready(now)); // size trigger
        assert_eq!(b.next_batch(now, false).unwrap().len(), 2);
        b.push("c", WorkKind::Infer, vec![]);
        let later = now + Duration::from_millis(100);
        assert!(b.ready(later)); // age trigger
    }

    #[test]
    fn one_item_per_session_per_batch() {
        // Batch staging snapshots Mem(t-1)/pos_cursor per session, so
        // two chunks of one session must land in successive batches.
        let mut b = Batcher::new(8, Duration::ZERO);
        b.push("s", WorkKind::Compress, vec![1]);
        b.push("s", WorkKind::Compress, vec![2]);
        b.push("t", WorkKind::Compress, vec![3]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        let sessions: Vec<&str> = batch.iter().map(|w| w.session.as_str()).collect();
        assert_eq!(sessions, vec!["s", "t"]);
        assert_eq!(batch[0].tokens, vec![1]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].tokens, vec![2]);
    }

    #[test]
    fn infer_priority_jumps_unrelated_compress_backlog() {
        let mut b = Batcher::new(4, Duration::ZERO);
        b.infer_priority = true;
        for i in 0..6 {
            b.push("bulk", WorkKind::Compress, vec![i]);
        }
        b.push("fast", WorkKind::Infer, vec![99]);
        // The query batch is emitted first even though 6 compressions
        // are ahead of it in arrival order.
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Infer]);
        assert_eq!(batch[0].session, "fast");
        // Then the compress backlog drains in order.
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Compress; 4]);
    }

    #[test]
    fn infer_priority_never_overtakes_own_sessions_compress() {
        let mut b = Batcher::new(4, Duration::ZERO);
        b.infer_priority = true;
        b.push("s", WorkKind::Compress, vec![1]);
        b.push("s", WorkKind::Infer, vec![2]); // depends on the compress
        // No executable infer exists: the compress batch goes first.
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Compress]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Infer]);
    }

    #[test]
    fn infer_priority_override_cap_prevents_compress_starvation() {
        // One compress at the front, then a steady stream of queries
        // from distinct sessions: at most PRIORITY_OVERRIDE_LIMIT infer
        // batches may jump before the compress is forced through.
        let mut b = Batcher::new(1, Duration::ZERO);
        b.infer_priority = true;
        b.push("bulk", WorkKind::Compress, vec![1]);
        for i in 0..8 {
            b.push(&format!("f{i}"), WorkKind::Infer, vec![2]);
        }
        let mut kinds = Vec::new();
        while b.pending() > 0 {
            let batch = b.next_batch(Instant::now(), true).unwrap();
            kinds.push(batch[0].kind);
        }
        let compress_at = kinds.iter().position(|k| *k == WorkKind::Compress).unwrap();
        assert_eq!(
            compress_at as u32,
            super::PRIORITY_OVERRIDE_LIMIT,
            "compress must run after exactly the override cap: {kinds:?}"
        );
        assert_eq!(kinds.len(), 9);
    }

    #[test]
    fn adversarial_query_flood_cannot_starve_compress_beyond_cap() {
        // Regression (ROADMAP fairness item): ONE adversarial session
        // flooding queries must not push another session's compress
        // work back by more than PRIORITY_OVERRIDE_LIMIT consecutive
        // overrides. The flood is same-session, so each infer batch
        // carries exactly one item — the worst case for the backlog.
        let mut b = Batcher::new(4, Duration::ZERO);
        b.infer_priority = true;
        b.push("victim", WorkKind::Compress, vec![1]);
        for _ in 0..32 {
            b.push("attacker", WorkKind::Infer, vec![9]);
        }
        b.push("victim2", WorkKind::Compress, vec![2]);
        let mut kinds = Vec::new();
        let mut compress_sessions = Vec::new();
        let mut emitted = 0usize;
        while b.pending() > 0 {
            let batch = b.next_batch(Instant::now(), true).unwrap();
            emitted += batch.len();
            if batch[0].kind == WorkKind::Compress {
                compress_sessions.extend(batch.iter().map(|w| w.session.clone()));
            }
            kinds.push(batch[0].kind);
        }
        // The front compress is delayed by exactly the override cap,
        // never more — and the forced compress turn flushes the WHOLE
        // compress backlog in one batch (both victims, distinct
        // sessions, coalesce), so nothing waits for a second turn.
        let first_compress = kinds.iter().position(|k| *k == WorkKind::Compress).unwrap();
        assert_eq!(
            first_compress as u32,
            super::PRIORITY_OVERRIDE_LIMIT,
            "flood must be capped at the override limit: {kinds:?}"
        );
        assert_eq!(kinds.iter().filter(|k| **k == WorkKind::Compress).count(), 1);
        assert_eq!(compress_sessions, vec!["victim", "victim2"]);
        assert_eq!(emitted, 34, "every queued item must be emitted exactly once");
        assert_eq!(b.total_overrides(), u64::from(super::PRIORITY_OVERRIDE_LIMIT));
    }

    #[test]
    fn queued_for_and_pending_sessions() {
        let mut b = Batcher::new(4, Duration::ZERO);
        b.push("u", WorkKind::Compress, vec![1]);
        b.push("u", WorkKind::Compress, vec![2]);
        b.push("u", WorkKind::Infer, vec![3]);
        b.push("v", WorkKind::Infer, vec![4]);
        assert_eq!(b.queued_for("u", WorkKind::Compress), 2);
        assert_eq!(b.queued_for("u", WorkKind::Infer), 1);
        assert_eq!(b.queued_for("w", WorkKind::Compress), 0);
        let sessions = b.pending_sessions();
        assert!(sessions.contains("u") && sessions.contains("v"));
        assert_eq!(sessions.len(), 2);
    }

    #[test]
    fn property_every_item_emitted_once_in_session_order() {
        crate::util::proptest::check("batcher-order", 60, |rng| {
            let max_batch = rng.range(1, 6);
            let mut b = Batcher::new(max_batch, Duration::ZERO);
            b.infer_priority = rng.bool(0.5);
            let sessions = ["s0", "s1", "s2"];
            let n = rng.range(1, 40);
            let mut submitted: Vec<(u64, String)> = Vec::new();
            for _ in 0..n {
                let s = sessions[rng.range(0, 3)];
                let kind = if rng.bool(0.5) { WorkKind::Compress } else { WorkKind::Infer };
                let seq = b.push(s, kind, vec![]);
                submitted.push((seq, s.to_string()));
            }
            let mut emitted: Vec<WorkItem> = Vec::new();
            let mut guard = 0;
            while b.pending() > 0 {
                guard += 1;
                crate::prop_assert!(guard < 1000, "batcher stuck");
                let batch = b.next_batch(Instant::now(), true).unwrap();
                crate::prop_assert!(batch.len() <= max_batch, "batch too big");
                let k = batch[0].kind;
                crate::prop_assert!(
                    batch.iter().all(|w| w.kind == k),
                    "mixed-kind batch"
                );
                emitted.extend(batch);
            }
            crate::prop_assert!(emitted.len() == n, "lost items: {} != {n}", emitted.len());
            // Per-session sequence ids must be strictly increasing.
            for s in sessions {
                let seqs: Vec<u64> =
                    emitted.iter().filter(|w| w.session == s).map(|w| w.seq).collect();
                crate::prop_assert!(
                    seqs.windows(2).all(|w| w[0] < w[1]),
                    "session {s} out of order: {seqs:?}"
                );
            }
            Ok(())
        });
    }
}
