//! Dynamic batcher: groups pending compression / inference work into
//! artifact-sized batches while preserving per-session ordering.
//!
//! Ordering invariant: work items of one session execute in submission
//! order (an inference that depends on a pending compression never jumps
//! the queue), and a batch holds AT MOST ONE item per session — batch
//! staging snapshots session state (Mem(t-1), pos_cursor) before
//! execution, so a second same-session item in one batch would read
//! stale memory and clash on positions. Batches are homogeneous in
//! (kind, strategy): the two artifacts differ, and different
//! compression tiers take different execution paths. Flush policy:
//! size-triggered or age-triggered (max_wait).
//!
//! Scheduling policy: plain FIFO by default. With `infer_priority` set
//! (the serving engine turns it on), ready inference batches are emitted
//! ahead of unrelated sessions' compression backlog — queries are
//! latency-sensitive, compressions are throughput work — while the
//! per-session ordering invariant still holds (an infer never overtakes
//! its own session's queued compress). Overrides are governed by
//! per-session token buckets ([`Tiers`]: refill rate and burst per
//! strategy tier): each batch that jumps the front spends one token
//! from the overriding session's bucket, so ONE tenant's query flood
//! can delay another tenant's compress by at most that tenant's burst.
//! An aging floor (`front_max_delay`) additionally bounds the
//! aggregate delay across many funded tenants in wall-clock terms.

use std::collections::{HashMap, HashSet, VecDeque};
use std::time::{Duration, Instant};

use crate::compress::strategy::{StrategyKind, Tiers};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkKind {
    Compress,
    Infer,
}

#[derive(Debug, Clone)]
pub struct WorkItem {
    pub seq: u64,
    pub session: String,
    pub kind: WorkKind,
    pub strategy: StrategyKind,
    pub tokens: Vec<i32>,
    pub submitted: Instant,
}

/// Default wall-clock bound on how long priority overrides may hold the
/// front item back, regardless of how many funded tenants keep jumping.
pub const FRONT_MAX_DELAY: Duration = Duration::from_millis(50);

/// One session's override budget (token bucket).
#[derive(Debug, Clone, Copy)]
struct TokenBucket {
    tokens: f64,
    last: Instant,
    /// Burst cap snapshot (for pruning full, idle buckets).
    burst: f64,
}

#[derive(Debug)]
pub struct Batcher {
    queue: VecDeque<WorkItem>,
    next_seq: u64,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Emit ready infer batches ahead of unrelated compress backlog.
    pub infer_priority: bool,
    /// Per-tier token-bucket shapes governing priority overrides.
    tiers: Tiers,
    /// Aging floor: once the front item has waited this long, no
    /// override is permitted until it runs.
    pub front_max_delay: Duration,
    /// Per-session override budgets.
    buckets: HashMap<String, TokenBucket>,
    /// Lifetime count of priority overrides (surfaced in serve stats).
    overrides_total: u64,
    /// Overrides charged per overriding session's strategy tier.
    overrides_by: [u64; 3],
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        assert!(max_batch >= 1);
        Batcher {
            queue: VecDeque::new(),
            next_seq: 0,
            max_batch,
            max_wait,
            infer_priority: false,
            tiers: Tiers::default(),
            front_max_delay: FRONT_MAX_DELAY,
            buckets: HashMap::new(),
            overrides_total: 0,
            overrides_by: [0; 3],
        }
    }

    /// Swap the per-tier QoS shapes (refill/burst). Live buckets keep
    /// their balance but refill and cap under the new shape.
    pub fn set_tiers(&mut self, tiers: Tiers) {
        self.tiers = tiers;
    }

    /// Total priority overrides emitted over this batcher's lifetime
    /// (how often a ready infer batch jumped the compress backlog).
    pub fn total_overrides(&self) -> u64 {
        self.overrides_total
    }

    /// Lifetime overrides split by the overriding session's strategy
    /// tier, indexed by [`StrategyKind::index`].
    pub fn overrides_by_strategy(&self) -> [u64; 3] {
        self.overrides_by
    }

    /// Enqueue; returns the work-item sequence id.
    pub fn push(
        &mut self,
        session: &str,
        kind: WorkKind,
        strategy: StrategyKind,
        tokens: Vec<i32>,
    ) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back(WorkItem {
            seq,
            session: session.to_string(),
            kind,
            strategy,
            tokens,
            submitted: Instant::now(),
        });
        seq
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Queued (unexecuted) items of `kind` for one session. The serving
    /// front-end uses this to ack context chunks with the time step they
    /// will actually land on (t+1, t+2, ... for chunks queued together).
    pub fn queued_for(&self, session: &str, kind: WorkKind) -> usize {
        self.queue.iter().filter(|w| w.kind == kind && w.session == session).count()
    }

    /// Sessions with any queued work (memory governance must not evict
    /// these: their queued items reference session state).
    pub fn pending_sessions(&self) -> HashSet<String> {
        self.queue.iter().map(|w| w.session.clone()).collect()
    }

    /// Would a batch be emitted right now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.max_batch {
            return true;
        }
        self.queue
            .front()
            .map(|w| now.duration_since(w.submitted) >= self.max_wait)
            .unwrap_or(false)
    }

    /// Refill `session`'s bucket to `now` under its tier shape and try
    /// to spend one override token. A tier with burst < 1 never
    /// overrides.
    fn take_token(&mut self, session: &str, strategy: StrategyKind, now: Instant) -> bool {
        let cfg = *self.tiers.get(strategy);
        if cfg.burst < 1.0 {
            return false;
        }
        let b = self
            .buckets
            .entry(session.to_string())
            .or_insert(TokenBucket { tokens: cfg.burst, last: now, burst: cfg.burst });
        b.burst = cfg.burst;
        let dt = now.saturating_duration_since(b.last).as_secs_f64();
        b.tokens = (b.tokens + dt * cfg.refill_per_sec).min(cfg.burst);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Batch key for the next emission. FIFO: the front item's (kind,
    /// strategy). With `infer_priority`: the first executable infer (no
    /// earlier same-session compress) whose session can spend an
    /// override token — unless the front item has already waited
    /// `front_max_delay`, in which case fairness forces it through.
    fn pick_key(&mut self, now: Instant) -> (WorkKind, StrategyKind) {
        // lint: allow(unwrap) — only called from next_batch after its
        // queue-empty early return, so the front exists.
        let front = self.queue.front().unwrap();
        let front_key = (front.kind, front.strategy);
        if !self.infer_priority || front.kind == WorkKind::Infer {
            return front_key;
        }
        if now.saturating_duration_since(front.submitted) >= self.front_max_delay {
            return front_key; // aging floor: the backlog gets its turn
        }
        // Executable infer candidates in queue order, one per session.
        let mut blocked: HashSet<&str> = HashSet::new();
        let mut seen: HashSet<&str> = HashSet::new();
        let mut candidates: Vec<(String, StrategyKind)> = Vec::new();
        for w in &self.queue {
            match w.kind {
                WorkKind::Infer if !blocked.contains(w.session.as_str()) => {
                    if seen.insert(w.session.as_str()) {
                        candidates.push((w.session.clone(), w.strategy));
                    }
                }
                WorkKind::Infer => {}
                WorkKind::Compress => {
                    blocked.insert(w.session.as_str());
                }
            }
        }
        for (session, strategy) in candidates {
            if self.take_token(&session, strategy, now) {
                self.overrides_total += 1;
                self.overrides_by[strategy.index()] += 1;
                return (WorkKind::Infer, strategy);
            }
        }
        front_key
    }

    /// Pop the next homogeneous batch (up to max_batch items of the
    /// picked kind and strategy), skipping items whose session has an
    /// earlier still-queued item of another key — those stay queued, and
    /// the session is "blocked" for the rest of this scan.
    pub fn next_batch(&mut self, now: Instant, force: bool) -> Option<Vec<WorkItem>> {
        if self.queue.is_empty() || (!force && !self.ready(now)) {
            return None;
        }
        let (kind, strategy) = self.pick_key(now);
        let mut blocked: HashSet<String> = HashSet::new();
        let mut taken: HashSet<String> = HashSet::new();
        let mut taken_idx = Vec::new();
        for (i, w) in self.queue.iter().enumerate() {
            if taken_idx.len() == self.max_batch {
                break;
            }
            if blocked.contains(&w.session) {
                continue;
            }
            if w.kind == kind && w.strategy == strategy && !taken.contains(&w.session) {
                taken.insert(w.session.clone());
                taken_idx.push(i);
            } else {
                // Either this session already has an item in the batch
                // (staging snapshots state, so a second item must wait
                // for the next batch) or it has an unexecuted earlier
                // item of another key — later items must wait.
                blocked.insert(w.session.clone());
            }
        }
        let mut batch = Vec::with_capacity(taken_idx.len());
        // Remove back-to-front so indices stay valid.
        for &i in taken_idx.iter().rev() {
            // lint: allow(unwrap) — taken_idx came from enumerating
            // this same queue a few lines up.
            batch.push(self.queue.remove(i).unwrap());
        }
        batch.reverse();
        debug_assert!(!batch.is_empty());
        // Full, idle buckets are equivalent to absent ones — drop them
        // so a long-lived server does not accrete one entry per
        // session ever seen.
        if self.buckets.len() > 256 {
            self.buckets.retain(|_, b| b.tokens + 1e-9 < b.burst);
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::strategy::TierConfig;

    const CCM: StrategyKind = StrategyKind::Ccm;

    fn item_kinds(b: &[WorkItem]) -> Vec<WorkKind> {
        b.iter().map(|w| w.kind).collect()
    }

    /// Tiers where every strategy has the given burst and no refill —
    /// the deterministic shape the fairness tests reason about.
    fn flat_tiers(burst: f64) -> Tiers {
        let mut t = Tiers::default();
        for k in StrategyKind::ALL {
            *t.get_mut(k) = TierConfig { refill_per_sec: 0.0, burst, ..TierConfig::default() };
        }
        t
    }

    #[test]
    fn batches_are_homogeneous_and_fifo() {
        let mut b = Batcher::new(4, Duration::ZERO);
        b.push("a", WorkKind::Compress, CCM, vec![1]);
        b.push("b", WorkKind::Compress, CCM, vec![2]);
        b.push("c", WorkKind::Infer, CCM, vec![3]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Compress; 2]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Infer]);
        assert!(b.next_batch(Instant::now(), true).is_none());
    }

    #[test]
    fn batches_are_homogeneous_in_strategy() {
        // Same kind, different tiers: the batch must not mix them —
        // each tier takes a different execution path in the
        // coordinator (backend g_comp vs session-local absorption).
        let mut b = Batcher::new(8, Duration::ZERO);
        b.push("a", WorkKind::Compress, StrategyKind::Ccm, vec![1]);
        b.push("c", WorkKind::Compress, StrategyKind::NoCompress, vec![2]);
        b.push("b", WorkKind::Compress, StrategyKind::Ccm, vec![3]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        let sessions: Vec<&str> = batch.iter().map(|w| w.session.as_str()).collect();
        assert_eq!(sessions, vec!["a", "b"], "ccm batch coalesces around the no-compress item");
        assert!(batch.iter().all(|w| w.strategy == StrategyKind::Ccm));
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(batch[0].strategy, StrategyKind::NoCompress);
        assert!(b.next_batch(Instant::now(), true).is_none());
    }

    #[test]
    fn session_order_is_preserved() {
        let mut b = Batcher::new(8, Duration::ZERO);
        b.push("s", WorkKind::Compress, CCM, vec![1]);
        b.push("s", WorkKind::Infer, CCM, vec![2]); // depends on the compress
        b.push("t", WorkKind::Compress, CCM, vec![3]);
        b.push("s", WorkKind::Compress, CCM, vec![4]); // after s's infer!
        let batch = b.next_batch(Instant::now(), true).unwrap();
        // s's later compress must NOT ride along: s is blocked by its infer.
        let sessions: Vec<&str> = batch.iter().map(|w| w.session.as_str()).collect();
        assert_eq!(sessions, vec!["s", "t"]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Infer]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(batch[0].tokens, vec![4]);
    }

    #[test]
    fn size_and_age_triggers() {
        let mut b = Batcher::new(2, Duration::from_millis(50));
        b.push("a", WorkKind::Infer, CCM, vec![]);
        let now = Instant::now();
        assert!(!b.ready(now));
        assert!(b.next_batch(now, false).is_none());
        b.push("b", WorkKind::Infer, CCM, vec![]);
        assert!(b.ready(now)); // size trigger
        assert_eq!(b.next_batch(now, false).unwrap().len(), 2);
        b.push("c", WorkKind::Infer, CCM, vec![]);
        let later = now + Duration::from_millis(100);
        assert!(b.ready(later)); // age trigger
    }

    #[test]
    fn one_item_per_session_per_batch() {
        // Batch staging snapshots Mem(t-1)/pos_cursor per session, so
        // two chunks of one session must land in successive batches.
        let mut b = Batcher::new(8, Duration::ZERO);
        b.push("s", WorkKind::Compress, CCM, vec![1]);
        b.push("s", WorkKind::Compress, CCM, vec![2]);
        b.push("t", WorkKind::Compress, CCM, vec![3]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        let sessions: Vec<&str> = batch.iter().map(|w| w.session.as_str()).collect();
        assert_eq!(sessions, vec!["s", "t"]);
        assert_eq!(batch[0].tokens, vec![1]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].tokens, vec![2]);
    }

    #[test]
    fn infer_priority_jumps_unrelated_compress_backlog() {
        let mut b = Batcher::new(4, Duration::ZERO);
        b.infer_priority = true;
        for i in 0..6 {
            b.push("bulk", WorkKind::Compress, CCM, vec![i]);
        }
        b.push("fast", WorkKind::Infer, CCM, vec![99]);
        // The query batch is emitted first even though 6 compressions
        // are ahead of it in arrival order.
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Infer]);
        assert_eq!(batch[0].session, "fast");
        assert_eq!(b.total_overrides(), 1);
        assert_eq!(b.overrides_by_strategy()[CCM.index()], 1);
        // Then the compress backlog drains in order.
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Compress; 4]);
    }

    #[test]
    fn infer_priority_never_overtakes_own_sessions_compress() {
        let mut b = Batcher::new(4, Duration::ZERO);
        b.infer_priority = true;
        b.push("s", WorkKind::Compress, CCM, vec![1]);
        b.push("s", WorkKind::Infer, CCM, vec![2]); // depends on the compress
        // No executable infer exists: the compress batch goes first.
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Compress]);
        let batch = b.next_batch(Instant::now(), true).unwrap();
        assert_eq!(item_kinds(&batch), vec![WorkKind::Infer]);
        assert_eq!(b.total_overrides(), 0, "in-order emission spends no tokens");
    }

    #[test]
    fn single_tenant_flood_delay_is_bounded_by_configured_burst() {
        // QoS property (replaces the fixed consecutive-override cap):
        // ONE session flooding queries delays another tenant's compress
        // by at most ITS OWN bucket burst — then the bucket is empty
        // and the compress is forced through, whatever the flood depth.
        for burst in [1u32, 3, 4, 7] {
            let mut b = Batcher::new(4, Duration::ZERO);
            b.infer_priority = true;
            b.set_tiers(flat_tiers(burst as f64));
            b.push("victim", WorkKind::Compress, CCM, vec![1]);
            for _ in 0..32 {
                b.push("attacker", WorkKind::Infer, CCM, vec![9]);
            }
            b.push("victim2", WorkKind::Compress, CCM, vec![2]);
            let mut kinds = Vec::new();
            let mut compress_sessions = Vec::new();
            let mut emitted = 0usize;
            while b.pending() > 0 {
                let batch = b.next_batch(Instant::now(), true).unwrap();
                emitted += batch.len();
                if batch[0].kind == WorkKind::Compress {
                    compress_sessions.extend(batch.iter().map(|w| w.session.clone()));
                }
                kinds.push(batch[0].kind);
            }
            let first_compress = kinds.iter().position(|k| *k == WorkKind::Compress).unwrap();
            assert_eq!(
                first_compress as u32, burst,
                "flood must be capped at the configured burst {burst}: {kinds:?}"
            );
            // The forced compress turn flushes the WHOLE compress
            // backlog in one batch (both victims, distinct sessions,
            // coalesce), so nothing waits for a second turn.
            assert_eq!(kinds.iter().filter(|k| **k == WorkKind::Compress).count(), 1);
            assert_eq!(compress_sessions, vec!["victim", "victim2"]);
            assert_eq!(emitted, 34, "every queued item must be emitted exactly once");
            assert_eq!(b.total_overrides(), u64::from(burst));
        }
    }

    #[test]
    fn bucket_refill_restores_override_budget_over_time() {
        // refill 100/s, burst 2: after the burst is spent, ~10ms of
        // simulated wall clock buys one more override.
        let mut t = Tiers::default();
        *t.get_mut(CCM) = TierConfig { refill_per_sec: 100.0, burst: 2.0, ..TierConfig::default() };
        let mut b = Batcher::new(1, Duration::ZERO);
        b.infer_priority = true;
        b.set_tiers(t);
        let start = Instant::now();
        b.push("victim", WorkKind::Compress, CCM, vec![1]);
        for _ in 0..4 {
            b.push("flood", WorkKind::Infer, CCM, vec![9]);
        }
        // Two overrides spend the burst...
        assert_eq!(b.next_batch(start, true).unwrap()[0].kind, WorkKind::Infer);
        assert_eq!(b.next_batch(start, true).unwrap()[0].kind, WorkKind::Infer);
        // ...the third pick at the same instant is broke: compress runs.
        assert_eq!(b.next_batch(start, true).unwrap()[0].kind, WorkKind::Compress);
        // 10ms later the bucket holds one token again. (The flood is
        // now the front, so push another victim compress behind it to
        // make the override observable.)
        b.push("victim2", WorkKind::Compress, CCM, vec![2]);
        let later = start + Duration::from_millis(10);
        let batch = b.next_batch(later, true).unwrap();
        assert_eq!(batch[0].kind, WorkKind::Infer, "refilled bucket funds the jump");
        assert_eq!(b.total_overrides(), 3);
    }

    #[test]
    fn aging_floor_forces_front_through_funded_floods() {
        // Two funded tenants alternate overrides; once the front
        // compress has waited front_max_delay, no budget can jump it.
        let mut b = Batcher::new(1, Duration::ZERO);
        b.infer_priority = true;
        b.set_tiers(flat_tiers(1000.0));
        let start = Instant::now();
        b.push("victim", WorkKind::Compress, CCM, vec![1]);
        for i in 0..8 {
            b.push(&format!("f{i}"), WorkKind::Infer, CCM, vec![9]);
        }
        // Well-funded tenants override while the front is young...
        assert_eq!(b.next_batch(start, true).unwrap()[0].kind, WorkKind::Infer);
        // ...but at front_max_delay the aging floor wins.
        let late = start + b.front_max_delay;
        assert_eq!(b.next_batch(late, true).unwrap()[0].kind, WorkKind::Compress);
    }

    #[test]
    fn queued_for_and_pending_sessions() {
        let mut b = Batcher::new(4, Duration::ZERO);
        b.push("u", WorkKind::Compress, CCM, vec![1]);
        b.push("u", WorkKind::Compress, CCM, vec![2]);
        b.push("u", WorkKind::Infer, CCM, vec![3]);
        b.push("v", WorkKind::Infer, CCM, vec![4]);
        assert_eq!(b.queued_for("u", WorkKind::Compress), 2);
        assert_eq!(b.queued_for("u", WorkKind::Infer), 1);
        assert_eq!(b.queued_for("w", WorkKind::Compress), 0);
        let sessions = b.pending_sessions();
        assert!(sessions.contains("u") && sessions.contains("v"));
        assert_eq!(sessions.len(), 2);
    }

    #[test]
    fn property_every_item_emitted_once_in_session_order() {
        crate::util::proptest::check("batcher-order", 60, |rng| {
            let max_batch = rng.range(1, 6);
            let mut b = Batcher::new(max_batch, Duration::ZERO);
            b.infer_priority = rng.bool(0.5);
            // One strategy per session (the serving invariant: a
            // session's strategy is pinned at admission).
            let sessions = [
                ("s0", StrategyKind::Ccm),
                ("s1", StrategyKind::SlidingWindow),
                ("s2", StrategyKind::NoCompress),
            ];
            let n = rng.range(1, 40);
            let mut submitted: Vec<(u64, String)> = Vec::new();
            for _ in 0..n {
                let (s, strat) = sessions[rng.range(0, 3)];
                let kind = if rng.bool(0.5) { WorkKind::Compress } else { WorkKind::Infer };
                let seq = b.push(s, kind, strat, vec![]);
                submitted.push((seq, s.to_string()));
            }
            let mut emitted: Vec<WorkItem> = Vec::new();
            let mut guard = 0;
            while b.pending() > 0 {
                guard += 1;
                crate::prop_assert!(guard < 1000, "batcher stuck");
                let batch = b.next_batch(Instant::now(), true).unwrap();
                crate::prop_assert!(batch.len() <= max_batch, "batch too big");
                let k = batch[0].kind;
                let strat = batch[0].strategy;
                crate::prop_assert!(
                    batch.iter().all(|w| w.kind == k && w.strategy == strat),
                    "mixed-key batch"
                );
                emitted.extend(batch);
            }
            crate::prop_assert!(emitted.len() == n, "lost items: {} != {n}", emitted.len());
            // Per-session sequence ids must be strictly increasing.
            for (s, _) in sessions {
                let seqs: Vec<u64> =
                    emitted.iter().filter(|w| w.session == s).map(|w| w.seq).collect();
                crate::prop_assert!(
                    seqs.windows(2).all(|w| w[0] < w[1]),
                    "session {s} out of order: {seqs:?}"
                );
            }
            Ok(())
        });
    }
}
