//! The online-inference coordinator — the paper's system contribution at
//! serving time (vLLM-router-shaped).
//!
//! Flow per request: the router assigns work to the session, the dynamic
//! batcher groups compressions/inferences across sessions (preserving
//! per-session order), and the executor stages each batch into the AOT
//! artifacts via the compression engine. Memory per session is a compact
//! Mem(t) instead of raw context KV — the whole point of the paper.

pub mod batcher;
pub mod metrics;
pub mod session;

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::compress::{CompressItem, Engine, InferItem};
use crate::coordinator::batcher::{Batcher, WorkItem, WorkKind};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::session::{SessionManager, SessionPolicy};
use crate::model::Checkpoint;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

pub struct Coordinator<'rt> {
    pub engine: Engine<'rt>,
    pub sessions: SessionManager,
    pub batcher: Batcher,
    pub metrics: Metrics,
    results: HashMap<u64, Tensor>,
}

impl<'rt> Coordinator<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        ck: &'rt Checkpoint,
        policy: SessionPolicy,
        max_batch: usize,
        max_wait: std::time::Duration,
    ) -> Result<Coordinator<'rt>> {
        let engine = Engine::new(rt, ck, policy.comp_len)?;
        let sessions = SessionManager::with_policy(&rt.manifest, policy);
        Ok(Coordinator {
            engine,
            sessions,
            batcher: Batcher::new(max_batch, max_wait),
            metrics: Metrics::default(),
            results: HashMap::new(),
        })
    }

    /// Enqueue a new context chunk c(t) for a session (compression).
    pub fn add_context(&mut self, session: &str, chunk: Vec<i32>) -> u64 {
        self.metrics.requests += 1;
        self.sessions.get_or_create(session);
        self.batcher.push(session, WorkKind::Compress, chunk)
    }

    /// Enqueue a query I(t); the result (logits rows) is retrievable via
    /// `take_result` after the batcher has flushed.
    pub fn query(&mut self, session: &str, input: Vec<i32>) -> u64 {
        self.metrics.requests += 1;
        self.sessions.get_or_create(session);
        self.batcher.push(session, WorkKind::Infer, input)
    }

    /// Process at most one batch. Returns items processed (0 = idle).
    pub fn pump(&mut self, force: bool) -> Result<usize> {
        let now = Instant::now();
        let Some(batch) = self.batcher.next_batch(now, force) else {
            return Ok(0);
        };
        for w in &batch {
            self.metrics.queue_latency.record(now.duration_since(w.submitted));
        }
        self.metrics.record_batch(batch.len());
        let kind = batch[0].kind;
        let t = Instant::now();
        match kind {
            WorkKind::Compress => self.run_compress(&batch)?,
            WorkKind::Infer => self.run_infer(&batch)?,
        }
        let el = t.elapsed();
        match kind {
            WorkKind::Compress => {
                self.metrics.compressions += batch.len() as u64;
                self.metrics.compress_latency.record(el);
            }
            WorkKind::Infer => {
                self.metrics.inferences += batch.len() as u64;
                self.metrics.infer_latency.record(el);
            }
        }
        self.metrics.note_kv_bytes(self.sessions.total_kv_bytes());
        Ok(batch.len())
    }

    /// Drain the queue completely.
    pub fn run_until_idle(&mut self) -> Result<()> {
        while self.pump(true)? > 0 {}
        Ok(())
    }

    pub fn take_result(&mut self, seq: u64) -> Option<Tensor> {
        self.results.remove(&seq)
    }

    fn run_compress(&mut self, batch: &[WorkItem]) -> Result<()> {
        let comp_len = self.engine.comp_len;
        // Graceful concat overflow: evict oldest compressed chunks first
        // (the streaming policy of Figure 9 applied to serving).
        for w in batch {
            let s = self.sessions.get_mut(&w.session)?;
            if s.mem.free_slots() != usize::MAX && s.mem.free_slots() < comp_len {
                s.mem.evict_chunks(1);
            }
        }
        let items: Vec<CompressItem> = batch
            .iter()
            .map(|w| {
                let s = self.sessions.get(&w.session).unwrap();
                CompressItem { mem: &s.mem, chunk: &w.tokens, pos_start: s.pos_cursor }
            })
            .collect();
        let compressed = self.engine.compress(&items)?;
        for (w, h) in batch.iter().zip(compressed) {
            let s = self.sessions.get_mut(&w.session)?;
            s.mem.update(&h)?;
            s.pos_cursor += w.tokens.len() + comp_len;
            s.t += 1;
            s.raw_context_tokens += w.tokens.len();
            self.metrics.tokens_compressed += w.tokens.len() as u64;
        }
        Ok(())
    }

    fn run_infer(&mut self, batch: &[WorkItem]) -> Result<()> {
        let items: Vec<InferItem> = batch
            .iter()
            .map(|w| {
                let s = self.sessions.get(&w.session).unwrap();
                InferItem { mem: &s.mem, tokens: &w.tokens, pos_start: s.pos_cursor }
            })
            .collect();
        let logits = self.engine.infer(&items)?;
        for (w, l) in batch.iter().zip(logits) {
            self.results.insert(w.seq, l);
        }
        Ok(())
    }
}
