//! The online-inference coordinator — the paper's system contribution at
//! serving time (vLLM-router-shaped).
//!
//! Flow per request: the router assigns work to the session, the dynamic
//! batcher groups compressions/inferences across sessions (preserving
//! per-session order), and the executor stages each batch into the AOT
//! artifacts via the compression engine. Memory per session is a compact
//! Mem(t) instead of raw context KV — the whole point of the paper.
//!
//! The execution backend is pluggable ([`Compute`]): the XLA engine in
//! production, a deterministic host-side simulator in protocol tests and
//! host-only benches. Memory governance (global KV budget, idle-session
//! reaping) lives here so the serving front-end stays a thin pump loop.

pub mod batcher;
pub mod metrics;
pub mod session;

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::compress::{CompressItem, Compute, Engine, InferItem, StrategyKind};
use crate::coordinator::batcher::{Batcher, WorkItem, WorkKind};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::session::{SessionManager, SessionPolicy};
use crate::model::manifest::Manifest;
use crate::model::Checkpoint;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

pub struct Coordinator<'rt> {
    backend: Box<dyn Compute + 'rt>,
    pub sessions: SessionManager,
    pub batcher: Batcher,
    pub metrics: Metrics,
    /// Artifact input cap — non-compressing tiers stage retained raw
    /// context plus the query and must clamp to this.
    input_max: usize,
    /// seq -> (logits, staged input length). The staged length matters
    /// to the caller: retained-context tiers prepend history, so the
    /// query's next-token row is `staged_len - 1`, not `query_len - 1`.
    results: HashMap<u64, (Tensor, usize)>,
    /// Seqs of infer items whose batch failed (consumed via `take_failed`).
    failed: Vec<u64>,
}

impl<'rt> Coordinator<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        ck: &'rt Checkpoint,
        policy: SessionPolicy,
        max_batch: usize,
        max_wait: std::time::Duration,
    ) -> Result<Coordinator<'rt>> {
        let engine = Engine::new(rt, ck, policy.comp_len)?;
        Ok(Self::with_backend(&rt.manifest, Box::new(engine), policy, max_batch, max_wait))
    }

    /// Build a coordinator over any [`Compute`] backend (the server's
    /// test path and host-only benches inject [`crate::compress::SimCompute`]).
    pub fn with_backend(
        manifest: &Manifest,
        backend: Box<dyn Compute + 'rt>,
        policy: SessionPolicy,
        max_batch: usize,
        max_wait: std::time::Duration,
    ) -> Coordinator<'rt> {
        let sessions = SessionManager::with_policy(manifest, policy);
        Coordinator {
            backend,
            sessions,
            batcher: Batcher::new(max_batch, max_wait),
            metrics: Metrics::default(),
            input_max: manifest.scenario.input_max,
            results: HashMap::new(),
            failed: Vec::new(),
        }
    }

    /// Enqueue a new context chunk c(t) for a session (compression or
    /// tier-local absorption). `strategy` applies only if this admission
    /// creates the session — an existing session keeps the tier it was
    /// admitted under.
    pub fn add_context_strat(
        &mut self,
        session: &str,
        chunk: Vec<i32>,
        strategy: Option<StrategyKind>,
    ) -> u64 {
        self.metrics.requests += 1;
        let strat = self.sessions.get_or_create_with(session, strategy).strategy;
        self.batcher.push(session, WorkKind::Compress, strat, chunk)
    }

    /// Enqueue a context chunk under the session's (or default) tier.
    pub fn add_context(&mut self, session: &str, chunk: Vec<i32>) -> u64 {
        self.add_context_strat(session, chunk, None)
    }

    /// Enqueue a query I(t); the result (logits rows) is retrievable via
    /// `take_result` after the batcher has flushed.
    pub fn query(&mut self, session: &str, input: Vec<i32>) -> u64 {
        self.metrics.requests += 1;
        let strat = self.sessions.get_or_create_with(session, None).strategy;
        self.batcher.push(session, WorkKind::Infer, strat, input)
    }

    /// Queued-but-unexecuted work items (admission control reads this).
    pub fn pending(&self) -> usize {
        self.batcher.pending()
    }

    /// Process at most one batch. Returns items processed (0 = idle).
    pub fn pump(&mut self, force: bool) -> Result<usize> {
        let now = Instant::now();
        let Some(batch) = self.batcher.next_batch(now, force) else {
            return Ok(0);
        };
        for w in &batch {
            self.metrics.queue_latency.record(now.duration_since(w.submitted));
        }
        self.metrics.record_batch(batch.len());
        let kind = batch[0].kind;
        let strat = batch[0].strategy;
        let t = Instant::now();
        let ran = match kind {
            // A context chunk either runs through the backend's g_comp
            // (CCM tier) or is absorbed session-locally by the tier's
            // retention rule (sliding-window / no-compress) — no
            // accelerator call, so the batch key keeps these apart.
            WorkKind::Compress if self.sessions.strategy(strat).compresses() => {
                self.run_compress(&batch)
            }
            WorkKind::Compress => self.run_absorb(&batch),
            WorkKind::Infer => self.run_infer(&batch),
        };
        if let Err(e) = ran {
            // Record exactly which queries died with this batch so the
            // caller can fail those — and only those — requesters.
            if kind == WorkKind::Infer {
                self.failed.extend(batch.iter().map(|w| w.seq));
            }
            return Err(e);
        }
        let el = t.elapsed();
        let by = &mut self.metrics.by_strategy[strat.index()];
        match kind {
            WorkKind::Compress => {
                self.metrics.compressions += batch.len() as u64;
                by.compressions += batch.len() as u64;
                self.metrics.compress_latency.record(el);
            }
            WorkKind::Infer => {
                self.metrics.inferences += batch.len() as u64;
                by.inferences += batch.len() as u64;
                self.metrics.infer_latency.record(el);
            }
        }
        self.metrics.note_kv_bytes(self.sessions.total_kv_bytes());
        Ok(batch.len())
    }

    /// Drain the queue completely.
    pub fn run_until_idle(&mut self) -> Result<()> {
        while self.pump(true)? > 0 {}
        Ok(())
    }

    pub fn take_result(&mut self, seq: u64) -> Option<Tensor> {
        self.take_result_staged(seq).map(|(t, _)| t)
    }

    /// Like [`take_result`](Self::take_result) but also yields the
    /// staged input length the logits were computed over. Callers that
    /// read "the query's last row" must index `staged_len - 1`:
    /// retained-context tiers prepend history tokens to the query.
    pub fn take_result_staged(&mut self, seq: u64) -> Option<(Tensor, usize)> {
        self.results.remove(&seq)
    }

    /// Drop all undelivered results (the server calls this when nobody
    /// is waiting, so orphaned logits do not accumulate).
    pub fn clear_results(&mut self) {
        self.results.clear();
    }

    /// Seqs of queries whose batch failed since the last call.
    pub fn take_failed(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.failed)
    }

    /// Enforce a compressed-KV budget: evict idle sessions in the
    /// session manager's [`EvictionPolicy`] order (oldest-created by
    /// default) until under `max_bytes`. Sessions with queued work are
    /// never evicted (their batch staging holds memory references).
    /// Returns the evicted session ids; counts land in `metrics`.
    ///
    /// [`EvictionPolicy`]: crate::coordinator::session::EvictionPolicy
    pub fn enforce_kv_budget(&mut self, max_bytes: usize) -> Vec<String> {
        if self.sessions.total_kv_bytes() <= max_bytes {
            return Vec::new(); // common case: no protected-set allocation
        }
        let protected = self.batcher.pending_sessions();
        let evicted = self.sessions.evict_to_budget_protected(max_bytes, &protected);
        self.metrics.sessions_evicted += evicted.len() as u64;
        evicted
    }

    /// Reap sessions idle for at least `ttl` (no queued work). Returns
    /// the reaped ids; counts land in `metrics`.
    pub fn reap_idle(&mut self, ttl: Duration, now: Instant) -> Vec<String> {
        let protected = self.batcher.pending_sessions();
        let reaped = self.sessions.reap_idle(ttl, now, &protected);
        self.metrics.sessions_reaped += reaped.len() as u64;
        reaped
    }

    fn run_compress(&mut self, batch: &[WorkItem]) -> Result<()> {
        let comp_len = self.backend.comp_len();
        // Graceful concat overflow: evict oldest compressed chunks first
        // (the streaming policy of Figure 9 applied to serving). Sessions
        // are re-created if governance evicted them while work was queued
        // (defensive: governance skips pending sessions, but a removed
        // session must degrade to empty memory, not a panic).
        for w in batch {
            let s = self.sessions.get_or_create(&w.session);
            if s.mem.free_slots() != usize::MAX && s.mem.free_slots() < comp_len {
                s.mem.evict_chunks(1);
            }
        }
        let items: Vec<CompressItem> = batch
            .iter()
            .map(|w| {
                // lint: allow(unwrap) — get_or_create ran for every
                // batch session in the loop above.
                let s = self.sessions.get(&w.session).unwrap();
                CompressItem { mem: &s.mem, chunk: &w.tokens, pos_start: s.pos_cursor }
            })
            .collect();
        let compressed = self.backend.compress(&items)?;
        for (w, h) in batch.iter().zip(compressed) {
            let s = self.sessions.get_mut(&w.session)?;
            s.mem.update(&h)?;
            s.pos_cursor += w.tokens.len() + comp_len;
            s.t += 1;
            s.raw_context_tokens += w.tokens.len();
            self.metrics.tokens_compressed += w.tokens.len() as u64;
        }
        Ok(())
    }

    /// Non-compressing tiers: fold each chunk into the session's own
    /// retention state (sliding window / full tail). No backend call.
    fn run_absorb(&mut self, batch: &[WorkItem]) -> Result<()> {
        for w in batch {
            self.sessions.get_or_create_with(&w.session, Some(w.strategy));
            let dropped = self.sessions.absorb(&w.session, &w.tokens)?;
            self.metrics.by_strategy[w.strategy.index()].tokens_dropped += dropped as u64;
            self.metrics.tokens_compressed += w.tokens.len() as u64;
        }
        Ok(())
    }

    fn run_infer(&mut self, batch: &[WorkItem]) -> Result<()> {
        for w in batch {
            self.sessions.get_or_create_with(&w.session, Some(w.strategy));
        }
        // Stage first (owned token vectors), then borrow memories: the
        // tier decides what surrounds the query — nothing for CCM,
        // retained raw context for sliding-window / no-compress.
        let staged: Vec<(Vec<i32>, usize)> = batch
            .iter()
            .map(|w| self.sessions.stage_input(&w.session, &w.tokens, self.input_max))
            .collect::<Result<_>>()?;
        let items: Vec<InferItem> = batch
            .iter()
            .zip(&staged)
            .map(|(w, (tokens, pos_start))| {
                // lint: allow(unwrap) — get_or_create ran for every
                // batch session in the loop above.
                let s = self.sessions.get(&w.session).unwrap();
                InferItem { mem: &s.mem, tokens, pos_start: *pos_start }
            })
            .collect();
        let logits = self.backend.infer(&items)?;
        for ((w, l), (tokens, _)) in batch.iter().zip(logits).zip(&staged) {
            self.results.insert(w.seq, (l, tokens.len()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SimCompute;

    fn sim_coordinator(max_batch: usize) -> Coordinator<'static> {
        let m = Manifest::toy();
        let sim = SimCompute::from_manifest(&m);
        Coordinator::with_backend(
            &m,
            Box::new(sim),
            SessionPolicy::concat(m.scenario.comp_len_max),
            max_batch,
            Duration::ZERO,
        )
    }

    #[test]
    fn sim_backend_end_to_end() {
        let mut coord = sim_coordinator(4);
        coord.add_context("u1", vec![4, 5, 6]);
        coord.add_context("u1", vec![7, 8]);
        let seq = coord.query("u1", vec![9]);
        coord.run_until_idle().unwrap();
        let logits = coord.take_result(seq).expect("result");
        let row = logits.row(&[0]);
        let top = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
        assert_eq!(top, 9);
        assert_eq!(coord.sessions.get("u1").unwrap().t, 2);
        assert_eq!(coord.metrics.compressions, 2);
        assert_eq!(coord.metrics.inferences, 1);
        assert!(coord.sessions.total_kv_bytes() > 0);
    }

    #[test]
    fn mixed_strategy_tiers_serve_side_by_side() {
        let mut coord = sim_coordinator(4);
        coord.add_context_strat("c", vec![1, 2, 3], Some(StrategyKind::Ccm));
        coord.add_context_strat("w", vec![1, 2, 3], Some(StrategyKind::SlidingWindow));
        coord.add_context_strat("f", vec![1, 2, 3], Some(StrategyKind::NoCompress));
        let qc = coord.query("c", vec![7]);
        let qw = coord.query("w", vec![7]);
        let qf = coord.query("f", vec![7]);
        coord.run_until_idle().unwrap();
        // Every tier answers, and the echo lands on the STAGED last row
        // (retained-context tiers prepend history to the query).
        for (seq, sess, want_staged) in [(qc, "c", 1), (qw, "w", 4), (qf, "f", 4)] {
            let (logits, staged) = coord.take_result_staged(seq).expect(sess);
            assert_eq!(staged, want_staged, "staged len for {sess}");
            let row = logits.row(&[staged - 1]);
            let top = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
            assert_eq!(top, 7, "echoed query token for {sess}");
        }
        // CCM went through the backend's g_comp and holds Mem(t) only;
        // the other tiers absorbed raw tokens session-locally.
        assert!(!coord.sessions.get("c").unwrap().mem.is_empty());
        assert!(coord.sessions.get("w").unwrap().mem.is_empty());
        assert!(coord.sessions.get("f").unwrap().mem.is_empty());
        for k in StrategyKind::ALL {
            assert_eq!(coord.metrics.by_strategy[k.index()].compressions, 1, "{}", k.name());
            assert_eq!(coord.metrics.by_strategy[k.index()].inferences, 1, "{}", k.name());
        }
        let census = coord.sessions.census();
        assert_eq!(census.map(|(n, _)| n), [1, 1, 1]);
        assert!(census[StrategyKind::NoCompress.index()].1 > 0, "raw tail costs KV");
    }

    #[test]
    fn kv_budget_enforcement_skips_pending_sessions() {
        let mut coord = sim_coordinator(8);
        for id in 0..4 {
            coord.add_context(&format!("s{id}"), vec![id, id + 1]);
        }
        coord.run_until_idle().unwrap();
        let per = coord.sessions.get("s0").unwrap().mem.kv_bytes();
        assert!(per > 0);
        // s3 gets new queued work: protected from eviction.
        coord.add_context("s3", vec![1, 2]);
        let evicted = coord.enforce_kv_budget(per);
        assert_eq!(evicted, vec!["s0", "s1", "s2"]);
        assert_eq!(coord.metrics.sessions_evicted, 3);
        assert!(coord.sessions.get("s3").is_ok());
        assert!(coord.sessions.total_kv_bytes() <= per);
        coord.run_until_idle().unwrap();
    }

    #[test]
    fn idle_reaping_respects_ttl_and_pending() {
        let mut coord = sim_coordinator(8);
        coord.add_context("old", vec![1]);
        coord.run_until_idle().unwrap();
        coord.add_context("busy", vec![2]); // stays queued
        let later = Instant::now() + Duration::from_secs(60);
        let reaped = coord.reap_idle(Duration::from_secs(30), later);
        assert_eq!(reaped, vec!["old"]);
        assert_eq!(coord.metrics.sessions_reaped, 1);
        assert!(coord.sessions.get("busy").is_ok());
        coord.run_until_idle().unwrap();
    }

    #[test]
    fn query_after_eviction_degrades_to_empty_memory() {
        let mut coord = sim_coordinator(4);
        coord.add_context("u", vec![5, 6]);
        coord.run_until_idle().unwrap();
        assert!(!coord.sessions.get("u").unwrap().mem.is_empty());
        let evicted = coord.enforce_kv_budget(0);
        assert_eq!(evicted, vec!["u"]);
        let seq = coord.query("u", vec![7]);
        coord.run_until_idle().unwrap();
        let logits = coord.take_result(seq).expect("answered from fresh session");
        assert!(logits.row(&[0]).iter().all(|x| x.is_finite()));
        assert_eq!(coord.sessions.get("u").unwrap().mem.len(), 0);
    }
}
