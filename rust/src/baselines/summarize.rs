//! Extractive context summarization — the MemoryBank baseline (Table 9).
//!
//! The paper compresses dialogue history into *text* with ChatGPT and
//! feeds the summary back as a prompt. Offline, we substitute a
//! deterministic extractive summarizer: score each context token by
//! informativeness (in-context frequency × inverse background frequency,
//! i.e. TF-IDF at token granularity), then keep the highest-scoring
//! tokens in original order up to the budget. The comparison CCM cares
//! about — text summary of length B as context vs compressed KV of
//! length << B — is preserved.

use std::collections::HashMap;

use crate::datagen::vocab;

/// Summarize `chunks` into at most `budget` tokens (order-preserving).
pub fn summarize(chunks: &[Vec<i32>], budget: usize) -> Vec<i32> {
    let flat: Vec<i32> = chunks.iter().flatten().copied().collect();
    if flat.len() <= budget {
        return flat;
    }
    // Token informativeness: content tokens weighted by frequency; rare
    // structural tokens (labels, separators) get a strong prior because
    // they carry the mapping/answer structure.
    let mut tf: HashMap<i32, f64> = HashMap::new();
    for &t in &flat {
        *tf.entry(t).or_insert(0.0) += 1.0;
    }
    let score = |tok: i32, count: f64| -> f64 {
        if (vocab::LABEL_START..vocab::LABEL_END).contains(&tok) {
            1e3 + count
        } else if tok == vocab::SEP {
            1e2
        } else if tok < vocab::WORD_START {
            1.0
        } else {
            count // frequent content tokens summarize the context best
        }
    };
    let mut scored: Vec<(usize, f64)> = flat
        .iter()
        .enumerate()
        .map(|(i, &t)| (i, score(t, tf[&t])))
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    let mut keep: Vec<usize> = scored[..budget].iter().map(|(i, _)| *i).collect();
    keep.sort();
    keep.into_iter().map(|i| flat[i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_budget_and_order() {
        let chunks = vec![vec![30, 31, 2, 9], vec![40, 41, 2, 10], vec![30, 30, 2, 9]];
        let s = summarize(&chunks, 6);
        assert_eq!(s.len(), 6);
        // Labels (9, 10) survive.
        assert!(s.contains(&9) && s.contains(&10));
        // Order preserved: positions of kept tokens are increasing in the
        // original flattening.
        let flat: Vec<i32> = chunks.iter().flatten().copied().collect();
        let mut last = 0usize;
        for tok in &s {
            let idx = flat[last..].iter().position(|x| x == tok).unwrap() + last;
            assert!(idx >= last);
            last = idx + 1;
        }
    }

    #[test]
    fn short_context_passes_through() {
        let chunks = vec![vec![5, 6]];
        assert_eq!(summarize(&chunks, 10), vec![5, 6]);
    }

    #[test]
    fn prefers_frequent_content_tokens() {
        let chunks = vec![vec![100, 100, 100, 200, 201, 202, 203, 204]];
        let s = summarize(&chunks, 3);
        assert_eq!(s, vec![100, 100, 100]);
    }
}
