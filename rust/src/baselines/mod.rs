//! Baselines that are not expressible as a (mask, P) policy:
//!
//! * `rmt`        — the recurrent token-embedding compressor
//!   (RMT / AutoCompressor shape, Tables 8 & 22): sequential model calls
//!   per chunk, summary embeddings carried between calls.
//! * `summarize`  — the MemoryBank-style text-summarization baseline
//!   (Table 9): an extractive summarizer standing in for the paper's
//!   ChatGPT summarizer (see DESIGN.md §2 substitutions).

pub mod rmt;
pub mod summarize;
