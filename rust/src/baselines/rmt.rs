//! Recurrent-compression baseline (RMT / AutoCompressor shape).
//!
//! Context chunks are compressed into `rmt_mem` *token embeddings* by
//! sequential forward passes: chunk j is embedded, the previous summary
//! embeddings are appended, and the final-layer hidden states at the
//! summary positions become the next summary. Inference prepends the
//! summary embeddings to the input. Each step is a separate model call —
//! the sequential structure whose training/inference cost Table 8
//! contrasts with CCM's single parallel forward.

use anyhow::{ensure, Result};

use crate::datagen::OnlineSample;
use crate::model::store::gather_embeddings;
use crate::model::Checkpoint;
use crate::runtime::{Runtime, Value};
use crate::tensor::{IntTensor, Tensor};

pub struct RmtEngine<'rt> {
    pub rt: &'rt Runtime,
    pub ck: &'rt Checkpoint,
}

impl<'rt> RmtEngine<'rt> {
    pub fn new(rt: &'rt Runtime, ck: &'rt Checkpoint) -> RmtEngine<'rt> {
        RmtEngine { rt, ck }
    }

    fn seq_len(&self) -> usize {
        // Must match aot.py's Se for rmt_forward.
        let sc = &self.rt.manifest.scenario;
        (sc.chunk_max + sc.comp_len_max + sc.rmt_mem).max(sc.rmt_mem + sc.input_max)
    }

    /// Initial summary embeddings (the trainable comp_emb rows).
    pub fn init_memory(&self) -> Result<Vec<f32>> {
        let m = &self.rt.manifest;
        let n_mem = m.scenario.rmt_mem;
        let emb = m.lora_layout.slice(&self.ck.lora.data, "comp_emb")?;
        Ok(emb[..n_mem * m.model.d_model].to_vec())
    }

    /// One forward over `[tokens-as-embeddings | extra embeddings]`.
    /// Returns (logits [Se, V], hidden [Se, D]).
    fn forward(
        &self,
        tokens_prefix: &[i32],
        emb_prefix_first: bool,
        mem: &[f32],
    ) -> Result<(Tensor, Tensor)> {
        let m = &self.rt.manifest;
        let (d, se) = (m.model.d_model, self.seq_len());
        let n_mem = mem.len() / d;
        let tok_emb = gather_embeddings(&self.ck.base.data, &m.base_layout, tokens_prefix, d)?;
        let mut embeds = Tensor::zeros(&[1, se, d]);
        let mut valid = Tensor::zeros(&[1, se]);
        let total = tokens_prefix.len() + n_mem;
        ensure!(total <= se, "rmt sequence {total} > {se}");
        let (first, second): (&[f32], &[f32]) =
            if emb_prefix_first { (mem, &tok_emb) } else { (&tok_emb, mem) };
        embeds.data[..first.len()].copy_from_slice(first);
        embeds.data[first.len()..first.len() + second.len()].copy_from_slice(second);
        for i in 0..total {
            valid.data[i] = 1.0;
        }
        let mut pos = IntTensor::zeros(&[1, se]);
        for i in 0..se {
            pos.data[i] = i as i32;
        }
        let nb = m.base_layout.total;
        let nl = m.lora_layout.total;
        let outs = self.rt.execute_f32(
            "rmt_forward_b1",
            &[
                Value::vec_f32(&[nb], self.ck.base.data.clone())?,
                Value::vec_f32(&[nl], self.ck.lora.data.clone())?,
                Value::F32(embeds),
                Value::F32(valid),
                Value::I32(pos),
            ],
        )?;
        Ok((outs[0].clone(), outs[1].clone()))
    }

    /// Compress one chunk: summary' = hidden at the summary positions of
    /// `[emb(chunk) | summary]`.
    pub fn compress_chunk(&self, mem: &[f32], chunk: &[i32]) -> Result<Vec<f32>> {
        let d = self.rt.manifest.model.d_model;
        let n_mem = mem.len() / d;
        let (_, hidden) = self.forward(chunk, false, mem)?;
        let start = chunk.len();
        let mut out = Vec::with_capacity(n_mem * d);
        for i in 0..n_mem {
            out.extend_from_slice(hidden.row(&[start + i]));
        }
        Ok(out)
    }

    /// Score input+target with the summary prefix; returns the average
    /// target log-likelihood (targets start at `input_len` within
    /// `tokens`).
    pub fn score(&self, mem: &[f32], tokens: &[i32], input_len: usize) -> Result<f64> {
        let d = self.rt.manifest.model.d_model;
        let n_mem = mem.len() / d;
        let (logits, _) = self.forward(tokens, true, mem)?;
        let mut total = 0.0f64;
        let n_tgt = tokens.len() - input_len;
        for i in 0..n_tgt {
            // Token index within the packed sequence: n_mem + input_len + i;
            // its predictor row is one before.
            let row = logits.row(&[n_mem + input_len + i - 1]);
            let tgt = tokens[input_len + i] as usize;
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = row.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln() + mx;
            total += (row[tgt] - lse) as f64;
        }
        Ok(total / n_tgt as f64)
    }

    /// Full online evaluation of one sample: sequential compression of
    /// every chunk, then multi-choice scoring. Returns (chosen index,
    /// model calls made) — the call count is the inefficiency Table 8
    /// quantifies.
    pub fn choose(&self, sample: &OnlineSample) -> Result<(usize, usize)> {
        let mut mem = self.init_memory()?;
        let mut calls = 0usize;
        for c in &sample.chunks {
            mem = self.compress_chunk(&mem, c)?;
            calls += 1;
        }
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (ci, choice) in sample.choices.iter().enumerate() {
            let mut toks = sample.input.clone();
            toks.extend_from_slice(choice);
            let ll = self.score(&mem, &toks, sample.input.len())?;
            calls += 1;
            if ll > best.0 {
                best = (ll, ci);
            }
        }
        Ok((best.1, calls))
    }

    /// KV footprint of the summary memory (token-embedding slots act as
    /// n_mem KV entries once processed).
    pub fn mem_kv_bytes(&self) -> usize {
        let m = &self.rt.manifest;
        2 * m.model.n_layers * m.scenario.rmt_mem * m.model.d_model * 4
    }
}
