//! Host-side tensors used by the coordinator (masks, KV buffers, token
//! batches). Deliberately minimal: row-major `f32`/`i32` arrays with
//! shape checking. Device math lives in the XLA artifacts; these types
//! only stage inputs and unpack outputs.

use anyhow::{bail, Result};

/// Row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Row-major i32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        if data.len() != numel(shape) {
            bail!("shape {:?} wants {} elements, got {}", shape, numel(shape), data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < d, "index {idx:?} out of shape {:?} at dim {i}", self.shape);
            off = off * d + x;
        }
        off
    }

    pub fn get(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    /// Mutable row `[..., :]` of the last dimension at a leading index.
    pub fn row_mut(&mut self, lead: &[usize]) -> &mut [f32] {
        let last = *self.shape.last().expect("rank >= 1");
        let mut off = 0;
        for (&x, &d) in lead.iter().zip(&self.shape) {
            off = off * d + x;
        }
        off *= last;
        &mut self.data[off..off + last]
    }

    pub fn row(&self, lead: &[usize]) -> &[f32] {
        let last = *self.shape.last().expect("rank >= 1");
        let mut off = 0;
        for (&x, &d) in lead.iter().zip(&self.shape) {
            off = off * d + x;
        }
        off *= last;
        &self.data[off..off + last]
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// Elementwise a*(1-t) + b*t — used by merge-memory updates.
    pub fn lerp_from(&mut self, other: &Tensor, t: f32) {
        debug_assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a = *a * (1.0 - t) + b * t;
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

impl IntTensor {
    pub fn zeros(shape: &[usize]) -> IntTensor {
        IntTensor { shape: shape.to_vec(), data: vec![0; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<IntTensor> {
        if data.len() != numel(shape) {
            bail!("shape {:?} wants {} elements, got {}", shape, numel(shape), data.len());
        }
        Ok(IntTensor { shape: shape.to_vec(), data })
    }

    pub fn scalar(v: i32) -> IntTensor {
        IntTensor { shape: vec![], data: vec![v] }
    }

    pub fn row_mut(&mut self, lead: &[usize]) -> &mut [i32] {
        let last = *self.shape.last().expect("rank >= 1");
        let mut off = 0;
        for (&x, &d) in lead.iter().zip(&self.shape) {
            off = off * d + x;
        }
        off *= last;
        &mut self.data[off..off + last]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_is_row_major() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set(&[1, 2, 3], 7.0);
        assert_eq!(t.data[1 * 12 + 2 * 4 + 3], 7.0);
        assert_eq!(t.get(&[1, 2, 3]), 7.0);
        assert_eq!(t.offset(&[0, 0, 0]), 0);
    }

    #[test]
    fn rows() {
        let mut t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.row(&[1]), &[3.0, 4.0, 5.0]);
        t.row_mut(&[0])[2] = 9.0;
        assert_eq!(t.get(&[0, 2]), 9.0);
    }

    #[test]
    fn lerp() {
        let mut a = Tensor::from_vec(&[2], vec![0.0, 10.0]).unwrap();
        let b = Tensor::from_vec(&[2], vec![10.0, 0.0]).unwrap();
        a.lerp_from(&b, 0.25);
        assert_eq!(a.data, vec![2.5, 7.5]);
    }

    #[test]
    fn shape_mismatch_errors() {
        assert!(Tensor::from_vec(&[2, 2], vec![1.0]).is_err());
        assert!(IntTensor::from_vec(&[3], vec![1, 2, 3, 4]).is_err());
    }
}
