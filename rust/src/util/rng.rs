//! Deterministic RNG substrate (the `rand` crate is unavailable offline).
//!
//! PCG32 core with helpers used across datagen, init and the property
//! tests. Determinism is load-bearing: synthetic datasets are defined by
//! their seeds, and EXPERIMENTS.md records seed-exact runs.

/// Permuted congruential generator (PCG-XSH-RR 64/32).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream for the same seed (used to split per-identity
    /// generators off a dataset-level seed).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut r = Rng { state: 0, inc: (stream << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(seed);
        r.next_u32();
        r
    }

    /// Derive a child generator; mixes the label into the stream.
    pub fn split(&mut self, label: u64) -> Rng {
        let s = self.next_u64();
        Rng::with_stream(s, label.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        let span = (hi - lo) as u64;
        // Lemire's unbiased bounded generation.
        let mut x = self.next_u64();
        let mut m = (x as u128 * span as u128) >> 64;
        let mut lowbits = (x as u128 * span as u128) as u64;
        if lowbits < span {
            let t = span.wrapping_neg() % span;
            while lowbits < t {
                x = self.next_u64();
                m = (x as u128 * span as u128) >> 64;
                lowbits = (x as u128 * span as u128) as u64;
            }
        }
        lo + m as usize
    }

    pub fn bool(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        assert!(total > 0.0, "all-zero weights");
        let mut x = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn range_is_in_bounds_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.range(3, 13);
            assert!((3..13).contains(&x));
            seen[x - 3] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        let s = r.sample_indices(20, 8);
        assert_eq!(s.len(), 8);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 8);
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[1.0, 0.0, 3.0])] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn split_streams_diverge() {
        let mut root = Rng::new(7);
        let mut a = root.split(1);
        let mut b = root.split(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}
