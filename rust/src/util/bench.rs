//! Hand-rolled micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this directly.
//! Reports mean/median/p95 with simple outlier-robust statistics and a
//! fixed wall-clock budget per case.

use std::time::{Duration, Instant};

/// One benchmark measurement series.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Items/sec for a per-iteration item count.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }
}

/// Run `f` repeatedly: a few warmup iterations, then measure until the
/// budget elapses (min 5, max `max_iters` samples).
pub fn bench<F: FnMut()>(name: &str, budget: Duration, max_iters: usize, mut f: F) -> Stats {
    for _ in 0..2 {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while (samples.len() < 5 || start.elapsed() < budget) && samples.len() < max_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    Stats {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        median_ns: samples[n / 2],
        p95_ns: samples[((n as f64 * 0.95) as usize).min(n - 1)],
        min_ns: samples[0],
    }
}

/// Pretty table printer used by the bench binaries.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        println!("{}", s.trim_end());
    };
    line(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
    for row in rows {
        line(row);
    }
}
