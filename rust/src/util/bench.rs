//! Hand-rolled micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this directly.
//! Reports mean/median/p95/p99 with simple outlier-robust statistics
//! and a fixed wall-clock budget per case. [`Report`] is the
//! machine-readable side: the `BENCH_<n>.json` perf-trajectory
//! artifacts `ccm bench --emit` writes and CI regenerates and compares
//! (schema in docs/BENCH.md).

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::util::json::{escape, Json};

/// One benchmark measurement series.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Items/sec for a per-iteration item count.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }
}

/// Run `f` repeatedly: a few warmup iterations, then measure until the
/// budget elapses (min 5, max `max_iters` samples).
pub fn bench<F: FnMut()>(name: &str, budget: Duration, max_iters: usize, mut f: F) -> Stats {
    for _ in 0..2 {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while (samples.len() < 5 || start.elapsed() < budget) && samples.len() < max_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    Stats {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        median_ns: samples[n / 2],
        p95_ns: samples[((n as f64 * 0.95) as usize).min(n - 1)],
        p99_ns: samples[((n as f64 * 0.99) as usize).min(n - 1)],
        min_ns: samples[0],
    }
}

/// The `q`-th percentile (0..=100) of a raw sample set (sorts a copy;
/// nearest-rank, matching the IPC RTT window's estimator). `None` when
/// empty.
pub fn percentile(samples: &[u64], q: usize) -> Option<u64> {
    if samples.is_empty() {
        return None;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_unstable();
    Some(sorted[(sorted.len() - 1) * q.min(100) / 100])
}

/// The `q`-th per-mille percentile (0..=1000) of a raw sample set —
/// [`percentile`] at 0.1% resolution, for tail metrics like p99.9
/// (`q = 999`) where whole-percent ranks are too coarse. Nearest-rank,
/// `None` when empty.
pub fn percentile_mille(samples: &[u64], q: usize) -> Option<u64> {
    if samples.is_empty() {
        return None;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_unstable();
    Some(sorted[(sorted.len() - 1) * q.min(1000) / 1000])
}

/// Pretty table printer used by the bench binaries.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        println!("{}", s.trim_end());
    };
    line(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
    for row in rows {
        line(row);
    }
}

/// One scenario's results in a [`Report`]: a scenario name, an
/// optional codec qualifier (the json-vs-binary IPC comparison), and
/// flat numeric metrics whose units are part of the metric name
/// (`round_p99_ms`, `rounds_per_sec`).
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub codec: Option<String>,
    pub metrics: Vec<(String, f64)>,
}

impl Scenario {
    pub fn new(name: &str, codec: Option<&str>) -> Scenario {
        Scenario { name: name.into(), codec: codec.map(str::to_string), metrics: Vec::new() }
    }

    pub fn push(&mut self, metric: &str, value: f64) {
        self.metrics.push((metric.into(), value));
    }

    pub fn metric(&self, name: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }

    /// Display label: `name` or `name[codec]`.
    pub fn label(&self) -> String {
        match &self.codec {
            Some(codec) => format!("{}[{codec}]", self.name),
            None => self.name.clone(),
        }
    }
}

/// A `BENCH_<n>.json` perf-trajectory report. Serialized with one
/// scenario object per line so trajectory diffs stay readable in
/// review; metric values round to 3 decimals (microsecond precision on
/// millisecond metrics).
#[derive(Debug, Clone, PartialEq)]
pub struct Report {
    pub schema: u32,
    pub pr: u32,
    pub scenarios: Vec<Scenario>,
}

impl Report {
    pub fn new(pr: u32) -> Report {
        Report { schema: 1, pr, scenarios: Vec::new() }
    }

    pub fn find(&self, name: &str, codec: Option<&str>) -> Option<&Scenario> {
        self.scenarios.iter().find(|s| s.name == name && s.codec.as_deref() == codec)
    }

    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\n  \"schema\": {},\n  \"pr\": {},\n  \"scenarios\": [\n",
            self.schema, self.pr
        );
        for (i, sc) in self.scenarios.iter().enumerate() {
            out.push_str(&format!("    {{\"name\": {}", escape(&sc.name)));
            if let Some(codec) = &sc.codec {
                out.push_str(&format!(", \"codec\": {}", escape(codec)));
            }
            for (k, v) in &sc.metrics {
                out.push_str(&format!(", {}: {v:.3}", escape(k)));
            }
            out.push_str(if i + 1 < self.scenarios.len() { "},\n" } else { "}\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    pub fn parse(src: &str) -> Result<Report> {
        let j = Json::parse(src)?;
        let mut report = Report::new(j.get("pr")?.usize()? as u32);
        report.schema = j.get("schema")?.usize()? as u32;
        for row in j.get("scenarios")?.arr()? {
            let Json::Obj(fields) = row else { bail!("scenario row is not an object") };
            let name = row.get("name")?.str()?;
            let codec = row.opt("codec").and_then(|v| v.str().ok());
            let mut sc = Scenario::new(name, codec);
            for (key, value) in fields {
                if let Json::Num(v) = value {
                    sc.push(key, *v);
                }
            }
            report.scenarios.push(sc);
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_roundtrips_through_json() {
        let mut report = Report::new(7);
        let mut sc = Scenario::new("ipc-2worker", Some("binary"));
        sc.push("rounds_per_sec", 1234.5);
        sc.push("ipc_rtt_p99_ms", 0.25);
        report.scenarios.push(sc);
        report.scenarios.push(Scenario::new("serve-throughput", None));
        let parsed = Report::parse(&report.to_json()).expect("valid report JSON");
        // Metric ORDER is not preserved (objects parse into a sorted
        // map); values, names, and codecs are.
        assert_eq!((parsed.schema, parsed.pr, parsed.scenarios.len()), (1, 7, 2));
        assert!(parsed.find("serve-throughput", None).is_some());
        let sc = parsed.find("ipc-2worker", Some("binary")).expect("scenario present");
        assert_eq!(sc.metric("rounds_per_sec"), Some(1234.5));
        assert_eq!(sc.metric("ipc_rtt_p99_ms"), Some(0.25));
        assert_eq!(sc.label(), "ipc-2worker[binary]");
        assert!(parsed.find("ipc-2worker", Some("json")).is_none());
    }

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile(&[], 50), None);
        assert_eq!(percentile(&[7], 99), Some(7));
        let samples: Vec<u64> = (1..=100).rev().collect();
        assert_eq!(percentile(&samples, 50), Some(50));
        assert_eq!(percentile(&samples, 99), Some(99));
        assert_eq!(percentile(&samples, 100), Some(100));
    }

    #[test]
    fn percentile_mille_resolves_the_deep_tail() {
        assert_eq!(percentile_mille(&[], 999), None);
        assert_eq!(percentile_mille(&[7], 999), Some(7));
        let samples: Vec<u64> = (1..=2000).rev().collect();
        assert_eq!(percentile_mille(&samples, 500), Some(1000));
        assert_eq!(percentile_mille(&samples, 990), Some(1980));
        // p99.9 and p100 are distinct at this resolution — the whole
        // point vs whole-percent `percentile`.
        assert_eq!(percentile_mille(&samples, 999), Some(1998));
        assert_eq!(percentile_mille(&samples, 1000), Some(2000));
        // Agrees with `percentile` at whole-percent ranks.
        assert_eq!(percentile_mille(&samples, 990), percentile(&samples, 99));
    }
}
