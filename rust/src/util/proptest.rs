//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs the property over `cases` seeded
//! generators; on failure it reports the failing seed so the case can be
//! replayed exactly with `replay(seed, f)`. Used by the coordinator and
//! memory invariant tests.

use super::rng::Rng;

/// Outcome of one property case.
pub type PropResult = Result<(), String>;

/// Run a property across `cases` deterministic seeds. Panics (test
/// failure) with the seed and message of the first failing case.
pub fn check<F: FnMut(&mut Rng) -> PropResult>(name: &str, cases: u64, mut f: F) {
    for seed in 0..cases {
        let mut rng = Rng::new(0x5eed_0000 + seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property {name} failed at seed {seed}: {msg}");
        }
    }
}

/// Replay a single failing seed (for debugging).
pub fn replay<F: FnMut(&mut Rng) -> PropResult>(seed: u64, mut f: F) -> PropResult {
    let mut rng = Rng::new(0x5eed_0000 + seed);
    f(&mut rng)
}

/// Assert helper producing `PropResult`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("range-bounds", 50, |rng| {
            let x = rng.range(0, 10);
            prop_assert!(x < 10, "x = {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn reports_failing_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn replay_reproduces() {
        let mut first = None;
        check("record", 1, |rng| {
            first = Some(rng.next_u64());
            Ok(())
        });
        let mut second = None;
        replay(0, |rng| {
            second = Some(rng.next_u64());
            Ok(())
        })
        .unwrap();
        assert_eq!(first, second);
    }
}
