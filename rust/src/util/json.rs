//! Minimal JSON parser for `artifacts/<config>/manifest.json`.
//!
//! The manifest is the only structured interchange between the Python
//! compile path and this runtime, so a small hand-rolled parser keeps the
//! binary dependency-free (serde is not available in this offline build).
//! Supports the full JSON grammar except `\u` surrogate pairs outside the
//! BMP (the manifest is ASCII).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        let n = self.f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a usize: {n}");
        }
        Ok(n as usize)
    }

    pub fn i64(&self) -> Result<i64> {
        let n = self.f64()?;
        if n.fract() != 0.0 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    /// Convenience: array of usizes (shape vectors etc.).
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write!(f, "{}", escape(s)),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", escape(k))?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Escape `s` as a JSON string literal, quotes included. Debug-format
/// (`{:?}`) is NOT a JSON escape (it emits `\u{7f}`-style escapes that
/// JSON parsers reject); server responses must use this instead.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char)
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let n = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(n).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence.
                    let len = utf8_len(c);
                    out.push_str(std::str::from_utf8(&self.b[self.i - 1..self.i - 1 + len])?);
                    self.i += len - 1;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| anyhow!("bad number {s:?}"))?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected , or ] at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected , or }} at byte {}, got {:?}", self.i, c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":{}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().arr().unwrap()[2].get("b").unwrap().str().unwrap(),
            "x"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn parses_unicode_and_escapes() {
        let v = Json::parse(r#""A\t\"q\"""#).unwrap();
        assert_eq!(v, Json::Str("A\t\"q\"".into()));
        let v = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v, Json::Str("héllo".into()));
    }

    #[test]
    fn display_roundtrips_control_characters() {
        // Display must emit valid JSON (it uses escape(), not Debug,
        // which would produce \u{1}-style escapes the parser rejects).
        let v = Json::Arr(vec![
            Json::Str("a\u{1}b\n".into()),
            Json::Obj([("k\"ey".to_string(), Json::Num(1.0))].into_iter().collect()),
        ]);
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn escape_roundtrips_through_parser() {
        for s in ["plain", "line\nbreak", "q\"uote\\slash", "tab\there", "\u{1}ctl", "héllo"] {
            let lit = escape(s);
            assert_eq!(Json::parse(&lit).unwrap(), Json::Str(s.to_string()), "{lit}");
        }
        // Multi-line metrics reports (the stats payload) stay valid JSON.
        let report = "a=1 b=2\nlatency: 0.5 ms\n\"quoted\"";
        let wrapped = format!("{{\"report\":{}}}", escape(report));
        let parsed = Json::parse(&wrapped).unwrap();
        assert_eq!(parsed.get("report").unwrap().str().unwrap(), report);
    }

    #[test]
    fn usize_vec_roundtrip() {
        let v = Json::parse("[1, 2, 384]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![1, 2, 384]);
        assert!(Json::parse("[1.5]").unwrap().usize_vec().is_err());
    }
}
