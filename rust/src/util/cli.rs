//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments. Subcommand dispatch lives in `main.rs`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse a raw argv slice (without the program name).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Flag value, falling back to an environment variable, then to a
    /// default. Serving flags use this so one knob works both ways:
    /// `--reactor` beats `CCM_SERVE_REACTOR` (the CI matrix variable),
    /// which beats the built-in default.
    pub fn str_env(&self, key: &str, env: &str, default: &str) -> String {
        if let Some(v) = self.flags.get(key) {
            return v.clone();
        }
        match std::env::var(env) {
            Ok(v) if !v.is_empty() => v,
            _ => default.to_string(),
        }
    }

    /// Integer flag with env fallback that also accepts the literal
    /// `auto`, resolved to `auto_value` by the caller (serving uses
    /// this for `--reactors auto` = min(4, cores)). Precedence matches
    /// [`str_env`](Self::str_env): flag beats env beats `default`.
    pub fn usize_env_auto(
        &self,
        key: &str,
        env: &str,
        auto_value: usize,
        default: &str,
    ) -> Result<usize> {
        let raw = self.str_env(key, env, default);
        if raw == "auto" {
            return Ok(auto_value);
        }
        raw.parse().map_err(|_| anyhow!("--{key} expects an integer or `auto`, got {raw:?}"))
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.flags
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a float, got {v:?}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list flag.
    pub fn list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.flags.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => {
                v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
            }
        }
    }

    /// Error out on unknown flags (catches typos in experiment scripts).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(&argv(&["train", "--steps", "100", "--fast", "--lr=0.01"])).unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize("steps", 0).unwrap(), 100);
        assert!(a.bool("fast"));
        assert_eq!(a.f32("lr", 0.0).unwrap(), 0.01);
    }

    #[test]
    fn defaults_and_errors() {
        let a = Args::parse(&argv(&["--x", "abc"])).unwrap();
        assert_eq!(a.usize("missing", 7).unwrap(), 7);
        assert!(a.usize("x", 0).is_err());
        assert!(a.require("nope").is_err());
    }

    #[test]
    fn str_env_prefers_flag_then_default() {
        // Deliberately no std::env::set_var here: unit tests run
        // multi-threaded and other tests read the environment (e.g.
        // ServerConfig::new reads CCM_SERVE_REACTOR), and concurrent
        // setenv/getenv is undefined behavior in glibc. The env-beats-
        // default branch is exercised for real by the CI host-suite
        // matrix, which exports CCM_SERVE_REACTOR process-wide.
        let env = "CCM_TEST_CLI_STR_ENV_UNSET";
        let a = Args::parse(&argv(&["--reactor", "threads"])).unwrap();
        assert_eq!(a.str_env("reactor", env, "auto"), "threads", "flag wins");
        let b = Args::parse(&argv(&[])).unwrap();
        assert_eq!(b.str_env("reactor", env, "auto"), "auto", "default when flag+env absent");
    }

    #[test]
    fn usize_env_auto_resolves_auto_and_integers() {
        // No set_var here either (see str_env test above); the env
        // branch is shared with str_env and covered by the CI matrix.
        let env = "CCM_TEST_CLI_USIZE_ENV_AUTO_UNSET";
        let a = Args::parse(&argv(&["--reactors", "auto"])).unwrap();
        assert_eq!(a.usize_env_auto("reactors", env, 4, "1").unwrap(), 4, "auto resolves");
        let b = Args::parse(&argv(&["--reactors", "2"])).unwrap();
        assert_eq!(b.usize_env_auto("reactors", env, 4, "auto").unwrap(), 2, "flag wins");
        let c = Args::parse(&argv(&[])).unwrap();
        assert_eq!(c.usize_env_auto("reactors", env, 4, "auto").unwrap(), 4, "default auto");
        assert_eq!(c.usize_env_auto("reactors", env, 4, "1").unwrap(), 1, "default int");
        let d = Args::parse(&argv(&["--reactors", "many"])).unwrap();
        assert!(d.usize_env_auto("reactors", env, 4, "auto").is_err());
    }

    #[test]
    fn lists_and_known() {
        let a = Args::parse(&argv(&["--methods", "ccm-concat, ccm-merge"])).unwrap();
        assert_eq!(a.list("methods", &[]), vec!["ccm-concat", "ccm-merge"]);
        assert_eq!(a.list("other", &["x"]), vec!["x"]);
        assert!(a.check_known(&["methods"]).is_ok());
        assert!(a.check_known(&["nope"]).is_err());
    }
}
