//! Substrate utilities: JSON, RNG, CLI, bench + property-test harnesses,
//! and small logging/timing helpers. Everything here is dependency-free
//! (the offline build has only `xla` and `anyhow`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;

use std::time::Instant;

/// Scoped wall-clock timer: `let _t = Timer::new("phase");` logs on drop.
pub struct Timer {
    label: String,
    start: Instant,
    quiet: bool,
}

impl Timer {
    pub fn new(label: &str) -> Self {
        Timer { label: label.to_string(), start: Instant::now(), quiet: false }
    }

    pub fn quiet(label: &str) -> Self {
        Timer { label: label.to_string(), start: Instant::now(), quiet: true }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        if !self.quiet {
            eprintln!("[time] {}: {:.1} ms", self.label, self.elapsed_ms());
        }
    }
}

/// Simple leveled logging controlled by `CCM_LOG` (error|info|debug).
pub fn log_level() -> u8 {
    match std::env::var("CCM_LOG").as_deref() {
        Ok("debug") => 2,
        Ok("error") => 0,
        _ => 1,
    }
}

#[macro_export]
macro_rules! info {
    ($($fmt:tt)*) => {
        if $crate::util::log_level() >= 1 { eprintln!("[ccm] {}", format!($($fmt)*)); }
    };
}

#[macro_export]
macro_rules! debug {
    ($($fmt:tt)*) => {
        if $crate::util::log_level() >= 2 { eprintln!("[ccm:debug] {}", format!($($fmt)*)); }
    };
}

/// Mean of a slice (bench/eval helper).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    #[test]
    fn mean_works() {
        assert_eq!(super::mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!(super::mean(&[]).is_nan());
    }
}
