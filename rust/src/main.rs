//! `ccm` CLI — leader entrypoint for the Compressed Context Memory system.
//!
//! Subcommands:
//!   train      — pretrain the base LM and/or train compression adapters
//!   eval       — evaluate methods on the synthetic online-inference suites
//!   serve      — run the JSON-lines TCP serving coordinator
//!   worker     — run one shard executor process for a --workers serve
//!   bench      — serving benchmarks; --emit writes BENCH_<n>.json
//!   loadgen    — open-loop paper-workload traffic replay (docs/SCENARIOS.md)
//!   stream     — streaming-mode perplexity (PG19-style, Figure 8)
//!   reproduce  — regenerate a paper table/figure (see DESIGN.md §6)
//!   info       — print manifest/runtime information

use anyhow::{bail, Result};
use ccm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => cmd_info(&args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            // Subcommands that need the full system are wired in as the
            // corresponding modules land; dispatch lives here so the CLI
            // surface is stable.
            match other {
                "train" => ccm::cli_train(&args),
                "eval" => ccm::cli_eval(&args),
                "serve" => ccm::cli_serve(&args),
                "worker" => ccm::cli_worker(&args),
                "stream" => ccm::cli_stream(&args),
                "bench" => ccm::cli_bench(&args),
                "loadgen" => ccm::cli_loadgen(&args),
                "reproduce" => ccm::cli_reproduce(&args),
                _ => {
                    print_help();
                    bail!("unknown command {other:?}")
                }
            }
        }
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let config = args.str("config", "main");
    let rt = ccm::runtime::Runtime::from_config(&config)?;
    let m = &rt.manifest;
    println!("config   : {}", m.config_name);
    println!("platform : {}", rt.platform());
    println!(
        "model    : d={} L={} H={} V={} (base params {}, adapter params {})",
        m.model.d_model,
        m.model.n_layers,
        m.model.n_heads,
        m.model.vocab,
        m.base_layout.total,
        m.lora_layout.total
    );
    println!(
        "scenario : T={} chunk<={} comp_len={} input<={} S={} M={}",
        m.scenario.t_max,
        m.scenario.chunk_max,
        m.scenario.comp_len_max,
        m.scenario.input_max,
        m.scenario.seq_train,
        m.scenario.mem_slots
    );
    println!("artifacts:");
    for a in &m.artifacts {
        println!("  {:24} {} inputs, {} outputs", a.name, a.inputs.len(), a.outputs.len());
    }
    let n = ccm::masks::verify_goldens(&m.mask_goldens)?;
    println!("mask goldens: {n} cases verified against python/compile/masks.py");
    Ok(())
}

fn print_help() {
    println!(
        "ccm — Compressed Context Memory (ICLR 2024) coordinator\n\
         \n\
         USAGE: ccm <command> [--config main] [flags]\n\
         \n\
         COMMANDS:\n\
           info                         manifest + runtime info, golden check\n\
           train --phase lm|ccm|rmt     run a training phase (see --help-train)\n\
           eval --dataset metaicl ...   evaluate methods over time steps\n\
           serve --port 7878            start the serving coordinator\n\
                 [--shards N]           executor shards (stable session routing)\n\
                 [--workers N]          one worker PROCESS per shard (supervised)\n\
                 [--worker-addr a,b]    connect to externally-started workers\n\
                 [--eviction POLICY]    oldest | lru | largest-bytes\n\
                 [--strategy TIER]      default tier: ccm | sliding-window | none\n\
                 [--tiers SPEC]         QoS buckets, e.g. ccm=8/4 (refill/burst)\n\
                 [--hibernate-dir DIR]  spill idle sessions' Mem(t) to disk\n\
                 [--hibernate-after-secs 60]  idle threshold before spilling\n\
           worker --shard K --shards N  run one shard executor process (IPC)\n\
                 [--orphan-grace-secs 120]  first-connection orphan grace\n\
           bench --emit BENCH_10.json   serving benchmarks (json vs binary IPC)\n\
           loadgen --scenario mixed     open-loop paper-workload traffic replay\n\
                 [--users N --rate R]   population size / aggregate req/s\n\
                 [--mix dialog@ccm=3,.] tiered population (workload[@tier]=w)\n\
                 [--addr HOST:PORT]     drive an external serve (else self-serve)\n\
           stream --budget 160          streaming perplexity (Figure 8)\n\
           reproduce --exp table1|fig7  regenerate a paper table/figure\n"
    );
}
