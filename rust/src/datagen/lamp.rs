//! Synthetic LaMP: personalized categorization.
//!
//! Each identity is a *user* with an idiosyncratic tagging rule: the same
//! item features map to different category labels for different users.
//! A context chunk is one profile entry `[marker, item tokens..., SEP,
//! category]`; the input is a new item to categorize *for this user*.
//! Profiles of one user share information (the user's rule), mirroring
//! the complementary-context structure the paper observes on LaMP.

use super::{identity_rng, mixture_tokens, vocab, OnlineDataset, OnlineSample, Split};
use crate::model::manifest::ScenarioConfig;
use crate::util::rng::Rng;

const DS_ID: u64 = 2;

pub struct Lamp {
    seed: u64,
    vocab_size: usize,
    pub n_train: usize,
    pub n_test: usize,
    t_max: usize,
    chunk_max: usize,
    input_max: usize,
    n_categories: usize,
    n_aspects: usize,
    p_signature: f32,
}

struct User {
    /// Aspect -> signature tokens (aspects are global feature groups).
    aspect_tokens: Vec<Vec<i32>>,
    /// The user's personal aspect -> category assignment.
    category_of_aspect: Vec<usize>,
    /// Category labels (shared token region, same for all users).
    labels: Vec<i32>,
}

impl Lamp {
    pub fn new(seed: u64, sc: &ScenarioConfig, vocab_size: usize) -> Lamp {
        Lamp {
            seed,
            vocab_size,
            n_train: 100,
            n_test: 64,
            t_max: sc.t_max,
            chunk_max: sc.chunk_max,
            input_max: sc.input_max,
            n_categories: 4,
            n_aspects: 6,
            p_signature: 0.9,
        }
    }

    fn user(&self, split: Split, identity: usize) -> User {
        // Aspects (feature vocabularies) are GLOBAL — shared across users —
        // so the only thing a profile can teach is the user's assignment.
        let mut grng = Rng::with_stream(self.seed ^ 0x61a5, DS_ID);
        let word_lo = vocab::WORD_START as usize;
        let word_hi = vocab::word_end(self.vocab_size) as usize;
        let per = 5usize;
        let all = grng.sample_indices(word_hi - word_lo, self.n_aspects * per);
        let aspect_tokens: Vec<Vec<i32>> = (0..self.n_aspects)
            .map(|a| all[a * per..(a + 1) * per].iter().map(|&i| (word_lo + i) as i32).collect())
            .collect();
        let labels: Vec<i32> = (0..self.n_categories)
            .map(|c| vocab::LABEL_START + c as i32)
            .collect();
        // The personal rule.
        let mut rng = identity_rng(self.seed, DS_ID, split, identity);
        let category_of_aspect =
            (0..self.n_aspects).map(|_| rng.range(0, self.n_categories)).collect();
        User { aspect_tokens, category_of_aspect, labels }
    }

    fn item(&self, user: &User, rng: &mut Rng, max_len: usize) -> (Vec<i32>, usize) {
        let aspect = rng.range(0, user.aspect_tokens.len());
        let body_len = rng.range(4, max_len);
        let toks = mixture_tokens(
            rng,
            &user.aspect_tokens[aspect],
            vocab::WORD_START,
            vocab::WORD_START + 64,
            self.p_signature,
            body_len,
        );
        (toks, user.category_of_aspect[aspect])
    }
}

impl OnlineDataset for Lamp {
    fn name(&self) -> &'static str {
        "lamp"
    }

    fn n_identities(&self, split: Split) -> usize {
        match split {
            Split::Train => self.n_train,
            Split::Test => self.n_test,
        }
    }

    fn t_max(&self) -> usize {
        self.t_max
    }

    fn is_multi_choice(&self) -> bool {
        true
    }

    fn sample(&self, split: Split, identity: usize, t: usize) -> OnlineSample {
        assert!(t >= 1 && t <= self.t_max);
        let user = self.user(split, identity);
        let mut rng = identity_rng(self.seed ^ 0xB0B, DS_ID, split, identity);
        let chunks: Vec<Vec<i32>> = (0..t)
            .map(|_| {
                let (toks, cat) = self.item(&user, &mut rng, self.chunk_max - 3);
                let mut c = vec![vocab::MARKER_START + 2]; // "profile:" marker
                c.extend(toks);
                c.push(vocab::SEP);
                c.push(user.labels[cat]);
                c
            })
            .collect();
        // Query fixed per identity: the test set is identical across t.
        let mut qrng = identity_rng(self.seed ^ 0x9E52, DS_ID, split, identity);
        let (toks, cat) = self.item(&user, &mut qrng, self.input_max - 4);
        let mut input = vec![vocab::MARKER_START + 3]; // "query:" marker
        input.extend(toks);
        input.push(vocab::SEP);
        OnlineSample {
            chunks,
            input,
            target: vec![user.labels[cat]],
            choices: user.labels.iter().map(|&l| vec![l]).collect(),
            correct: cat,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sc() -> ScenarioConfig {
        ScenarioConfig {
            t_max: 8,
            chunk_max: 24,
            comp_len_max: 4,
            input_max: 32,
            seq_train: 384,
            mem_slots: 48,
            batch_train: 16,
            infer_batches: vec![1, 8],
            decode_cache: 96,
            rmt_unroll: 4,
            rmt_mem: 4,
        }
    }

    #[test]
    fn users_share_aspects_but_not_rules() {
        let ds = Lamp::new(3, &sc(), 512);
        let u1 = ds.user(Split::Train, 0);
        let u2 = ds.user(Split::Train, 1);
        assert_eq!(u1.aspect_tokens, u2.aspect_tokens);
        // With 4^6 possible rules, two users almost surely differ.
        assert_ne!(u1.category_of_aspect, u2.category_of_aspect);
    }

    #[test]
    fn personalization_is_required() {
        // The same item tokens can get different labels for different
        // users — so no-context accuracy is capped near chance.
        let ds = Lamp::new(3, &sc(), 512);
        let mut differs = false;
        for id in 0..10 {
            let ua = ds.user(Split::Train, id);
            let ub = ds.user(Split::Train, id + 1);
            if ua.category_of_aspect[0] != ub.category_of_aspect[0] {
                differs = true;
            }
        }
        assert!(differs);
    }

    #[test]
    fn sample_shapes() {
        let ds = Lamp::new(3, &sc(), 512);
        for t in [1, 5, 8] {
            let s = ds.sample(Split::Test, 2, t);
            assert_eq!(s.chunks.len(), t);
            for c in &s.chunks {
                assert!(c.len() <= 24);
            }
            assert!(s.input.len() + 1 <= 32);
            assert_eq!(s.choices.len(), 4);
            assert_eq!(s.choices[s.correct], s.target);
        }
    }

    #[test]
    fn deterministic() {
        let ds = Lamp::new(3, &sc(), 512);
        assert_eq!(ds.sample(Split::Test, 1, 4).chunks, ds.sample(Split::Test, 1, 4).chunks);
    }
}
