//! Synthetic DailyDialog: multi-turn conversation with distinct-per-turn
//! information.
//!
//! Each identity is a dialogue driven by a sticky Markov chain over
//! latent topics; every turn samples content from its topic's unigram
//! distribution and *calls back* tokens from earlier turns. Because each
//! turn introduces new information, merging compressed states loses more
//! than concatenating them — the effect behind Figure 7-c. The metric is
//! next-turn perplexity, as in the paper.

use super::{identity_rng, vocab, OnlineDataset, OnlineSample, Split};
use crate::model::manifest::ScenarioConfig;
use crate::util::rng::Rng;

const DS_ID: u64 = 3;

pub struct Dialog {
    seed: u64,
    vocab_size: usize,
    pub n_train: usize,
    pub n_test: usize,
    t_max: usize,
    chunk_max: usize,
    input_max: usize,
    n_topics: usize,
    topic_words: usize,
    p_stay: f32,
    p_callback: f32,
}

impl Dialog {
    pub fn new(seed: u64, sc: &ScenarioConfig, vocab_size: usize) -> Dialog {
        Dialog {
            seed,
            vocab_size,
            n_train: 200,
            n_test: 60,
            t_max: sc.t_max,
            chunk_max: sc.chunk_max,
            input_max: sc.input_max,
            n_topics: 12,
            topic_words: 18,
            p_stay: 0.7,
            p_callback: 0.25,
        }
    }

    /// Global topic vocabularies (shared across dialogues, like a language).
    fn topic_vocab(&self) -> Vec<Vec<i32>> {
        let mut grng = Rng::with_stream(self.seed ^ 0xD1A1, DS_ID);
        let word_lo = vocab::WORD_START as usize;
        let word_hi = vocab::word_end(self.vocab_size) as usize;
        (0..self.n_topics)
            .map(|_| {
                grng.sample_indices(word_hi - word_lo, self.topic_words)
                    .into_iter()
                    .map(|i| (word_lo + i) as i32)
                    .collect()
            })
            .collect()
    }

    /// Generate the full dialogue (t_max + 1 turns) for an identity.
    /// Turn generation is prefix-stable by construction.
    fn turns(&self, split: Split, identity: usize) -> Vec<Vec<i32>> {
        let topics = self.topic_vocab();
        let mut rng = identity_rng(self.seed, DS_ID, split, identity);
        let mut topic = rng.range(0, self.n_topics);
        let mut turns: Vec<Vec<i32>> = Vec::new();
        for turn_idx in 0..=self.t_max {
            if turn_idx > 0 && !rng.bool(self.p_stay) {
                topic = rng.range(0, self.n_topics);
            }
            let speaker = vocab::MARKER_START + (turn_idx % 2) as i32;
            let len = rng.range(5, self.chunk_max.min(self.input_max) - 2);
            let mut turn = vec![speaker];
            for _ in 0..len {
                // Callbacks copy a content token from an earlier turn — the
                // long-range dependency that rewards remembering history.
                if !turns.is_empty() && rng.bool(self.p_callback) {
                    let src = &turns[rng.range(0, turns.len())];
                    if src.len() > 1 {
                        turn.push(src[rng.range(1, src.len())]);
                        continue;
                    }
                }
                turn.push(*rng.choice(&topics[topic]));
            }
            turns.push(turn);
        }
        turns
    }
}

impl OnlineDataset for Dialog {
    fn name(&self) -> &'static str {
        "dialog"
    }

    fn n_identities(&self, split: Split) -> usize {
        match split {
            Split::Train => self.n_train,
            Split::Test => self.n_test,
        }
    }

    fn t_max(&self) -> usize {
        self.t_max
    }

    fn is_multi_choice(&self) -> bool {
        false // perplexity on the next turn
    }

    fn sample(&self, split: Split, identity: usize, t: usize) -> OnlineSample {
        assert!(t >= 1 && t <= self.t_max);
        let turns = self.turns(split, identity);
        let chunks = turns[..t].to_vec();
        // I(t) is just the speaker marker of the next turn; O(t) is the
        // turn's content (the model predicts the reply).
        let next = &turns[t];
        let input = vec![next[0]];
        let target = next[1..].to_vec();
        OnlineSample { chunks, input, target, choices: vec![], correct: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sc() -> ScenarioConfig {
        ScenarioConfig {
            t_max: 12,
            chunk_max: 24,
            comp_len_max: 4,
            input_max: 32,
            seq_train: 384,
            mem_slots: 48,
            batch_train: 16,
            infer_batches: vec![1, 8],
            decode_cache: 96,
            rmt_unroll: 4,
            rmt_mem: 4,
        }
    }

    #[test]
    fn prefix_stability_across_time_steps() {
        let ds = Dialog::new(5, &sc(), 512);
        let s4 = ds.sample(Split::Test, 7, 4);
        let s9 = ds.sample(Split::Test, 7, 9);
        assert_eq!(&s9.chunks[..4], s4.chunks.as_slice());
    }

    #[test]
    fn turns_alternate_speakers_and_fit() {
        let ds = Dialog::new(5, &sc(), 512);
        let s = ds.sample(Split::Train, 0, 12);
        for (i, c) in s.chunks.iter().enumerate() {
            assert_eq!(c[0], vocab::MARKER_START + (i % 2) as i32);
            assert!(c.len() <= 24);
        }
        assert!(s.input.len() + s.target.len() <= 32);
        assert!(!s.target.is_empty());
    }

    #[test]
    fn callbacks_create_cross_turn_dependencies() {
        // Later turns should reuse tokens from earlier turns well above
        // the rate expected from topic overlap alone.
        let ds = Dialog::new(5, &sc(), 512);
        let mut reused = 0usize;
        let mut total = 0usize;
        for id in 0..20 {
            let turns = ds.turns(Split::Train, id);
            let early: std::collections::HashSet<i32> =
                turns[..6].iter().flat_map(|t| t[1..].iter().copied()).collect();
            for t in &turns[6..] {
                for tok in &t[1..] {
                    total += 1;
                    reused += usize::from(early.contains(tok));
                }
            }
        }
        let frac = reused as f32 / total as f32;
        assert!(frac > 0.3, "cross-turn reuse {frac}");
    }

    #[test]
    fn distinct_dialogues_differ() {
        let ds = Dialog::new(5, &sc(), 512);
        assert_ne!(ds.sample(Split::Train, 0, 3).chunks, ds.sample(Split::Train, 1, 3).chunks);
    }
}
