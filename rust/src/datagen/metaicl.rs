//! Synthetic MetaICL: multi-task in-context classification.
//!
//! Each identity is a *task*: a hidden mapping from class-signature token
//! sets to label tokens. A context chunk c(t) is one demonstration
//! `[marker, item tokens..., SEP, label]`; the input I(t) is a fresh
//! problem and the target its label. Demonstrations of one task are
//! mutually complementary (they reveal the same mapping) — the property
//! that makes CCM-merge ≈ CCM-concat on this suite (paper §4.1).
//!
//! Train and test identities use disjoint signature draws, so evaluation
//! measures compression of *unseen tasks*, as in the paper's
//! high-to-low-resources split.

use super::{identity_rng, mixture_tokens, vocab, OnlineDataset, OnlineSample, Split};
use crate::model::manifest::ScenarioConfig;
use crate::util::rng::Rng;

const DS_ID: u64 = 1;

pub struct MetaIcl {
    seed: u64,
    vocab_size: usize,
    pub n_train: usize,
    pub n_test: usize,
    t_max: usize,
    chunk_max: usize,
    input_max: usize,
    /// Probability an item token comes from the class signature.
    p_signature: f32,
    n_classes_lo: usize,
    n_classes_hi: usize,
    sig_size: usize,
}

struct Task {
    /// Per-class signature token sets.
    signatures: Vec<Vec<i32>>,
    /// Per-class label token.
    labels: Vec<i32>,
}

impl MetaIcl {
    pub fn new(seed: u64, sc: &ScenarioConfig, vocab_size: usize) -> MetaIcl {
        MetaIcl {
            seed,
            vocab_size,
            n_train: 61, // paper: 61 train tasks
            n_test: 64,  // paper: 26 unseen tasks; more here to cut eval noise
            t_max: sc.t_max,
            chunk_max: sc.chunk_max,
            input_max: sc.input_max,
            p_signature: 0.9,
            n_classes_lo: 2,
            n_classes_hi: 5,
            sig_size: 4,
        }
    }

    fn task(&self, split: Split, identity: usize) -> Task {
        let mut rng = identity_rng(self.seed, DS_ID, split, identity);
        let n_classes = rng.range(self.n_classes_lo, self.n_classes_hi);
        // Distinct label tokens for this task.
        let label_span = (vocab::LABEL_END - vocab::LABEL_START) as usize;
        let labels: Vec<i32> = rng
            .sample_indices(label_span, n_classes)
            .into_iter()
            .map(|i| vocab::LABEL_START + i as i32)
            .collect();
        // Distinct signature words per class, drawn from a SHARED pool
        // (ids WORD_START+64..): every signature token is seen during
        // pretraining across tasks; unseen test tasks are new
        // *combinations* — as in real MetaICL, where words are known but
        // tasks are not.
        let word_lo = vocab::WORD_START as usize + 64;
        let word_hi = vocab::word_end(self.vocab_size) as usize;
        let all = rng.sample_indices(word_hi - word_lo, n_classes * self.sig_size);
        let signatures = (0..n_classes)
            .map(|c| {
                all[c * self.sig_size..(c + 1) * self.sig_size]
                    .iter()
                    .map(|&i| (word_lo + i) as i32)
                    .collect()
            })
            .collect();
        Task { signatures, labels }
    }

    fn demonstration(&self, task: &Task, rng: &mut Rng) -> Vec<i32> {
        let class = rng.range(0, task.labels.len());
        let body_len = rng.range(4, self.chunk_max - 3);
        let mut out = vec![vocab::MARKER_START]; // "example:" marker
        // Narrow noise pool: fewer embeddings to learn -> the
        // signature->label mapping emerges within a short pretrain.
        out.extend(mixture_tokens(
            rng,
            &task.signatures[class],
            vocab::WORD_START,
            vocab::WORD_START + 64,
            self.p_signature,
            body_len,
        ));
        out.push(vocab::SEP);
        out.push(task.labels[class]);
        out
    }
}

impl OnlineDataset for MetaIcl {
    fn name(&self) -> &'static str {
        "metaicl"
    }

    fn n_identities(&self, split: Split) -> usize {
        match split {
            Split::Train => self.n_train,
            Split::Test => self.n_test,
        }
    }

    fn t_max(&self) -> usize {
        self.t_max
    }

    fn is_multi_choice(&self) -> bool {
        true
    }

    fn sample(&self, split: Split, identity: usize, t: usize) -> OnlineSample {
        assert!(t >= 1 && t <= self.t_max);
        let task = self.task(split, identity);
        let mut rng = identity_rng(self.seed ^ 0xA11CE, DS_ID, split, identity);
        // Chunks are a prefix-stable sequence: c(1..t) at step t equals the
        // first t chunks at any later step (online accumulation).
        let chunks: Vec<Vec<i32>> =
            (0..t).map(|_| self.demonstration(&task, &mut rng)).collect();
        // The query is a function of the identity ONLY: the test set is
        // identical across time steps (paper protocol) — more context,
        // same questions.
        let mut qrng = identity_rng(self.seed ^ 0x9E51, DS_ID, split, identity);
        let class = qrng.range(0, task.labels.len());
        let body_len = qrng.range(4, self.input_max.min(self.chunk_max) - 4);
        let mut input = vec![vocab::MARKER_START + 1]; // "problem:" marker
        input.extend(mixture_tokens(
            &mut qrng,
            &task.signatures[class],
            vocab::WORD_START,
            vocab::WORD_START + 64,
            self.p_signature,
            body_len,
        ));
        input.push(vocab::SEP);
        OnlineSample {
            chunks,
            input,
            target: vec![task.labels[class]],
            choices: task.labels.iter().map(|&l| vec![l]).collect(),
            correct: class,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sc() -> ScenarioConfig {
        ScenarioConfig {
            t_max: 8,
            chunk_max: 24,
            comp_len_max: 4,
            input_max: 32,
            seq_train: 384,
            mem_slots: 48,
            batch_train: 16,
            infer_batches: vec![1, 8],
            decode_cache: 96,
            rmt_unroll: 4,
            rmt_mem: 4,
        }
    }

    #[test]
    fn deterministic_and_prefix_stable() {
        let ds = MetaIcl::new(7, &sc(), 512);
        let a = ds.sample(Split::Test, 3, 5);
        let b = ds.sample(Split::Test, 3, 5);
        assert_eq!(a.chunks, b.chunks);
        assert_eq!(a.input, b.input);
        // Online accumulation: step-5 chunks extend step-3 chunks.
        let c = ds.sample(Split::Test, 3, 3);
        assert_eq!(&a.chunks[..3], c.chunks.as_slice());
    }

    #[test]
    fn shapes_and_reserved_tokens() {
        let ds = MetaIcl::new(7, &sc(), 512);
        for t in [1, 4, 8] {
            let s = ds.sample(Split::Train, 0, t);
            assert_eq!(s.chunks.len(), t);
            for c in &s.chunks {
                assert!(c.len() <= 24, "{}", c.len());
                assert!(!c.contains(&vocab::PAD));
                assert!(!c.contains(&vocab::COMP));
                assert_eq!(c[c.len() - 2], vocab::SEP);
            }
            assert!(s.input.len() + s.target.len() <= 32);
            assert_eq!(s.target.len(), 1);
            assert!(s.choices.len() >= 2);
            assert_eq!(s.choices[s.correct], s.target);
        }
    }

    #[test]
    fn demonstrations_reveal_the_mapping() {
        // Signature tokens of the demo's class should dominate its body —
        // otherwise in-context learning is impossible by construction.
        let ds = MetaIcl::new(1, &sc(), 512);
        let task = ds.task(Split::Train, 5);
        let s = ds.sample(Split::Train, 5, 8);
        let mut hits = 0usize;
        let mut total = 0usize;
        for c in &s.chunks {
            let label = *c.last().unwrap();
            let class = task.labels.iter().position(|&l| l == label).unwrap();
            for &tok in &c[1..c.len() - 2] {
                total += 1;
                hits += usize::from(task.signatures[class].contains(&tok));
            }
        }
        let frac = hits as f32 / total as f32;
        assert!(frac > 0.55, "signature fraction {frac}");
    }

    #[test]
    fn train_test_tasks_differ() {
        let ds = MetaIcl::new(7, &sc(), 512);
        let tr = ds.task(Split::Train, 0);
        let te = ds.task(Split::Test, 0);
        assert_ne!(tr.signatures, te.signatures);
    }
}
