//! Synthetic PG19: an unbounded text stream with long-range structure.
//!
//! An HMM over "themes" with high persistence, plus a slowly-growing cast
//! of "entity" tokens that are introduced once and re-referenced long
//! after — the long-range dependency that makes compressed history beat a
//! recency-only sliding window (Figure 8). The generator is an iterator:
//! `next_token()` forever.

use super::vocab;
use crate::util::rng::Rng;

pub struct StreamGen {
    rng: Rng,
    vocab_size: usize,
    n_themes: usize,
    theme_vocab: Vec<Vec<i32>>,
    theme: usize,
    p_stay: f32,
    /// Entities introduced so far (re-referenced with p_entity).
    entities: Vec<i32>,
    p_entity: f32,
    p_new_entity: f32,
    tokens_emitted: u64,
}

impl StreamGen {
    pub fn new(seed: u64, vocab_size: usize) -> StreamGen {
        let mut rng = Rng::with_stream(seed, 4);
        let n_themes = 10;
        let theme_words = 24usize;
        let word_lo = vocab::WORD_START as usize;
        let word_hi = vocab_size;
        let theme_vocab = (0..n_themes)
            .map(|_| {
                rng.sample_indices(word_hi - word_lo, theme_words)
                    .into_iter()
                    .map(|i| (word_lo + i) as i32)
                    .collect()
            })
            .collect();
        let theme = rng.range(0, n_themes);
        StreamGen {
            rng,
            vocab_size,
            n_themes,
            theme_vocab,
            theme,
            p_stay: 0.995, // themes persist for ~200 tokens
            entities: Vec::new(),
            p_entity: 0.15,
            p_new_entity: 0.01,
            tokens_emitted: 0,
        }
    }

    pub fn next_token(&mut self) -> i32 {
        self.tokens_emitted += 1;
        if !self.rng.bool(self.p_stay) {
            self.theme = self.rng.range(0, self.n_themes);
        }
        if self.rng.bool(self.p_new_entity) || self.entities.is_empty() {
            // Introduce a new entity token (outside current theme words).
            let word_lo = vocab::WORD_START as usize;
            let tok = self.rng.range(word_lo, self.vocab_size) as i32;
            self.entities.push(tok);
            return tok;
        }
        if self.rng.bool(self.p_entity) {
            // Long-range re-reference: any previously-introduced entity.
            return *self.rng.choice(&self.entities);
        }
        *self.rng.choice(&self.theme_vocab[self.theme])
    }

    /// Per-user stream for multi-tenant replay (`ccm loadgen`): one
    /// independent PG19-style stream per (dataset seed, user index),
    /// decorrelated by mixing the user id into the seed so concurrent
    /// readers don't replay identical token sequences.
    pub fn for_user(seed: u64, user: u64, vocab_size: usize) -> StreamGen {
        StreamGen::new(seed ^ user.wrapping_mul(0x9e37_79b9_7f4a_7c15), vocab_size)
    }

    pub fn take(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.next_token()).collect()
    }

    pub fn tokens_emitted(&self) -> u64 {
        self.tokens_emitted
    }

    /// Unigram entropy estimate of a window (used by tests to confirm the
    /// stream is neither degenerate nor uniform).
    pub fn entropy(window: &[i32]) -> f64 {
        let mut counts = std::collections::HashMap::new();
        for &t in window {
            *counts.entry(t).or_insert(0usize) += 1;
        }
        let n = window.len() as f64;
        counts
            .values()
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = StreamGen::new(11, 512);
        let mut b = StreamGen::new(11, 512);
        assert_eq!(a.take(500), b.take(500));
        let mut c = StreamGen::new(12, 512);
        assert_ne!(a.take(100), c.take(100));
    }

    #[test]
    fn per_user_streams_are_deterministic_and_decorrelated() {
        let mut a = StreamGen::for_user(11, 3, 512);
        let mut b = StreamGen::for_user(11, 3, 512);
        assert_eq!(a.take(300), b.take(300), "same (seed, user) must replay identically");
        let mut c = StreamGen::for_user(11, 4, 512);
        assert_ne!(a.take(300), c.take(300), "different users must diverge");
        // User 0 is the base stream (xor with 0 is identity).
        let mut d = StreamGen::for_user(11, 0, 512);
        let mut base = StreamGen::new(11, 512);
        assert_eq!(d.take(100), base.take(100));
    }

    #[test]
    fn long_range_reuse_exists() {
        let mut g = StreamGen::new(3, 512);
        let early: std::collections::HashSet<i32> = g.take(2000).into_iter().collect();
        let late = g.take(2000);
        let reused = late.iter().filter(|t| early.contains(t)).count();
        // Theme persistence + entities mean heavy long-range overlap.
        assert!(reused as f32 / late.len() as f32 > 0.5);
    }

    #[test]
    fn entropy_in_reasonable_band() {
        let mut g = StreamGen::new(4, 512);
        let w = g.take(4000);
        let h = StreamGen::entropy(&w);
        // Not degenerate (>3 bits) and far from uniform over 488 words (<8.9).
        assert!(h > 3.0 && h < 8.5, "entropy {h}");
    }

    #[test]
    fn only_valid_token_ids() {
        let mut g = StreamGen::new(5, 512);
        assert!(g.take(3000).iter().all(|&t| (vocab::WORD_START..512).contains(&t)));
    }
}
