//! Pretraining corpus: documents sampled from the same generative
//! processes as the online datasets (paper §4.1 "Effect of training data
//! sources": the base LM is first fine-tuned on in-domain data, then the
//! compression adapter is trained on top).
//!
//! A document is a full packed sequence `[BOS, chunks..., input, target]`
//! with plain causal structure — no `<COMP>` tokens; this teaches the base
//! LM the synthetic language itself.

use super::{by_name, OnlineDataset, Split};
use crate::model::manifest::ScenarioConfig;
use crate::util::rng::Rng;

/// Named mixtures of data sources (Table 4 rows).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Mixture {
    /// A single dataset.
    One(String),
    /// Uniform over several datasets.
    Mix(Vec<String>),
}

impl Mixture {
    pub fn parse(s: &str) -> Mixture {
        let parts: Vec<String> = s.split('+').map(|p| p.trim().to_string()).collect();
        if parts.len() == 1 {
            Mixture::One(parts[0].clone())
        } else {
            Mixture::Mix(parts)
        }
    }

    pub fn sources(&self) -> Vec<String> {
        match self {
            Mixture::One(s) => vec![s.clone()],
            Mixture::Mix(v) => v.clone(),
        }
    }
}

/// Document sampler over a dataset mixture.
pub struct Corpus {
    datasets: Vec<Box<dyn OnlineDataset>>,
    rng: Rng,
    bos: i32,
}

impl Corpus {
    pub fn new(
        mixture: &Mixture,
        seed: u64,
        sc: &ScenarioConfig,
        vocab_size: usize,
        bos: i32,
    ) -> anyhow::Result<Corpus> {
        let mut datasets = Vec::new();
        for name in mixture.sources() {
            if name == "stream" {
                // The stream corpus is handled by StreamDoc below; as part
                // of a mixture it is represented through dialog-like docs.
                continue;
            }
            datasets.push(by_name(&name, seed, sc, vocab_size)?);
        }
        anyhow::ensure!(!datasets.is_empty(), "empty mixture");
        Ok(Corpus { datasets, rng: Rng::new(seed.wrapping_mul(0xC0FFEE) ^ 0x5eed), bos })
    }

    /// One packed LM document of exactly `len` tokens (0-padded if the
    /// sampled interaction is shorter).
    pub fn document(&mut self, len: usize) -> Vec<i32> {
        let ds = &self.datasets[self.rng.range(0, self.datasets.len())];
        let id = self.rng.range(0, ds.n_identities(Split::Train));
        let t = self.rng.range(1, ds.t_max() + 1);
        let s = ds.sample(Split::Train, id, t);
        let mut doc = vec![self.bos];
        for c in &s.chunks {
            doc.extend_from_slice(c);
        }
        doc.extend_from_slice(&s.input);
        doc.extend_from_slice(&s.target);
        doc.truncate(len);
        doc.resize(len, 0);
        doc
    }

    /// A [B, len] batch of documents plus the loss mask (1.0 on positions
    /// whose next token is real).
    pub fn batch(&mut self, b: usize, len: usize) -> (Vec<i32>, Vec<f32>) {
        let mut tokens = Vec::with_capacity(b * len);
        let mut loss = vec![0.0f32; b * len];
        for bi in 0..b {
            let doc = self.document(len);
            for i in 0..len.saturating_sub(1) {
                // predict token i+1 from position i
                if doc[i] != 0 && doc[i + 1] != 0 {
                    loss[bi * len + i] = 1.0;
                }
            }
            tokens.extend_from_slice(&doc);
        }
        (tokens, loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sc() -> ScenarioConfig {
        ScenarioConfig {
            t_max: 8,
            chunk_max: 24,
            comp_len_max: 4,
            input_max: 32,
            seq_train: 384,
            mem_slots: 48,
            batch_train: 16,
            infer_batches: vec![1, 8],
            decode_cache: 96,
            rmt_unroll: 4,
            rmt_mem: 4,
        }
    }

    #[test]
    fn mixture_parsing() {
        assert_eq!(Mixture::parse("metaicl").sources(), vec!["metaicl"]);
        assert_eq!(
            Mixture::parse("metaicl+dialog").sources(),
            vec!["metaicl", "dialog"]
        );
    }

    #[test]
    fn documents_have_shape_and_loss_masks_align() {
        let mut c = Corpus::new(&Mixture::parse("metaicl+dialog"), 1, &sc(), 512, 1).unwrap();
        let (tokens, loss) = c.batch(4, 128);
        assert_eq!(tokens.len(), 4 * 128);
        assert_eq!(loss.len(), 4 * 128);
        for bi in 0..4 {
            assert_eq!(tokens[bi * 128], 1, "doc starts with BOS");
            for i in 0..127 {
                if loss[bi * 128 + i] > 0.0 {
                    assert_ne!(tokens[bi * 128 + i + 1], 0, "loss on pad successor");
                }
            }
            // Some loss positions must exist.
            assert!(loss[bi * 128..(bi + 1) * 128].iter().sum::<f32>() > 10.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            Corpus::new(&Mixture::parse("lamp"), 5, &sc(), 512, 1)
                .unwrap()
                .batch(2, 64)
        };
        assert_eq!(mk().0, mk().0);
    }
}
