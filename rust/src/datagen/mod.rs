//! Synthetic online-interaction datasets.
//!
//! The paper evaluates on MetaICL (multi-task ICL), LaMP (personalization),
//! DailyDialog (conversation) and PG19 (streaming). Those corpora are not
//! available here, so each generator synthesises a workload that preserves
//! the *structural property* the paper's analysis hinges on (DESIGN.md §2):
//!
//! * `metaicl` — demonstrations of one task are mutually complementary
//!   (shared signature→label mapping) ⇒ merge ≈ concat;
//! * `lamp`    — user profiles share per-user information;
//! * `dialog`  — each turn carries *distinct* information (topic drift +
//!   callbacks) ⇒ concat > merge as t grows;
//! * `stream`  — long-range topic persistence ⇒ compressed history beats a
//!   recency-only window.
//!
//! All generators are deterministic functions of (dataset seed, identity,
//! time step) and split identities into train/test sets.
//!
//! Besides offline eval, these generators are the traffic source for
//! `ccm loadgen` (`crate::bench::loadgen`): each workload replays as a
//! population of live serving sessions — the scenario-by-scenario
//! operator guide is docs/SCENARIOS.md.

pub mod corpus;
pub mod dialog;
pub mod lamp;
pub mod metaicl;
pub mod stream;

use crate::util::rng::Rng;

/// Reserved token-id regions of the 512-token synthetic vocabulary
/// (mirrored by `ModelConfig` ids 0..4 in python/compile/config.py).
pub mod vocab {
    pub const PAD: i32 = 0;
    pub const BOS: i32 = 1;
    pub const SEP: i32 = 2;
    pub const COMP: i32 = 3;
    /// Speaker / structural markers.
    pub const MARKER_START: i32 = 4;
    pub const MARKER_END: i32 = 8;
    /// Answer/label tokens (multi-choice targets live here).
    pub const LABEL_START: i32 = 8;
    pub const LABEL_END: i32 = 24;
    /// Content words.
    pub const WORD_START: i32 = 24;

    pub fn word_end(vocab_size: usize) -> i32 {
        vocab_size as i32
    }
}

/// One online-inference example at time step t: the accumulated context is
/// `chunks[0..t]`, the query is `input`, the answer is `target`.
#[derive(Debug, Clone)]
pub struct OnlineSample {
    /// c(1), ..., c(t): context chunks in arrival order.
    pub chunks: Vec<Vec<i32>>,
    /// I(t): the query (ends with SEP; the target follows it).
    pub input: Vec<i32>,
    /// O(t): target tokens (appended to `input` for scoring/training).
    pub target: Vec<i32>,
    /// Multi-choice candidates (accuracy datasets); `correct` indexes them.
    pub choices: Vec<Vec<i32>>,
    pub correct: usize,
}

impl OnlineSample {
    /// input ++ target (the packed input segment fed to the model).
    pub fn input_with_target(&self) -> Vec<i32> {
        let mut v = self.input.clone();
        v.extend_from_slice(&self.target);
        v
    }
}

/// Identity split: which identities (tasks/users/dialogues) are train vs
/// held-out test — the paper's I_train / I_test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// An online-interaction dataset: deterministic sampler over identities
/// and time steps.
pub trait OnlineDataset {
    fn name(&self) -> &'static str;

    /// Number of identities in the split.
    fn n_identities(&self, split: Split) -> usize;

    /// Max time step for evaluation (paper: 16 / 16 / 12).
    fn t_max(&self) -> usize;

    /// Sample the interaction for `identity` at time step `t` (1-based):
    /// returns chunks c(1..t), input I(t), target O(t).
    fn sample(&self, split: Split, identity: usize, t: usize) -> OnlineSample;

    /// Whether accuracy (multi-choice) or perplexity is the metric.
    fn is_multi_choice(&self) -> bool;
}

/// Deterministic per-(dataset, split, identity) generator.
pub(crate) fn identity_rng(seed: u64, ds: u64, split: Split, identity: usize) -> Rng {
    let s = match split {
        Split::Train => 0x7121u64,
        Split::Test => 0x7e57u64,
    };
    Rng::with_stream(
        seed ^ ds.wrapping_mul(0x9e37_79b9_7f4a_7c15),
        (s.wrapping_mul(31) ^ identity as u64).wrapping_mul(2) | 1,
    )
}

/// Draw `n` tokens from a weighted mixture of a signature set and a noise
/// pool — the shared building block of metaicl/lamp items.
pub(crate) fn mixture_tokens(
    rng: &mut Rng,
    signature: &[i32],
    noise_lo: i32,
    noise_hi: i32,
    p_signature: f32,
    n: usize,
) -> Vec<i32> {
    (0..n)
        .map(|_| {
            if rng.bool(p_signature) {
                *rng.choice(signature)
            } else {
                rng.range(noise_lo as usize, noise_hi as usize) as i32
            }
        })
        .collect()
}

/// Resolve a dataset by name at the scenario sizes from the manifest.
pub fn by_name(
    name: &str,
    seed: u64,
    sc: &crate::model::manifest::ScenarioConfig,
    vocab_size: usize,
) -> anyhow::Result<Box<dyn OnlineDataset>> {
    Ok(match name {
        "metaicl" => Box::new(metaicl::MetaIcl::new(seed, sc, vocab_size)),
        "lamp" => Box::new(lamp::Lamp::new(seed, sc, vocab_size)),
        "dialog" => Box::new(dialog::Dialog::new(seed, sc, vocab_size)),
        _ => anyhow::bail!("unknown dataset {name:?} (metaicl|lamp|dialog)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_rng_is_deterministic_and_split() {
        let mut a = identity_rng(1, 2, Split::Train, 3);
        let mut b = identity_rng(1, 2, Split::Train, 3);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = identity_rng(1, 2, Split::Test, 3);
        let mut d = identity_rng(1, 2, Split::Train, 4);
        let x = identity_rng(1, 2, Split::Train, 3).next_u64();
        assert_ne!(x, c.next_u64());
        assert_ne!(x, d.next_u64());
    }

    #[test]
    fn mixture_respects_probability() {
        let mut rng = Rng::new(9);
        let sig = vec![100, 101, 102];
        let toks = mixture_tokens(&mut rng, &sig, 200, 400, 0.8, 2000);
        let in_sig = toks.iter().filter(|t| sig.contains(t)).count();
        assert!(in_sig > 1400 && in_sig < 1900, "{in_sig}");
        assert!(toks.iter().all(|&t| sig.contains(&t) || (200..400).contains(&t)));
    }
}
