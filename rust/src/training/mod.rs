//! Training driver: executes the AOT train-step artifacts in a loop.
//!
//! Three phases, matching the paper's recipe (Appendix B):
//!  1. `pretrain_lm`   — full-weight causal-LM training of the base model
//!     on the synthetic corpus (the paper's dataset fine-tune).
//!  2. `train_ccm`     — compression training of the conditional-LoRA +
//!     `<COMP>` embeddings with the parallelized forward (Algorithm 1).
//!     The mask/P inputs select the method, so the same loop trains
//!     CCM-concat/-merge, Gisting and Compressive Transformer.
//!  3. `train_rmt`     — the recurrent baseline (unrolled in-graph),
//!     whose per-sample cost is what Table 8 compares.
//!
//! Adam moments live host-side and round-trip through the artifacts.

pub mod pack;

use std::time::Instant;

use anyhow::Result;

use crate::datagen::corpus::{Corpus, Mixture};
use crate::datagen::{by_name, Split};
use crate::model::{cosine_lr, AdamState, Checkpoint};
use crate::runtime::{Runtime, Value};
use crate::tensor::{IntTensor, Tensor};
use crate::training::pack::{pack_batch, PackPolicy};
use crate::util::rng::Rng;

/// Per-run training report (recorded into EXPERIMENTS.md by callers).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub steps: usize,
    pub ms_per_step: f64,
    pub ms_per_sample: f64,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        let k = self.losses.len().min(10);
        self.losses[self.losses.len() - k..].iter().sum::<f32>() / k as f32
    }
}

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub log_every: usize,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime) -> Trainer<'rt> {
        Trainer { rt, log_every: 25 }
    }

    /// Phase 1: full-weight LM pretraining on a dataset mixture.
    pub fn pretrain_lm(
        &self,
        ck: &mut Checkpoint,
        mixture: &Mixture,
        steps: usize,
        base_lr: f32,
        seed: u64,
    ) -> Result<TrainReport> {
        let m = &self.rt.manifest;
        let (b, s) = (m.scenario.batch_train, m.scenario.seq_train);
        let mut corpus = Corpus::new(mixture, seed, &m.scenario, m.model.vocab, m.model.bos_id)?;
        let mut adam = AdamState::new(ck.base.data.len());
        let mut losses = Vec::with_capacity(steps);
        let pos_row: Vec<i32> = (0..s as i32).collect();
        let mut pos = IntTensor::zeros(&[b, s]);
        for bi in 0..b {
            pos.row_mut(&[bi]).copy_from_slice(&pos_row);
        }
        let t0 = Instant::now();
        for step in 0..steps {
            let (tokens, loss_mask) = corpus.batch(b, s);
            let lr = cosine_lr(step, steps, base_lr, steps / 20 + 1);
            let outs = self.rt.execute_f32(
                "train_lm_step",
                &[
                    Value::vec_f32(&[ck.base.data.len()], std::mem::take(&mut ck.base.data))?,
                    Value::vec_f32(&[adam.mu.len()], std::mem::take(&mut adam.mu))?,
                    Value::vec_f32(&[adam.nu.len()], std::mem::take(&mut adam.nu))?,
                    Value::scalar_i32(adam.step),
                    Value::scalar_f32(lr),
                    Value::I32(IntTensor::from_vec(&[b, s], tokens)?),
                    Value::I32(pos.clone()),
                    Value::F32(Tensor::from_vec(&[b, s], loss_mask)?),
                ],
            )?;
            ck.base.data = outs[0].data.clone();
            adam.mu = outs[1].data.clone();
            adam.nu = outs[2].data.clone();
            adam.step += 1;
            let loss = outs[3].data[0];
            losses.push(loss);
            if step % self.log_every == 0 {
                crate::info!("lm step {step}/{steps} loss {loss:.4} lr {lr:.2e}");
            }
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / steps.max(1) as f64;
        Ok(TrainReport { losses, steps, ms_per_step: ms, ms_per_sample: ms / b as f64 })
    }

    /// Phase 2: compression training (Algorithm 1). `mixture` follows the
    /// paper's per-application or unified training-data settings.
    #[allow(clippy::too_many_arguments)]
    pub fn train_ccm(
        &self,
        ck: &mut Checkpoint,
        policy: &PackPolicy,
        mixture: &Mixture,
        steps: usize,
        base_lr: f32,
        seed: u64,
    ) -> Result<TrainReport> {
        let m = &self.rt.manifest;
        let b = m.scenario.batch_train;
        let mut datasets = Vec::new();
        for name in mixture.sources() {
            datasets.push(by_name(&name, seed, &m.scenario, m.model.vocab)?);
        }
        let mut rng = Rng::new(seed ^ 0xCC);
        let mut adam = AdamState::new(ck.lora.data.len());
        let mut losses = Vec::with_capacity(steps);
        let t0 = Instant::now();
        for step in 0..steps {
            // Sample a batch of (identity, t) pairs across the mixture.
            let mut samples = Vec::with_capacity(b);
            for _ in 0..b {
                let ds = &datasets[rng.range(0, datasets.len())];
                let id = rng.range(0, ds.n_identities(Split::Train));
                let t = rng.range(1, ds.t_max() + 1);
                samples.push(ds.sample(Split::Train, id, t));
            }
            let refs: Vec<(&crate::datagen::OnlineSample, Option<&[i32]>)> =
                samples.iter().map(|s| (s, None)).collect();
            let batch = pack_batch(policy, m, &refs, b)?;
            let lr = cosine_lr(step, steps, base_lr, steps / 20 + 1);
            let outs = self.rt.execute_f32(
                "train_ccm_step",
                &[
                    Value::vec_f32(&[ck.base.data.len()], ck.base.data.clone())?,
                    Value::vec_f32(&[ck.lora.data.len()], std::mem::take(&mut ck.lora.data))?,
                    Value::vec_f32(&[adam.mu.len()], std::mem::take(&mut adam.mu))?,
                    Value::vec_f32(&[adam.nu.len()], std::mem::take(&mut adam.nu))?,
                    Value::scalar_i32(adam.step),
                    Value::scalar_f32(lr),
                    Value::I32(batch.tokens),
                    Value::I32(batch.comp_slot),
                    Value::F32(batch.gate),
                    Value::I32(batch.pos),
                    Value::F32(batch.mask),
                    Value::F32(batch.merge_p),
                    Value::F32(batch.loss_mask),
                ],
            )?;
            ck.lora.data = outs[0].data.clone();
            adam.mu = outs[1].data.clone();
            adam.nu = outs[2].data.clone();
            adam.step += 1;
            let loss = outs[3].data[0];
            losses.push(loss);
            if step % self.log_every == 0 {
                crate::info!(
                    "ccm[{}] step {step}/{steps} loss {loss:.4}",
                    policy.method.name()
                );
            }
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / steps.max(1) as f64;
        Ok(TrainReport { losses, steps, ms_per_step: ms, ms_per_sample: ms / b as f64 })
    }

    /// Phase 3: the recurrent-compression baseline (RMT/AutoCompressor
    /// shape). Sequential in-graph recursion — slow per sample by design.
    pub fn train_rmt(
        &self,
        ck: &mut Checkpoint,
        mixture: &Mixture,
        steps: usize,
        base_lr: f32,
        seed: u64,
    ) -> Result<TrainReport> {
        let m = &self.rt.manifest;
        let sc = &m.scenario;
        let (b, r, s_c, si) = (sc.batch_train, sc.rmt_unroll, sc.chunk_max, sc.input_max);
        let mut datasets = Vec::new();
        for name in mixture.sources() {
            datasets.push(by_name(&name, seed, sc, m.model.vocab)?);
        }
        let mut rng = Rng::new(seed ^ 0x12A7);
        let mut adam = AdamState::new(ck.lora.data.len());
        let mut losses = Vec::with_capacity(steps);
        let t0 = Instant::now();
        for step in 0..steps {
            let mut chunks = IntTensor::zeros(&[b, r, s_c]);
            let mut chunk_valid = Tensor::zeros(&[b, r, s_c]);
            let mut inputs = IntTensor::zeros(&[b, si]);
            let mut input_valid = Tensor::zeros(&[b, si]);
            let mut loss_mask = Tensor::zeros(&[b, si]);
            for bi in 0..b {
                let ds = &datasets[rng.range(0, datasets.len())];
                let id = rng.range(0, ds.n_identities(Split::Train));
                let t = rng.range(1, (ds.t_max().min(r)) + 1);
                let s = ds.sample(Split::Train, id, t);
                for (j, c) in s.chunks.iter().take(r).enumerate() {
                    chunks.row_mut(&[bi, j])[..c.len()].copy_from_slice(c);
                    for x in &mut chunk_valid.row_mut(&[bi, j])[..c.len()] {
                        *x = 1.0;
                    }
                }
                let it = s.input_with_target();
                inputs.row_mut(&[bi])[..it.len()].copy_from_slice(&it);
                for x in &mut input_valid.row_mut(&[bi])[..it.len()] {
                    *x = 1.0;
                }
                let tgt_start = s.input.len();
                for i in 0..s.target.len() {
                    loss_mask.row_mut(&[bi])[tgt_start + i - 1] = 1.0;
                }
            }
            let lr = cosine_lr(step, steps, base_lr, steps / 20 + 1);
            let outs = self.rt.execute_f32(
                "train_rmt_step",
                &[
                    Value::vec_f32(&[ck.base.data.len()], ck.base.data.clone())?,
                    Value::vec_f32(&[ck.lora.data.len()], std::mem::take(&mut ck.lora.data))?,
                    Value::vec_f32(&[adam.mu.len()], std::mem::take(&mut adam.mu))?,
                    Value::vec_f32(&[adam.nu.len()], std::mem::take(&mut adam.nu))?,
                    Value::scalar_i32(adam.step),
                    Value::scalar_f32(lr),
                    Value::I32(chunks),
                    Value::F32(chunk_valid),
                    Value::I32(inputs),
                    Value::F32(input_valid),
                    Value::F32(loss_mask),
                ],
            )?;
            ck.lora.data = outs[0].data.clone();
            adam.mu = outs[1].data.clone();
            adam.nu = outs[2].data.clone();
            adam.step += 1;
            losses.push(outs[3].data[0]);
            if step % self.log_every == 0 {
                crate::info!("rmt step {step}/{steps} loss {:.4}", outs[3].data[0]);
            }
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / steps.max(1) as f64;
        Ok(TrainReport { losses, steps, ms_per_step: ms, ms_per_sample: ms / b as f64 })
    }
}
