//! Packing online samples into the parallel-forward tensors.
//!
//! One packed row = the Figure-3 sequence
//! `[c(1), <COMP>*, ..., c(t), <COMP>*, I(t), O(t)]` plus its attention
//! mask, merge matrix, LoRA gate and loss mask. Shared by the trainer
//! (train_ccm_step) and the evaluation harness (ccm_forward).

use anyhow::{bail, Result};

use crate::datagen::OnlineSample;
use crate::masks::{self, Layout, MergeScheme, Method};
use crate::model::manifest::{Manifest, ScenarioConfig};
use crate::tensor::{IntTensor, Tensor};

/// Packing policy: which method's mask/P to build.
#[derive(Debug, Clone)]
pub struct PackPolicy {
    pub method: Method,
    pub scheme: MergeScheme,
    /// `<COMP>` tokens appended per chunk (and Compressive pool width).
    pub comp_len: usize,
    /// Conditional (paper) vs unconditional (Table 5 ablation) LoRA gate.
    pub conditional: bool,
}

impl PackPolicy {
    pub fn new(method: Method, comp_len: usize) -> PackPolicy {
        PackPolicy { method, scheme: MergeScheme::Avg, comp_len, conditional: true }
    }
}

/// One packed sample row (host-side, f32/i32 flat vectors).
pub struct PackedRow {
    pub layout: Layout,
    pub tokens: Vec<i32>,
    pub comp_slot: Vec<i32>,
    pub gate: Vec<f32>,
    pub pos: Vec<i32>,
    pub mask: Tensor,
    pub merge_p: Tensor,
    pub loss_mask: Vec<f32>,
    /// Position of the first target token within the sequence.
    pub target_start: usize,
    pub target_len: usize,
}

/// Pack one sample at sequence length `seq` with `mem_slots` columns.
pub fn pack_row(
    policy: &PackPolicy,
    sc: &ScenarioConfig,
    sample: &OnlineSample,
    override_input: Option<&[i32]>,
) -> Result<PackedRow> {
    let seq = sc.seq_train;
    let comp_len = if policy.method.uses_comp_tokens() { policy.comp_len } else { 0 };
    let chunk_lens: Vec<usize> = match policy.method {
        Method::NoContext => vec![],
        _ => sample.chunks.iter().map(|c| c.len()).collect(),
    };
    // The input segment is input ++ target (teacher forcing / scoring).
    let target = &sample.target;
    let base_input = &sample.input;
    let (inp, tgt): (&[i32], &[i32]) = match override_input {
        Some(choice) => (base_input, choice),
        None => (base_input, target),
    };
    let input_len = inp.len() + tgt.len();
    if input_len > sc.input_max {
        bail!("input+target {} > input_max {}", input_len, sc.input_max);
    }
    let lay = masks::build_layout(&chunk_lens, comp_len, input_len, seq)?;
    let (mask, merge_p) =
        masks::build_masks(policy.method, &lay, sc.mem_slots, policy.scheme, policy.comp_len)?;

    let mut tokens = vec![0i32; seq];
    let mut pos = 0usize;
    if !matches!(policy.method, Method::NoContext) {
        for c in &sample.chunks {
            tokens[pos..pos + c.len()].copy_from_slice(c);
            pos += c.len();
            for _ in 0..comp_len {
                tokens[pos] = 3; // <COMP>
                pos += 1;
            }
        }
    }
    let target_start = pos + inp.len();
    tokens[pos..pos + inp.len()].copy_from_slice(inp);
    tokens[target_start..target_start + tgt.len()].copy_from_slice(tgt);

    // Loss on positions predicting the target: [target_start-1, ...).
    let mut loss_mask = vec![0.0f32; seq];
    for i in 0..tgt.len() {
        loss_mask[target_start + i - 1] = 1.0;
    }

    Ok(PackedRow {
        tokens,
        comp_slot: masks::comp_slot_input(&lay),
        gate: masks::lora_gate(&lay, policy.conditional),
        pos: masks::position_ids(&lay),
        mask,
        merge_p,
        loss_mask,
        target_start,
        target_len: tgt.len(),
        layout: lay,
    })
}

/// A [B, ...] batch of packed rows, staged for train_ccm_step/ccm_forward.
pub struct PackedBatch {
    pub b: usize,
    pub tokens: IntTensor,
    pub comp_slot: IntTensor,
    pub gate: Tensor,
    pub pos: IntTensor,
    pub mask: Tensor,
    pub merge_p: Tensor,
    pub loss_mask: Tensor,
    pub rows: Vec<(usize, usize)>, // (target_start, target_len) per row
}

pub fn pack_batch(
    policy: &PackPolicy,
    manifest: &Manifest,
    samples: &[(&OnlineSample, Option<&[i32]>)],
    b: usize,
) -> Result<PackedBatch> {
    let sc = &manifest.scenario;
    let (s, m) = (sc.seq_train, sc.mem_slots);
    if samples.len() > b {
        bail!("{} samples > batch {b}", samples.len());
    }
    let mut out = PackedBatch {
        b,
        tokens: IntTensor::zeros(&[b, s]),
        comp_slot: IntTensor::zeros(&[b, s]),
        gate: Tensor::zeros(&[b, s]),
        pos: IntTensor::zeros(&[b, s]),
        mask: Tensor::zeros(&[b, s, m + s]),
        merge_p: Tensor::zeros(&[b, m, s]),
        loss_mask: Tensor::zeros(&[b, s]),
        rows: Vec::with_capacity(samples.len()),
    };
    for (bi, (sample, choice)) in samples.iter().enumerate() {
        let row = pack_row(policy, sc, sample, *choice)?;
        out.tokens.row_mut(&[bi]).copy_from_slice(&row.tokens);
        out.comp_slot.row_mut(&[bi]).copy_from_slice(&row.comp_slot);
        out.gate.row_mut(&[bi]).copy_from_slice(&row.gate);
        out.pos.row_mut(&[bi]).copy_from_slice(&row.pos);
        out.loss_mask.row_mut(&[bi]).copy_from_slice(&row.loss_mask);
        let n = s * (m + s);
        out.mask.data[bi * n..(bi + 1) * n].copy_from_slice(&row.mask.data);
        let np = m * s;
        out.merge_p.data[bi * np..(bi + 1) * np].copy_from_slice(&row.merge_p.data);
        out.rows.push((row.target_start, row.target_len));
    }
    // Padding rows (samples.len()..b) keep all-zero tokens; the layout
    // builder gives pad rows self-attention so softmax stays finite, but
    // zero masks here are also safe because loss_mask is zero.
    for bi in samples.len()..b {
        for i in 0..s {
            out.mask.set(&[bi, i, m + i], 1.0);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::OnlineSample;

    fn sc() -> ScenarioConfig {
        ScenarioConfig {
            t_max: 4,
            chunk_max: 12,
            comp_len_max: 2,
            input_max: 16,
            seq_train: 96,
            mem_slots: 8,
            batch_train: 4,
            infer_batches: vec![1, 4],
            decode_cache: 48,
            rmt_unroll: 2,
            rmt_mem: 2,
        }
    }

    fn sample() -> OnlineSample {
        OnlineSample {
            chunks: vec![vec![10, 11, 12], vec![20, 21, 22, 23]],
            input: vec![30, 31, 2],
            target: vec![9],
            choices: vec![vec![8], vec![9]],
            correct: 1,
        }
    }

    #[test]
    fn packs_tokens_in_layout_order() {
        let p = PackPolicy::new(Method::CcmConcat, 2);
        let row = pack_row(&p, &sc(), &sample(), None).unwrap();
        assert_eq!(&row.tokens[..5], &[10, 11, 12, 3, 3]);
        assert_eq!(&row.tokens[5..11], &[20, 21, 22, 23, 3, 3]);
        assert_eq!(&row.tokens[11..15], &[30, 31, 2, 9]);
        assert_eq!(row.target_start, 14);
        assert_eq!(row.loss_mask[13], 1.0); // position 13 predicts token 14
        assert_eq!(row.loss_mask.iter().filter(|&&x| x > 0.0).count(), 1);
        assert_eq!(row.gate.iter().filter(|&&x| x > 0.0).count(), 4);
    }

    #[test]
    fn choice_override_swaps_target() {
        let p = PackPolicy::new(Method::CcmConcat, 2);
        let choice = [8];
        let row = pack_row(&p, &sc(), &sample(), Some(&choice)).unwrap();
        assert_eq!(row.tokens[row.target_start], 8);
    }

    #[test]
    fn full_and_nocontext_have_no_comp_tokens() {
        for method in [Method::Full, Method::NoContext] {
            let p = PackPolicy::new(method, 2);
            let row = pack_row(&p, &sc(), &sample(), None).unwrap();
            assert!(row.tokens.iter().all(|&t| t != 3), "{method:?}");
            assert_eq!(row.gate.iter().sum::<f32>(), 0.0);
        }
        // NoContext drops the chunks entirely.
        let p = PackPolicy::new(Method::NoContext, 2);
        let row = pack_row(&p, &sc(), &sample(), None).unwrap();
        assert_eq!(row.tokens[0], 30);
    }

    #[test]
    fn batch_stages_all_rows_and_pads() {
        let p = PackPolicy::new(Method::CcmMerge, 2);
        let s1 = sample();
        let manifest = toy_manifest();
        let batch =
            pack_batch(&p, &manifest, &[(&s1, None), (&s1, Some(&[8]))], 4).unwrap();
        assert_eq!(batch.rows.len(), 2);
        assert_eq!(batch.tokens.shape, vec![4, 96]);
        // Pad rows have inert self-attention.
        assert_eq!(batch.mask.get(&[3, 0, 8 + 0]), 1.0);
        assert!(batch.loss_mask.row(&[3]).iter().all(|&x| x == 0.0));
    }

    fn toy_manifest() -> Manifest {
        use crate::model::manifest::*;
        Manifest {
            config_name: "toy".into(),
            dir: std::path::PathBuf::from("."),
            model: ModelConfig {
                name: "toy".into(),
                vocab: 256,
                d_model: 8,
                n_layers: 1,
                n_heads: 1,
                d_ff: 8,
                max_pos: 128,
                lora_rank: 2,
                lora_alpha: 4.0,
                pad_id: 0,
                bos_id: 1,
                sep_id: 2,
                comp_id: 3,
                d_head: 8,
            },
            scenario: sc(),
            base_layout: ParamLayout { total: 1, entries: vec![] },
            lora_layout: ParamLayout { total: 1, entries: vec![] },
            artifacts: vec![],
            mask_goldens: vec![],
        }
    }
}
