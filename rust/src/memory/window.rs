//! Streaming KV-budget bookkeeping: sliding window with attention sink +
//! compressed context memory (paper Figure 9).
//!
//! Tokens stream in one at a time under a hard KV budget. The layout is
//! `[sink tokens | compressed memory slots | recent window]`. When the
//! budget is hit, the oldest `compress_block` window tokens are handed to
//! the compressor (CCM) or simply dropped (StreamingLLM baseline). For
//! CCM-concat the memory itself is bounded: oldest compressed pairs are
//! emitted FIFO.

/// What the policy wants done with overflowing tokens.
#[derive(Debug, Clone, PartialEq)]
pub enum Overflow {
    /// Nothing to do yet.
    None,
    /// Compress these (oldest) window token blocks into memory, in order.
    /// Enough blocks are emitted to restore the budget even after the
    /// memory grows by `slots_per_compress` per block (cap-aware).
    Compress(Vec<Vec<i32>>),
    /// Drop them without compression (StreamingLLM).
    Drop(usize),
}

/// Streaming window policy + state.
#[derive(Debug, Clone)]
pub struct StreamWindow {
    /// First tokens of the stream, pinned (attention sink).
    pub sink: Vec<i32>,
    /// Recent raw tokens.
    pub window: Vec<i32>,
    /// Hard cap on sink + mem_slots + window length (the KV budget).
    pub max_kv: usize,
    /// Slots currently held by compressed memory (updated by the caller
    /// after each compression, since CCM-concat grows then saturates).
    pub mem_slots_used: usize,
    /// Cap on compressed-memory slots (CCM size).
    pub mem_slots_max: usize,
    /// How many oldest tokens are compressed per compression step.
    pub compress_block: usize,
    /// Memory slots one compression adds (the `<COMP>` length).
    pub slots_per_compress: usize,
    pub n_sink: usize,
    /// Total tokens ever seen (diagnostics).
    pub seen: u64,
    compress: bool,
}

impl StreamWindow {
    /// CCM streaming window (compresses overflow).
    pub fn ccm(
        max_kv: usize,
        mem_slots_max: usize,
        compress_block: usize,
        slots_per_compress: usize,
        n_sink: usize,
    ) -> Self {
        assert!(
            max_kv > n_sink + mem_slots_max,
            "budget {max_kv} cannot hold sink {n_sink} + memory {mem_slots_max}"
        );
        StreamWindow {
            sink: Vec::new(),
            window: Vec::new(),
            max_kv,
            mem_slots_used: 0,
            mem_slots_max,
            compress_block,
            slots_per_compress,
            n_sink,
            seen: 0,
            compress: true,
        }
    }

    /// StreamingLLM baseline (drops overflow). To keep the comparison
    /// budget-fair, the baseline gets the memory slots back as window.
    pub fn streaming_llm(max_kv: usize, n_sink: usize) -> Self {
        StreamWindow {
            sink: Vec::new(),
            window: Vec::new(),
            max_kv,
            mem_slots_used: 0,
            mem_slots_max: 0,
            compress_block: 0,
            slots_per_compress: 0,
            n_sink,
            seen: 0,
            compress: false,
        }
    }

    /// Current KV size in token-equivalents (sink + memory + window).
    pub fn kv_size(&self) -> usize {
        self.sink.len() + self.mem_slots_used + self.window.len()
    }

    /// Push one token; returns what to do about overflow (at most one
    /// action per push — callers loop if they push many tokens).
    pub fn push(&mut self, tok: i32) -> Overflow {
        self.seen += 1;
        if self.sink.len() < self.n_sink {
            self.sink.push(tok);
            return Overflow::None;
        }
        self.window.push(tok);
        if self.kv_size() <= self.max_kv {
            return Overflow::None;
        }
        if self.compress {
            // Emit enough blocks to restore the budget even after the
            // memory grows (capped at mem_slots_max) per block.
            let mut blocks = Vec::new();
            let mut mem_sim = self.mem_slots_used;
            while self.sink.len() + mem_sim + self.window.len() > self.max_kv
                && !self.window.is_empty()
            {
                let n = self.compress_block.min(self.window.len());
                blocks.push(self.window.drain(..n).collect());
                mem_sim = (mem_sim + self.slots_per_compress).min(self.mem_slots_max);
            }
            Overflow::Compress(blocks)
        } else {
            let n = (self.kv_size() - self.max_kv).min(self.window.len());
            self.window.drain(..n);
            Overflow::Drop(n)
        }
    }

    /// Record a memory update after a compression step; returns how many
    /// oldest memory *slots* must be evicted to stay within mem_slots_max
    /// (CCM-concat emits oldest compressed pairs, Figure 9).
    pub fn note_compressed(&mut self, new_slots: usize) -> usize {
        self.mem_slots_used += new_slots;
        if self.mem_slots_used > self.mem_slots_max {
            let evict = self.mem_slots_used - self.mem_slots_max;
            self.mem_slots_used = self.mem_slots_max;
            evict
        } else {
            0
        }
    }

    /// Budget-fair window cap for the baseline comparison: StreamingLLM
    /// may hold this many raw tokens when CCM holds `ccm_mem` slots.
    pub fn equal_budget_window(max_kv: usize, n_sink: usize) -> usize {
        max_kv - n_sink
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_fills_first() {
        let mut w = StreamWindow::ccm(16, 4, 4, 1, 2);
        assert_eq!(w.push(10), Overflow::None);
        assert_eq!(w.push(11), Overflow::None);
        assert_eq!(w.sink, vec![10, 11]);
        assert!(w.window.is_empty());
    }

    #[test]
    fn ccm_compresses_oldest_blocks() {
        let mut w = StreamWindow::ccm(9, 2, 3, 1, 1);
        let mut saw_compress = false;
        for t in 0..30 {
            match w.push(t) {
                Overflow::Compress(blocks) => {
                    saw_compress = true;
                    for b in blocks {
                        assert!(!b.is_empty() && b.len() <= 3);
                        w.note_compressed(1);
                        assert!(w.mem_slots_used <= w.mem_slots_max);
                    }
                    assert!(w.kv_size() <= w.max_kv, "kv {} > {}", w.kv_size(), w.max_kv);
                }
                Overflow::None => {}
                Overflow::Drop(_) => panic!("ccm never drops"),
            }
        }
        assert!(saw_compress && w.mem_slots_used > 0);
    }

    #[test]
    fn concat_memory_saturates_and_evicts() {
        let mut w = StreamWindow::ccm(64, 4, 8, 2, 0);
        assert_eq!(w.note_compressed(2), 0);
        assert_eq!(w.note_compressed(2), 0);
        assert_eq!(w.note_compressed(2), 2); // over 4-slot cap -> evict 2
        assert_eq!(w.mem_slots_used, 4);
    }

    #[test]
    fn streaming_llm_drops_to_budget() {
        let mut w = StreamWindow::streaming_llm(6, 2);
        for t in 0..30 {
            match w.push(t) {
                Overflow::Drop(n) => assert!(n >= 1),
                Overflow::None => {}
                Overflow::Compress(_) => panic!("baseline never compresses"),
            }
            assert!(w.kv_size() <= 6);
        }
        assert_eq!(w.sink, vec![0, 1]); // sink pinned forever
        assert_eq!(w.window.len(), 4);
        assert_eq!(*w.window.last().unwrap(), 29);
    }

    #[test]
    fn kv_budget_invariant_under_random_ops() {
        crate::util::proptest::check("stream-budget", 50, |rng| {
            let cap = rng.range(1, 8);
            let sink = rng.range(0, 4);
            let max_kv = sink + cap + rng.range(4, 48);
            let block = rng.range(1, 6);
            let spc = rng.range(1, cap + 1);
            let mut w = StreamWindow::ccm(max_kv, cap, block, spc, sink);
            for t in 0..rng.range(50, 300) {
                if let Overflow::Compress(blocks) = w.push(t as i32) {
                    crate::prop_assert!(!blocks.is_empty(), "empty compress action");
                    for b in blocks {
                        crate::prop_assert!(!b.is_empty(), "empty block");
                        w.note_compressed(spc);
                    }
                }
                crate::prop_assert!(
                    w.kv_size() <= max_kv,
                    "budget violated: {} > {max_kv}",
                    w.kv_size()
                );
            }
            Ok(())
        });
    }
}
